"""Deterministic synthetic data pipelines (the container is offline).

Three generators, all seeded and host-shardable (seed folds in (stream,
step, host) so every host materializes exactly its shard — the standard
multi-host input pipeline contract):

  * token streams   — Zipf-distributed ids with Markov momentum (LM-ish);
  * image rows      — smooth 2-D random fields quantized to bytes
                      (spatially correlated: the Fig. 3/4(b) workload);
  * batches         — train batches (tokens, labels=shift) for any cfg;
  * candidate planes — model-top-k stand-ins for decoder speculation
                      sweeps (a model's top-1 accuracy without its cost).
"""

from __future__ import annotations

import numpy as np

from repro.models.config import ModelConfig


def _rng(*keys: int) -> np.random.Generator:
    return np.random.default_rng(np.abs(hash(keys)) % (2**63)) if False else \
        np.random.default_rng([k & 0x7FFFFFFF for k in keys])


def token_stream(vocab: int, shape: tuple, *, seed: int = 0,
                 zipf_a: float = 1.3, momentum: float = 0.3) -> np.ndarray:
    """Zipf + first-order momentum: compressible, non-trivial stream."""
    rng = _rng(seed, vocab, *shape)
    n = int(np.prod(shape))
    ranks = rng.zipf(zipf_a, size=n).astype(np.int64)
    toks = (ranks - 1) % vocab
    # momentum: with prob `momentum`, repeat the previous symbol
    rep = rng.random(n) < momentum
    out = toks.copy()
    for i in range(1, n):
        if rep[i]:
            out[i] = out[i - 1]
    return out.reshape(shape)


def image_rows(lanes: int, t: int, *, seed: int = 0,
               step_scale: int = 3) -> np.ndarray:
    """Smooth random-walk rows in [0,255] — image-like raster symbols."""
    rng = _rng(seed, lanes, t)
    steps = rng.integers(-step_scale, step_scale + 1, (lanes, t))
    return np.clip(128 + np.cumsum(steps, axis=1), 0, 255).astype(np.int64)


def synthetic_image(h: int, w: int, *, seed: int = 0) -> np.ndarray:
    """2-D smooth field (separable random-walk) quantized to uint8."""
    rng = _rng(seed, h, w)
    rows = np.cumsum(rng.integers(-2, 3, (h, 1)), axis=0)
    cols = np.cumsum(rng.integers(-2, 3, (1, w)), axis=1)
    noise = rng.integers(-4, 5, (h, w))
    img = 128 + rows + cols + noise
    return np.clip(img, 0, 255).astype(np.uint8)


def candidate_planes(syms: np.ndarray, k: int, topk: int,
                     hit_rate: float, seed: int = 0) -> np.ndarray:
    """(T, lanes, topk) model-top-k stand-in for speculation workloads.

    Slot 0 holds the true symbol with probability ``hit_rate`` (a model's
    top-1 accuracy); the remaining slots are random alphabet ids.  The
    decode-backend sweeps and the Fig. 4(b) probe-regression tests share
    this single synthesizer so the benchmark measures exactly the workload
    the tests pin.
    """
    rng = _rng(seed, k, topk)
    syms = np.asarray(syms)
    lanes, t = syms.shape
    cands = rng.integers(0, k, (t, lanes, topk))
    hit = rng.random((t, lanes)) < hit_rate
    cands[..., 0] = np.where(hit, syms.T, cands[..., 0])
    return cands.astype(np.int32)


def train_batch(cfg: ModelConfig, batch: int, seq: int, *, step: int = 0,
                host: int = 0, seed: int = 0) -> dict:
    rng = _rng(seed, step, host, batch, seq)
    toks = token_stream(cfg.vocab_size, (batch, seq + 1),
                        seed=seed * 1000003 + step * 101 + host)
    out = {"tokens": toks[:, :-1].astype(np.int32),
           "labels": toks[:, 1:].astype(np.int32)}
    if cfg.family == "vlm":
        out["memory"] = (rng.standard_normal(
            (batch, cfg.memory_tokens, cfg.d_model)) * 0.02).astype(
                np.float32)
    if cfg.is_encdec:
        out["enc_inputs"] = (rng.standard_normal(
            (batch, cfg.memory_tokens, cfg.d_model)) * 0.02).astype(
                np.float32)
    return out
