"""Architecture registry + assigned input shapes.

Every assigned architecture registers its exact ``ModelConfig`` (and a
reduced ``smoke`` variant for CPU tests) under its pool id; the shape table
below is the assigned (arch x shape) grid for the dry-run.
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass

from repro.models.config import ModelConfig

ARCH_IDS = (
    "llama-3.2-vision-11b",
    "qwen1.5-4b",
    "qwen3-4b",
    "qwen3-32b",
    "llama3-405b",
    "mixtral-8x22b",
    "phi3.5-moe-42b-a6.6b",
    "mamba2-130m",
    "seamless-m4t-large-v2",
    "recurrentgemma-2b",
    # the paper's own compact image-probability model (extra, not in the grid)
    "ras-pimc",
)

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}

# the archs whose smoke configs are wired end to end through the serve
# stack (compress -> container -> fused kernel decompress -> engine) in
# tier-1 CI — one per state shape: pure ring (dense), pure recurrent
# (ssm), and ring + recurrent hybrid
SERVE_SMOKE_ARCHS = ("ras-pimc", "mamba2-130m", "recurrentgemma-2b")


def _module(arch: str) -> str:
    try:
        return _MODULES[arch]
    except KeyError:
        raise KeyError(
            f"unknown arch {arch!r}: registered ids are "
            f"{', '.join(ARCH_IDS)}") from None


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module(arch)}")
    return mod.SMOKE


def get_protocol(arch: str):
    """The arch's :class:`repro.models.ModelProtocol` (family dispatch)."""
    from repro.models import get_protocol as _by_cfg
    return _by_cfg(get_config(arch))


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runnable, reason-if-skipped) per the assignment's shape rules."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("full quadratic attention at 524k context; "
                       "sub-quadratic archs only (DESIGN.md "
                       "§Arch-applicability)")
    return True, ""


def grid():
    """All 40 assigned (arch, shape) cells with applicability."""
    for arch in ARCH_IDS:
        if arch == "ras-pimc":
            continue
        cfg = get_config(arch)
        for sname, sh in SHAPES.items():
            ok, why = shape_applicable(cfg, sh)
            yield arch, sname, ok, why
