"""recurrentgemma-2b [hybrid]: 26L d=2560 10H (MQA kv=1) ff=7680 v=256000,
RG-LRU + local attention 1:2 (pattern rec,rec,attn).

Sub-quadratic: runs long_500k (RG-LRU O(1) state + ring-buffer local-attn
cache of 2048).  TP: 10 q heads pad to 16; the single kv head replicates.
[arXiv:2402.19427; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    block_pattern=("rec", "rec", "attn"),
    local_window=2048,
    tie_embeddings=True,
    tp=16,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    n_layers=4,                  # one (rec,rec,attn) pattern + 1 tail rec
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    block_pattern=("rec", "rec", "attn"),
    local_window=16,
    tie_embeddings=True,
    tp=1,
    dtype="float32",
    remat=False,
)
