"""mixtral-8x22b [moe]: 56L d=6144 48H (GQA kv=8) ff=16384 v=32768,
MoE 8e top-2, SWA.

EP note: 8 experts < tp=16, so experts replicate across model and each
expert's FFN shards over model (per-expert TP); capacity-based dispatch.
[arXiv:2401.04088; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    n_experts=8,
    topk_experts=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    tp=16,
    dtype="bfloat16",
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="mixtral-8x22b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    n_experts=4,
    topk_experts=2,
    sliding_window=16,
    tp=1,
    dtype="float32",
    remat=False,
)
