"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) ff=6400 v=32064,
MoE 16e top-2.

EP note: 16 experts == tp, so the expert dim shards exactly over the model
axis (expert parallelism); dispatch/combine lower to the EP all-to-all.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    head_dim=128,
    n_experts=16,
    topk_experts=2,
    tp=16,
    dtype="bfloat16",
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=512,
    head_dim=16,
    n_experts=8,
    topk_experts=2,
    tp=1,
    dtype="float32",
    remat=False,
)
