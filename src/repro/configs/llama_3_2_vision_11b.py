"""llama-3.2-vision-11b [vlm]: 40L d=4096 32H (GQA kv=8) ff=14336 v=128256.

Cross-attention image layers: 1 per 5 (8 cross layers over 40).  The vision
frontend is a STUB — input_specs() supplies precomputed patch embeddings
(B, memory_tokens, d_model) as the cross-attention memory.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    memory_tokens=4096,        # stub patch-embedding sequence
    memory_dim=4096,
    tp=16,
    dtype="bfloat16",
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke",
    family="vlm",
    n_layers=5,                # one full (4 self + 1 cross) pattern
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    cross_attn_every=5,
    memory_tokens=8,
    memory_dim=64,
    tp=1,
    dtype="float32",
    remat=False,
)
