"""qwen3-4b [dense]: 36L d=2560 32H (GQA kv=8) ff=9728 v=151936, qk_norm.
[hf:Qwen/Qwen3-8B; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tp=16,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="qwen3-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    qk_norm=True,
    tp=1,
    dtype="float32",
    remat=False,
)
