"""llama3-405b [dense]: 126L d=16384 128H (GQA kv=8) ff=53248 v=128256.

Fitting notes (DESIGN.md §5): FSDP over the data axis + gradient
accumulation (16 microbatches) + remat + sequence-chunked loss are on by
default — this is what brings per-device memory inside a v5e HBM at 256/512
chips.  [arXiv:2407.21783; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    head_dim=128,
    rope_theta=500_000.0,
    tp=16,
    dtype="bfloat16",
    grad_accum=8,               # microbatch 32 divides both dp extents
    moment_dtype="bfloat16",    # 6.3 GB moments/chip instead of 12.7
    grad_dtype="bfloat16",      # 3.2 GB grads/chip instead of 6.3
    attn_impl="blockwise",
    act_pspec=(("pod", "data"), "model", None),  # SP residuals
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    head_dim=16,
    tp=1,
    dtype="float32",
    remat=False,
    grad_accum=2,
    logits_chunk=8,
    attn_impl="blockwise",
    attn_block=8,
)
