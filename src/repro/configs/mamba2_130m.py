"""mamba2-130m [ssm]: 24L d=768 (attn-free) v=50280, ssm_state=128, SSD.

Sub-quadratic: runs the long_500k shape (O(1)-state decode).
[arXiv:2405.21060; unverified]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    ssm_chunk=128,
    tie_embeddings=True,
    tp=16,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="mamba2-130m-smoke",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=16,
    ssm_chunk=8,
    tie_embeddings=True,
    tp=1,
    dtype="float32",
    remat=False,
)
