"""qwen1.5-4b [dense]: 40L d=2560 20H (GQA kv=20) ff=6912 v=151936, QKV bias.

TP note: 20 q heads pad to 32 for tp=16 (zero-init extras); kv=20 is not
divisible by 16 so kv projections replicate over model (+FSDP over data).
[hf:Qwen/Qwen1.5-0.5B; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tp=16,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="qwen1.5-4b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    qkv_bias=True,
    tp=1,
    dtype="float32",
    remat=False,
)
