"""qwen3-32b [dense]: 64L d=5120 64H (GQA kv=8) ff=25600 v=151936, qk_norm.
[hf:Qwen/Qwen3-8B; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    d_ff=25600,
    vocab_size=151936,
    head_dim=80,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tp=16,
    dtype="bfloat16",
    grad_accum=8,
)

SMOKE = ModelConfig(
    name="qwen3-32b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    head_dim=8,
    qk_norm=True,
    tp=1,
    dtype="float32",
    remat=False,
)
