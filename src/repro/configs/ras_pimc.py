"""ras-pimc: the paper's own compact image-probability model (PiMC-style).

A small autoregressive context model over 8-bit pixel symbols (alphabet 256)
that feeds the SPC + rANS fabric in the compression benchmarks — the "PC /
compact NN" probability generator of Fig. 1/2.  Not part of the assigned
dry-run grid; used by examples/compress_images.py and bench_ratio.py.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="ras-pimc",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=4,
    d_ff=512,
    vocab_size=256,
    head_dim=64,
    tie_embeddings=True,
    tp=1,
    dtype="float32",
    remat=False,
)

SMOKE = CONFIG.with_(name="ras-pimc-smoke", n_layers=2, d_model=64,
                     d_ff=128, head_dim=16)
