from repro.configs.registry import (ARCH_IDS, SERVE_SMOKE_ARCHS, SHAPES,
                                    ShapeSpec, get_config, get_protocol,
                                    get_smoke_config, grid, shape_applicable)

__all__ = ["ARCH_IDS", "SERVE_SMOKE_ARCHS", "SHAPES", "ShapeSpec",
           "get_config", "get_protocol", "get_smoke_config", "grid",
           "shape_applicable"]
