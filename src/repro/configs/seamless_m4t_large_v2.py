"""seamless-m4t-large-v2 [audio]: 24L d=1024 16H (kv=16) ff=8192 v=256206,
enc-dec, multimodal.

The audio frontend is a STUB — input_specs() supplies precomputed frame
embeddings (B, memory_tokens, d_model) consumed by the text decoder's
cross-attention after a 24-layer bidirectional encoder.
[arXiv:2308.11596; hf]
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,                 # decoder layers (self + cross + MLP)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    encoder_layers=24,
    memory_tokens=1024,          # stub speech-frame sequence
    memory_dim=1024,
    block_pattern=("dec",),
    tp=16,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="seamless-m4t-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    head_dim=16,
    encoder_layers=2,
    memory_tokens=8,
    memory_dim=64,
    block_pattern=("dec",),
    tp=1,
    dtype="float32",
    remat=False,
)
