"""Serving / compression launcher (the paper's deployment direction).

    python -m repro.launch.serve --arch ras-pimc --mode compress --lanes 8 \
        --symbols 256

Loads (or freshly initializes) a probability model, compresses a synthetic
stream through SPC + multi-lane rANS, decompresses it with prediction-guided
decoding, and verifies bit-exactness — the full Fig. 2 datapath.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import bitstream
from repro.data.pipeline import token_stream
from repro.models import init_model
from repro.serve.compress import lm_compress, lm_decompress
from repro.serve.engine import generate
from repro.train import checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ras-pimc")
    ap.add_argument("--mode", choices=["compress", "generate"],
                    default="compress")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--symbols", type=int, default=256)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--topk", type=int, default=4)
    ap.add_argument("--backend", choices=["coder", "kernel", "two_pass"],
                    default="coder",
                    help="rANS datapath: 'coder' = pure-JAX lane coder; "
                         "'kernel' = Pallas encode + the FUSED serve decode "
                         "(one program: model step + SPC + per-step decode "
                         "kernel); 'two_pass' = Pallas encode + the "
                         "collect-then-replay reference decode "
                         "(interpret mode off-TPU)")
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch)
    params = init_model(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        step = checkpoint.latest_step(args.ckpt)
        if step is not None:
            from repro.train.train_loop import init_train_state
            state = checkpoint.restore(args.ckpt, step,
                                       init_train_state(params))
            params = state.params
            print(f"restored checkpoint step {step}")

    if args.mode == "generate":
        prompt = jnp.asarray(
            token_stream(cfg.vocab_size, (2, 16), seed=1), jnp.int32)
        out = generate(params, cfg, prompt, 32, max_len=64)
        print("generated:", np.asarray(out))
        return

    toks = jnp.asarray(token_stream(cfg.vocab_size,
                                    (args.lanes, args.symbols), seed=7),
                       jnp.int32)
    t0 = time.time()
    enc_backend = "coder" if args.backend == "coder" else "kernel"
    stats = lm_compress(params, cfg, toks, backend=enc_backend)
    jax.block_until_ready(stats.enc.buf)
    t_enc = time.time() - t0
    blob = bitstream.pack(*map(np.asarray, stats.enc),
                          n_symbols=args.symbols)
    t0 = time.time()
    dec, probes = lm_decompress(params, cfg, stats.enc, args.symbols,
                                topk=args.topk, backend=args.backend)
    jax.block_until_ready(dec)
    t_dec = time.time() - t0
    exact = bool(np.array_equal(np.asarray(dec), np.asarray(toks)))
    raw = args.lanes * args.symbols
    print(f"lanes={args.lanes} symbols/lane={args.symbols} "
          f"backend={args.backend}")
    print(f"  bits/symbol     : {float(stats.bits_per_symbol):.3f} "
          f"(model bound {float(stats.model_xent_bits):.3f})")
    print(f"  container bytes : {len(blob)} (raw {raw})  "
          f"CR={raw/len(blob):.3f}")
    print(f"  encode {t_enc:.2f}s  decode {t_dec:.2f}s  "
          f"avg CDF probes/symbol {float(probes):.2f}")
    print(f"  bit-exact roundtrip: {exact}")
    assert exact


if __name__ == "__main__":
    main()
