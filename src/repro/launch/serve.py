"""Serving / compression launcher (the paper's deployment direction).

    python -m repro.launch.serve --arch ras-pimc --mode compress --lanes 8 \
        --symbols 256
    python -m repro.launch.serve --mode engine --streams 6 --slots 2 \
        --arrival-rate 0.5

``--mode compress`` runs one stream end to end: SPC + multi-lane rANS
encode, prediction-guided decode, bit-exactness check — the full Fig. 2
datapath.  ``--mode engine`` drives the batched multi-stream engine
instead: ``--streams`` requests with seeded Poisson arrivals
(``--arrival-rate`` per virtual tick) are continuously batched into
``--slots`` slots of one traced step program, every round-tripped stream
is verified byte-identical to the single-request path, and per-request
latency (admission wait included) is reported.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core import bitstream
from repro.data.pipeline import token_stream
from repro.models import init_model, state_spec
from repro.serve.compress import lm_compress, lm_decompress
from repro.serve.engine import generate
from repro.train import checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ras-pimc", metavar="ARCH",
                    help="any registered arch id (configs.registry.ARCH_IDS)"
                         " — the serve stack is family-agnostic behind the "
                         "model-state protocol: SSM / rGLRU / MoE smoke "
                         "configs all run the same datapath")
    ap.add_argument("--mode", choices=["compress", "generate", "engine"],
                    default="compress",
                    help="compress = one stream end to end; generate = "
                         "sampled rollout; engine = batched multi-stream "
                         "serving (continuous batching, Poisson arrivals)")
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--symbols", type=int, default=256)
    ap.add_argument("--streams", type=int, default=6,
                    help="[engine] number of concurrent compress requests")
    ap.add_argument("--slots", type=int, default=2,
                    help="[engine] co-batched request slots in the shared "
                         "step program (rows = slots * lanes)")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="[engine] Poisson arrival rate per virtual tick "
                         "(one tick ~= one chunk cycle)")
    ap.add_argument("--chunk-size", type=int, default=16,
                    help="[engine] symbols per lane per scheduling chunk")
    ap.add_argument("--seed", type=int, default=0,
                    help="[engine] arrival-process seed (schedules are "
                         "deterministic per seed)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--topk", type=int, default=4)
    ap.add_argument("--backend", choices=["coder", "kernel", "two_pass"],
                    default="coder",
                    help="rANS datapath: 'coder' = pure-JAX lane coder; "
                         "'kernel' = Pallas encode + the FUSED serve decode "
                         "(one program: model step + SPC + per-step decode "
                         "kernel); 'two_pass' = Pallas encode + the "
                         "collect-then-replay reference decode "
                         "(interpret mode off-TPU)")
    args = ap.parse_args(argv)

    if args.arch not in ARCH_IDS:
        ap.error(f"unknown --arch {args.arch!r}; registered ids: "
                 f"{', '.join(ARCH_IDS)}")
    cfg = get_smoke_config(args.arch)
    spec = state_spec(cfg)
    state_kind = ("ring+recurrent" if spec.ring and spec.recurrent
                  else "recurrent" if spec.recurrent else "ring")
    print(f"arch={args.arch} family={cfg.family} kinds={spec.kinds} "
          f"state={state_kind}")
    params = init_model(cfg, jax.random.PRNGKey(0))
    if args.ckpt:
        step = checkpoint.latest_step(args.ckpt)
        if step is not None:
            from repro.train.train_loop import init_train_state
            state = checkpoint.restore(args.ckpt, step,
                                       init_train_state(params))
            params = state.params
            print(f"restored checkpoint step {step}")

    if args.mode == "engine":
        from repro.serve.compress import lm_compress_chunked
        from repro.serve.engine import BatchEngine
        rng = np.random.default_rng(args.seed)
        arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                             size=args.streams))
        streams = [np.asarray(token_stream(cfg.vocab_size,
                                           (args.lanes, args.symbols),
                                           seed=100 + i), np.int32)
                   for i in range(args.streams)]
        eng = BatchEngine(params, cfg, slots=args.slots, lanes=args.lanes,
                          chunk_size=args.chunk_size,
                          max_len=args.symbols)
        rids = [eng.submit_compress(s, arrival=float(a))
                for s, a in zip(streams, arrivals)]
        t0 = time.time()
        res = eng.run(clock="virtual")
        wall = time.time() - t0
        lat = []
        for rid, toks in zip(rids, streams):
            r = res[rid]
            assert r.ok, r.error
            stats = lm_compress_chunked(params, cfg, jnp.asarray(toks),
                                        chunk_size=args.chunk_size)
            enc = jax.tree.map(np.asarray, stats.chunks)
            ref = bitstream.pack_chunked(enc.buf, enc.start, enc.length,
                                         enc.overflow,
                                         chunk_size=args.chunk_size,
                                         n_symbols=args.symbols)
            assert r.blob == ref, f"request {rid}: engine blob diverged"
            lat.append(r.completed_at - r.arrival)
        lat = np.sort(np.asarray(lat))
        print(f"engine: {args.streams} streams x {args.lanes} lanes x "
              f"{args.symbols} symbols through {args.slots} slots")
        print(f"  wall {wall:.2f}s  throughput "
              f"{args.streams / wall:.2f} streams/s")
        print(f"  virtual latency (ticks): p50 {np.percentile(lat, 50):.1f} "
              f" p99 {np.percentile(lat, 99):.1f}")
        print(f"  all {args.streams} blobs byte-identical to the "
              "single-request path")
        return

    if args.mode == "generate":
        prompt = jnp.asarray(
            token_stream(cfg.vocab_size, (2, 16), seed=1), jnp.int32)
        out = generate(params, cfg, prompt, 32, max_len=64)
        print("generated:", np.asarray(out))
        return

    toks = jnp.asarray(token_stream(cfg.vocab_size,
                                    (args.lanes, args.symbols), seed=7),
                       jnp.int32)
    t0 = time.time()
    enc_backend = "coder" if args.backend == "coder" else "kernel"
    stats = lm_compress(params, cfg, toks, backend=enc_backend)
    jax.block_until_ready(stats.enc.buf)
    t_enc = time.time() - t0
    blob = bitstream.pack(*map(np.asarray, stats.enc),
                          n_symbols=args.symbols)
    t0 = time.time()
    dec, probes = lm_decompress(params, cfg, stats.enc, args.symbols,
                                topk=args.topk, backend=args.backend)
    jax.block_until_ready(dec)
    t_dec = time.time() - t0
    exact = bool(np.array_equal(np.asarray(dec), np.asarray(toks)))
    raw = args.lanes * args.symbols
    print(f"lanes={args.lanes} symbols/lane={args.symbols} "
          f"backend={args.backend}")
    print(f"  bits/symbol     : {float(stats.bits_per_symbol):.3f} "
          f"(model bound {float(stats.model_xent_bits):.3f})")
    print(f"  container bytes : {len(blob)} (raw {raw})  "
          f"CR={raw/len(blob):.3f}")
    print(f"  encode {t_enc:.2f}s  decode {t_dec:.2f}s  "
          f"avg CDF probes/symbol {float(probes):.2f}")
    print(f"  bit-exact roundtrip: {exact}")
    assert exact


if __name__ == "__main__":
    main()
