"""Production mesh builders.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — required for the dry-run's forced-512-device
initialization order.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod; 2 pods when multi_pod (512 chips total)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices: int, model_parallel: int = 16):
    """Elastic helper: largest (data, model) mesh for a survivor set."""
    model = min(model_parallel, devices)
    while devices % model:
        model -= 1
    return jax.make_mesh((devices // model, model), ("data", "model"))
