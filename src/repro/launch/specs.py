"""Dry-run cell builder: (arch x shape x mesh) -> (step_fn, abstract args,
shardings).  Nothing here allocates device memory — weights, optimizer
state, caches and batches are all ShapeDtypeStructs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, tree_map_with_path

from repro.configs.registry import SHAPES, ShapeSpec, get_config
from repro.models.config import ModelConfig
from repro.models.layers import logits as logits_fn
from repro.models.param import abstract_params
from repro.models.transformer import (decode_step, forward, init_cache,
                                      make_model_defs)
from repro.parallel.sharding import batch_pspec, param_shardings
from repro.train.train_loop import init_train_state, make_train_step


def tune_for_shape(cfg: ModelConfig, shape: ShapeSpec) -> ModelConfig:
    """Shape-dependent framework defaults (fit requirements, not tuning)."""
    if shape.kind == "prefill" and shape.seq_len >= 32_768 \
            and cfg.attn_impl == "naive":
        # a naive (B,H,32k,32k) score tensor cannot exist on any chip
        cfg = cfg.with_(attn_impl="blockwise", attn_block=2048)
    if shape.kind != "train":
        cfg = cfg.with_(grad_accum=1)
    elif cfg.grad_accum < 8:
        # fit requirement, not tuning: the remat stash scales with the local
        # microbatch; accum=8 keeps every arch's train_4k inside v5e HBM
        # (qwen3-4b: 100 GB -> 13 GB/chip).  Microbatch 32 divides both the
        # 16-way and 32-way DP extents.
        cfg = cfg.with_(grad_accum=8)
    return cfg


def _dp_axes_for(mesh, global_batch: int):
    return batch_pspec(mesh, global_batch, ndim=1)[0]


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Training batch ShapeDtypeStructs + shardings."""
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
             "labels": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.family == "vlm":
        specs["memory"] = jax.ShapeDtypeStruct(
            (b, cfg.memory_tokens, cfg.d_model), dt)
    if cfg.is_encdec:
        specs["enc_inputs"] = jax.ShapeDtypeStruct(
            (b, cfg.memory_tokens, cfg.d_model), dt)
    shard = jax.tree.map(
        lambda sds: NamedSharding(
            mesh, batch_pspec(mesh, shape.global_batch, sds.ndim)), specs)
    return specs, shard


def cache_shardings(cfg: ModelConfig, mesh, cache_abs, global_batch: int):
    """Path-aware cache shardings: batch over DP; KV heads over model when
    divisible, else the *sequence* dim of KV caches shards over model
    (decode context parallelism — the llama3-405b 32k-cache fit lever);
    SSM/RG-LRU state shards its feature dim over model."""
    model_n = mesh.shape["model"]
    b_axes = _dp_axes_for(mesh, global_batch)

    def spec(path, leaf):
        keys = [k.key for k in path if isinstance(k, DictKey)]
        dims: list = [None] * leaf.ndim
        if leaf.ndim >= 2:
            dims[1] = b_axes           # (layer_stack, batch, ...)
        if "kv" in keys and leaf.ndim == 5:
            if cfg.kv_sharded:
                dims[3] = "model"
            elif leaf.shape[2] % model_n == 0:
                dims[2] = "model"      # context-parallel cache
        elif keys and keys[-1] in ("h", "conv"):
            if leaf.shape[-1] % model_n == 0:
                dims[-1] = "model"
        return NamedSharding(mesh, P(*dims))

    return tree_map_with_path(spec, cache_abs)


def build_cell(arch: str, shape_name: str, mesh, *, fsdp: bool = True,
               overrides: dict | None = None):
    """Returns (fn, args, in_shardings, out_shardings, donate, cfg)."""
    shape = SHAPES[shape_name]
    cfg = tune_for_shape(get_config(arch), shape)
    if overrides:
        cfg = cfg.with_(**overrides)
    multi_pod = "pod" in mesh.axis_names
    if cfg.act_pspec is not None and not multi_pod:
        # drop the pod axis from activation constraints on the single pod
        fixed = tuple(tuple(a for a in ax if a != "pod") if
                      isinstance(ax, tuple) else ax for ax in cfg.act_pspec)
        cfg = cfg.with_(act_pspec=fixed)
    if cfg.act_pspec is None and shape.kind != "decode":
        # default residual-stream constraint: batch over the DP axes
        b_axes = batch_pspec(mesh, shape.global_batch, 1)[0]
        cfg = cfg.with_(act_pspec=(b_axes, None, None))

    defs = make_model_defs(cfg)
    p_abs = abstract_params(defs, jnp.dtype(cfg.dtype))
    p_shard = param_shardings(cfg, mesh, defs, fsdp=fsdp)
    b, s = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        state_abs = jax.eval_shape(
            functools.partial(init_train_state,
                              moment_dtype=jnp.dtype(cfg.moment_dtype)),
            p_abs)
        rep = NamedSharding(mesh, P())
        state_shard = type(state_abs)(
            params=p_shard,
            opt=type(state_abs.opt)(step=rep, m=p_shard, v=p_shard),
            step=rep, error=None)
        batch_abs, batch_shard = batch_specs(cfg, shape, mesh)
        fn = make_train_step(cfg)
        return (fn, (state_abs, batch_abs), (state_shard, batch_shard),
                (state_shard, None), (0,), cfg)

    if shape.kind == "prefill":
        batch_abs, batch_shard = batch_specs(cfg, shape, mesh)
        batch_abs.pop("labels")
        batch_shard.pop("labels")

        def prefill_step(params, batch):
            """Compression direction: all per-position distributions."""
            x, _ = forward(params, batch["tokens"], cfg,
                           memory=batch.get("memory"),
                           enc_inputs=batch.get("enc_inputs"))
            return logits_fn(params["tok"], x, cfg).astype(jnp.bfloat16)

        return (prefill_step, (p_abs, batch_abs), (p_shard, batch_shard),
                None, (), cfg)

    # decode: one token against a seq_len cache (serve_step)
    cache_abs = jax.eval_shape(
        functools.partial(init_cache, cfg, b, s))
    cache_shard = cache_shardings(cfg, mesh, cache_abs, b)
    tok_abs = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tok_shard = NamedSharding(mesh, batch_pspec(mesh, b, 2))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    pos_shard = NamedSharding(mesh, P())
    args = [p_abs, cache_abs, tok_abs, pos_abs]
    in_sh = [p_shard, cache_shard, tok_shard, pos_shard]
    lg_shard = NamedSharding(mesh, P(batch_pspec(mesh, b, 1)[0], "model"))
    needs_mem = cfg.family == "vlm" or cfg.is_encdec
    if needs_mem:
        args.append(jax.ShapeDtypeStruct(
            (b, cfg.memory_tokens, cfg.d_model), jnp.dtype(cfg.dtype)))
        in_sh.append(NamedSharding(mesh, batch_pspec(mesh, b, 3)))

        def serve_step(params, cache, token, pos, memory):
            return decode_step(params, cache, token, pos, cfg, memory=memory)
    else:
        def serve_step(params, cache, token, pos):
            return decode_step(params, cache, token, pos, cfg)

    return (serve_step, tuple(args), tuple(in_sh),
            (lg_shard, cache_shard), (1,), cfg)
