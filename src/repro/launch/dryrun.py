import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/collective analysis.

MUST be run as its own process (`python -m repro.launch.dryrun ...`) — the
two lines above run before any jax import so the 512 placeholder devices
exist before jax locks the device count.  Never set that flag globally:
smoke tests and benchmarks see 1 device.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import sys
import time
import traceback

import jax

from repro.analysis import roofline
from repro.configs.registry import SHAPES, grid, shape_applicable, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import build_cell


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str | None = None, verbose: bool = True,
             fsdp: bool = True, overrides: dict | None = None,
             tag: str = "") -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = mesh.devices.size
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "SKIP", "reason": why}
        _emit(rec, out_dir, verbose)
        return rec

    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, donate, cfg = build_cell(
            arch, shape_name, mesh, fsdp=fsdp, overrides=overrides)
        with jax.set_mesh(mesh):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            rep = roofline.analyze(compiled, cfg, shape, arch, mesh_name,
                                   chips)
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "OK", "tag": tag,
            "fsdp": fsdp, "overrides": overrides,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "memory_analysis": {
                "argument_bytes": int(mem.argument_size_in_bytes),
                "output_bytes": int(mem.output_size_in_bytes),
                "temp_bytes": int(mem.temp_size_in_bytes),
                "alias_bytes": int(mem.alias_size_in_bytes),
                "total_per_chip_gb": round(
                    (mem.argument_size_in_bytes + mem.output_size_in_bytes
                     + mem.temp_size_in_bytes
                     - mem.alias_size_in_bytes) / 2**30, 3),
            },
            "roofline": json.loads(rep.to_json()),
        }
    except Exception as e:  # noqa: BLE001 — record the failure, keep going
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "FAIL", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-2000:]}
    _emit(rec, out_dir, verbose)
    return rec


def _emit(rec: dict, out_dir: str | None, verbose: bool):
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{rec['tag']}" if rec.get("tag") else ""
        path = os.path.join(
            out_dir,
            f"{rec['mesh']}__{rec['arch']}__{rec['shape']}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    if verbose:
        if rec["status"] == "OK":
            r = rec["roofline"]
            print(f"[OK]   {rec['mesh']:12s} {rec['arch']:24s} "
                  f"{rec['shape']:12s} mem={rec['memory_analysis']['total_per_chip_gb']:7.2f}GB "
                  f"compute={r['compute_s']*1e3:8.2f}ms "
                  f"mem={r['memory_s']*1e3:8.2f}ms "
                  f"coll={r['collective_s']*1e3:8.2f}ms "
                  f"dom={r['dominant']}", flush=True)
        elif rec["status"] == "SKIP":
            print(f"[SKIP] {rec['mesh']:12s} {rec['arch']:24s} "
                  f"{rec['shape']:12s} ({rec['reason'][:60]})", flush=True)
        else:
            print(f"[FAIL] {rec['mesh']:12s} {rec['arch']:24s} "
                  f"{rec['shape']:12s} {rec['error'][:200]}", flush=True)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--no-fsdp", action="store_true",
                    help="replicate weights over data (inference mode)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (hillclimb knobs)")
    ap.add_argument("--tag", default="", help="suffix for output json")
    args = ap.parse_args(argv)

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("true", "false"):
            v = v == "true"
        overrides[k] = v

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    fails = 0
    if args.all:
        for multi in meshes:
            for arch, shape_name, ok, why in grid():
                rec = run_cell(arch, shape_name, multi, args.out,
                               fsdp=not args.no_fsdp,
                               overrides=overrides or None, tag=args.tag)
                fails += rec["status"] == "FAIL"
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        for multi in meshes:
            rec = run_cell(args.arch, args.shape, multi, args.out,
                           fsdp=not args.no_fsdp,
                           overrides=overrides or None, tag=args.tag)
            fails += rec["status"] == "FAIL"
    sys.exit(1 if fails else 0)


if __name__ == "__main__":
    main()
