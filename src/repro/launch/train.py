"""Training launcher.

Two modes:
  * real run (CPU-scale):  python -m repro.launch.train --arch ras-pimc
        --steps 200 --batch 8 --seq 128 --ckpt /tmp/ckpt
    runs the full fault-tolerant loop (RestartManager + StragglerMonitor +
    periodic checkpoints) on the smoke config of the chosen arch.
  * production lowering is exercised by launch/dryrun.py (same step fn).
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.pipeline import train_batch
from repro.models import init_model
from repro.train.fault_tolerance import RestartManager
from repro.train.train_loop import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ras-pimc")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch).with_(grad_accum=1)
    params = init_model(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params)
    step_fn = jax.jit(make_train_step(cfg, base_lr=args.lr))

    last_loss = [None]

    def wrapped(state, batch):
        state, metrics = step_fn(state, batch)
        last_loss[0] = float(metrics["loss"])
        if int(state.step) % 10 == 0:
            print(f"step {int(state.step):5d} loss {last_loss[0]:.4f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        return state, metrics

    def batch_fn(i):
        return jax.tree.map(jnp.asarray,
                            train_batch(cfg, args.batch, args.seq, step=i))

    mgr = RestartManager(args.ckpt, save_every=args.save_every)
    state = mgr.run(state, wrapped, batch_fn, args.steps)
    print(f"done: {int(state.step)} steps, final loss {last_loss[0]:.4f}, "
          f"{len(mgr.monitor.slow_steps)} straggler steps, "
          f"{mgr.failures} restarts")
    return state


if __name__ == "__main__":
    main()
