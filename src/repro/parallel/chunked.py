"""Chunk x lane placement of the chunked rANS codec on a device mesh.

The chunked streams of ``core.coder.encode_chunked`` are independent by
construction (every chunk has its own flush), so the chunk axis is an
embarrassingly-parallel device axis: this module places the full-size
chunks of a ``(n_chunks, lanes, cap)`` stream on a 1-D ``("chunks",)``
mesh with ``shard_map`` — each device runs the vmap'd single-chunk
coder over its local chunk slab, no collectives at all (the multi-device
generalization of the paper's multi-lane fabric, Sec. III).

Fallback contract: with one device, a ``None`` mesh, or a chunk count not
divisible by the mesh size, both entry points degrade to the plain vmap
path in ``core.coder`` — bit-exactly the same streams/symbols either way
(the tier-1 differential test pins shard_map == vmap symbol-for-symbol).
The ragged tail chunk, when present, is always coded on the default device.

:func:`lane_mesh` is the companion 1-D ``("lanes",)`` mesh for the FUSED
serve decode (``serve.compress``, ``backend="kernel"``): that program is
sequential over positions/chunks, so its parallel axis is the lane, not
the chunk (see the function docstring and DESIGN.md §9).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import bitstream, coder, constants as C
from repro.core.bitstream import ContainerSlab
from repro.core.coder import ChunkedLanes, EncodedLanes
from repro.core.spc import TableSet


def chunk_mesh(devices=None) -> Mesh:
    """1-D ``("chunks",)`` mesh over ``devices`` (default: all devices)."""
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), ("chunks",))


def lane_mesh(devices=None) -> Mesh:
    """1-D ``("lanes",)`` mesh over ``devices`` (default: all devices).

    The placement axis of the FUSED serve decode (``serve.compress``,
    ``backend="kernel"``): that program is sequential over positions and
    chunks — the model is autoregressive over its own decoded tokens — so
    the chunk axis cannot shard it.  Lanes can: each lane owns a private
    byte stream, a private rANS state and an independent model batch row,
    so the fused scan runs per-device on a lane slab with no collectives
    (the multi-device form of the paper's multi-lane fabric for the decode
    direction).  Same fallback contract as :func:`chunk_mesh`: indivisible
    lane counts degrade to the single-device program, bit-exactly.
    """
    devices = jax.devices() if devices is None else list(devices)
    return Mesh(np.asarray(devices), ("lanes",))


def _usable(mesh: Mesh | None, n_full: int) -> bool:
    return (mesh is not None and "chunks" in mesh.axis_names
            and n_full > 0 and n_full % mesh.shape["chunks"] == 0)


def lane_mesh_usable(mesh: Mesh | None, rows: int,
                     what: str = "fused serve decode") -> bool:
    """Validate/route a ``("lanes",)`` mesh for an independent row axis.

    The ONE routing contract shared by every lane-parallel serve program
    (the fused decode of ``serve.compress`` and the batched engine's
    slots x lanes row axis — both are sequential over positions, so their
    only parallel axis is the row).  True = place ``rows`` rows on the
    mesh; False = degrade to the single-device program (row counts that
    don't divide the mesh fall back bit-exactly — same contract as the
    chunk mesh's :func:`_usable`).  A mesh without a ``"lanes"`` axis
    raises: chunk meshes place the two-pass kernel replay, not the
    sequential row-parallel programs.
    """
    if mesh is None:
        return False
    if "lanes" not in mesh.axis_names:
        raise ValueError(
            f"the {what} parallelizes over the lane axis: pass a "
            '("lanes",) mesh (parallel.chunked.lane_mesh).  Chunk meshes '
            "place the two-pass kernel replay — use backend='two_pass' "
            "with a ('chunks',) mesh instead")
    return rows > 0 and rows % mesh.shape["lanes"] == 0


def state_row_specs(state, row_axis: int = 1):
    """PartitionSpec tree sharding a model-state pytree's row axis.

    The model-state protocol (``repro.models.state_spec``) pins every
    state leaf — KV rings and recurrent ``(h, conv)`` alike — to carry
    the batch row on axis ``row_axis`` (axis 1 behind the stage ``reps``
    axis), so ONE spec tree places *arbitrary* state on a ``("lanes",)``
    mesh: ``P(None, "lanes")`` shards rows and replicates every
    trailing per-leaf dimension (ring slots, conv taps, SSD planes —
    a PartitionSpec shorter than the leaf rank replicates the rest).
    Consumed by the batched engine's shard_map carry; the companion of
    :func:`lane_mesh_usable` on the same routing contract.
    """
    spec = P(*([None] * row_axis + ["lanes"]))
    return jax.tree.map(lambda _: spec, state)


def _chunked_table_specs(tbl: TableSet, sharded: bool):
    spec = P("chunks") if sharded else P()
    return jax.tree.map(lambda _: spec, tbl)


def encode_chunked(symbols: jax.Array, tbl: TableSet, chunk_size: int,
                   mesh: Mesh | None = None,
                   cap: int | None = None,
                   backend: str = "coder",
                   interpret: bool = True) -> ChunkedLanes:
    """Device-parallel chunked encode over either encode backend.

    Full chunks are sharded over the mesh's ``chunks`` axis; per-position
    tables (leading T dim) are split chunk-major and ride on the same axis.
    ``backend="coder"`` runs the pure-JAX lane encoder (vmap over the local
    chunk slab); ``backend="kernel"`` runs the fused-compaction Pallas
    encode kernel — one ``pallas_call`` per device covering its whole local
    slab (the kernel's chunk grid axis, interpret mode on CPU) and emitting
    packed streams directly (no host-side ``compact_records`` pass).  Both
    consume ``core.update``, so the produced streams — and the per-cell
    overflow flags — are byte-identical across backends and mesh shapes.
    Falls back to the single-device path whenever the mesh cannot evenly
    take the chunk axis.
    """
    if backend == "kernel":
        from repro.kernels import ops as kops
    elif backend != "coder":
        raise ValueError(f"unknown encode backend {backend!r}")
    lanes, t_len = symbols.shape
    coder.num_chunks(t_len, chunk_size)     # validates chunk_size > 0
    n_full, tail_len = divmod(t_len, chunk_size)
    cap = coder.default_cap(min(chunk_size, t_len)) if cap is None else cap
    if not _usable(mesh, n_full):
        if backend == "kernel":
            return kops.rans_encode_chunked(symbols, tbl, chunk_size,
                                            cap=cap, interpret=interpret)
        return coder.encode_chunked(symbols, tbl, chunk_size, cap=cap)

    per_position = coder.is_per_position(tbl, t_len)
    full = symbols[:, :n_full * chunk_size]
    full = full.reshape(lanes, n_full, chunk_size).swapaxes(0, 1)

    def _slab_encode(sym_loc, tbl_loc, chunk_major: bool):
        """Encode the local (n_loc, lanes, chunk_size) chunk slab.
        ``tbl_loc`` is chunk-major ``(n_loc, chunk_size, ...)`` when
        ``chunk_major`` else a replicated static/shared TableSet."""
        if backend == "kernel":
            # one pallas_call for the whole local slab: stitch the local
            # chunks back into a (lanes, n_loc * chunk_size) stream and let
            # the fused kernel's chunk grid axis re-cut it — packed streams
            # (and per-cell overflow flags) come straight off the kernel,
            # no host-side compact_records pass
            n_loc = sym_loc.shape[0]
            flat = sym_loc.swapaxes(0, 1).reshape(lanes, n_loc * chunk_size)
            tbl_flat = (jax.tree.map(
                lambda a: a.reshape((n_loc * chunk_size,) + a.shape[2:]),
                tbl_loc) if chunk_major else tbl_loc)
            ch = kops.rans_encode_chunked(flat, tbl_flat, chunk_size,
                                          cap=cap, interpret=interpret)
            return EncodedLanes(ch.buf, ch.start, ch.length, ch.overflow)
        if chunk_major:
            return jax.vmap(lambda s, tb: coder.encode(s, tb, cap=cap))(
                sym_loc, tbl_loc)
        return jax.vmap(lambda s: coder.encode(s, tbl_loc, cap=cap))(sym_loc)

    spec = P("chunks")
    out_specs = EncodedLanes(buf=spec, start=spec, length=spec,
                             overflow=spec)
    check_rep = {"check_rep": False} if backend == "kernel" else {}
    if per_position:
        tbl_full = coder.chunk_tables(tbl, n_full, chunk_size)
        enc = shard_map(lambda s, tb: _slab_encode(s, tb, True), mesh=mesh,
                        in_specs=(spec,
                                  _chunked_table_specs(tbl, sharded=True)),
                        out_specs=out_specs, **check_rep)(full, tbl_full)
    else:
        enc = shard_map(lambda s, tb: _slab_encode(s, tb, False), mesh=mesh,
                        in_specs=(spec,
                                  _chunked_table_specs(tbl, sharded=False)),
                        out_specs=out_specs, **check_rep)(full, tbl)
    enc = ChunkedLanes(buf=enc.buf, start=enc.start, length=enc.length,
                       overflow=enc.overflow)

    if tail_len:
        tbl_tail = (coder.slice_tables(tbl, n_full * chunk_size, t_len)
                    if per_position else tbl)
        sym_tail = symbols[:, n_full * chunk_size:]
        if backend == "kernel":
            tail = kops.rans_encode(sym_tail, tbl_tail, cap=cap,
                                    interpret=interpret)
        else:
            tail = coder.encode(sym_tail, tbl_tail, cap=cap)
        enc = jax.tree.map(
            lambda a, b: jnp.concatenate([a, b[None]], axis=0), enc,
            ChunkedLanes(buf=tail.buf, start=tail.start, length=tail.length,
                         overflow=tail.overflow))
    return enc


def decode_chunked(chunks: ChunkedLanes | ContainerSlab, n_symbols: int,
                   tbl: TableSet,
                   chunk_size: int, mesh: Mesh | None = None,
                   prob_bits: int = C.PROB_BITS, use_lut: bool = False,
                   predictor=None, backend: str = "coder",
                   candidates: jax.Array | None = None,
                   interpret: bool = True):
    """Device-parallel chunked decode over either decode backend.

    ``backend="coder"`` runs the pure-JAX lane decoder (vmap per local
    chunk slab); ``backend="kernel"`` runs the Pallas decode kernel — one
    ``pallas_call`` per device covering its whole local slab (the kernel's
    chunk grid axis, interpret mode on CPU).  Both consume ``core.search``,
    so the returned (symbols (lanes, T), avg_probes) are bit-identical
    across backends and mesh shapes (chunks carry no cross-device state).
    ``predictor`` drives prediction-guided search inside every chunk.
    ``candidates`` is an optional ``(T, lanes, topk)`` model-top-k plane
    (the serve pipeline's trial symbols): full-size chunks' rows are cut
    chunk-major and sharded with the chunk slab on the same mesh axis, the
    ragged tail's rows ride the tail decode — probe accounting is
    identical to ``coder.decode_chunked(candidates=...)`` on every backend
    and mesh shape (topk == 0 disables speculation).

    ``chunks`` may also be a :class:`~repro.core.bitstream.ContainerSlab`
    (``bitstream.parse_chunked`` of a serialized container): the
    single-device kernel path then decodes ZERO-COPY straight from the
    packed payload slab (per-window DMA inside the kernel — no host- or
    device-side right-align materialization at all), while the mesh and
    coder paths rebuild the dense ``(n_chunks, lanes, cap)`` slab with one
    device-side gather (``bitstream.slab_to_chunked``) — still never the
    host copy.  Symbols and probe counts are bit-identical to passing the
    equivalent ``ChunkedLanes`` on every path.
    """
    if backend == "kernel":
        from repro.kernels import ops as kops
    elif backend != "coder":
        raise ValueError(f"unknown decode backend {backend!r}")
    slab_in = isinstance(chunks, ContainerSlab)
    n_have = chunks.offset.shape[0] if slab_in else chunks.buf.shape[0]
    n_total = coder.num_chunks(n_symbols, chunk_size)
    if n_have != n_total:
        raise ValueError(
            f"stream has {n_have} chunks but n_symbols="
            f"{n_symbols} at chunk_size={chunk_size} implies {n_total}")
    n_full, tail_len = divmod(n_symbols, chunk_size)
    if candidates is not None and candidates.shape[-1] == 0:
        candidates = None
    if candidates is not None:
        lanes = chunks.offset.shape[1] if slab_in else chunks.buf.shape[1]
        if candidates.shape[:2] != (n_symbols, lanes):
            raise ValueError(
                f"candidate planes must be (T, lanes, topk)=({n_symbols}, "
                f"{lanes}, *); got {candidates.shape}")
        candidates = candidates.astype(jnp.int32)
    if not _usable(mesh, n_full):
        if backend == "kernel":
            if slab_in:
                # zero-copy: the kernel DMAs each (chunk, lane) window out
                # of the packed slab itself — no dense stream rebuild
                return kops.rans_decode_chunked(
                    n_symbols=n_symbols, tbl=tbl, chunk_size=chunk_size,
                    prob_bits=prob_bits, predictor=predictor,
                    candidates=candidates, interpret=interpret,
                    from_container=chunks)
            return kops.rans_decode_chunked(
                chunks, n_symbols, tbl, chunk_size, prob_bits=prob_bits,
                predictor=predictor, candidates=candidates,
                interpret=interpret)
        if slab_in:
            chunks = bitstream.slab_to_chunked(chunks)
        return coder.decode_chunked(chunks, n_symbols, tbl, chunk_size,
                                    prob_bits=prob_bits, use_lut=use_lut,
                                    predictor=predictor,
                                    candidates=candidates)
    if slab_in:
        # sharded path: rebuild the dense chunk slab with one device-side
        # gather so the shard_map below sees the usual (n_chunks, lanes,
        # cap) layout (host right-align copy still never runs)
        chunks = bitstream.slab_to_chunked(chunks)

    per_position = coder.is_per_position(tbl, n_symbols)
    sub = jax.tree.map(lambda a: a[:n_full], chunks)
    n_loc = n_full // mesh.shape["chunks"]
    out_specs = (P("chunks"), P("chunks"), P("chunks"))

    def _decode_one(enc, tb, n=chunk_size, cand=None, flags=False):
        """One chunk decode.  ``flags=True`` threads the per-lane stream
        exhaustion flag out instead of raising — required inside traced
        shard_map/vmap bodies, where the host-level
        ``StreamExhaustedError`` cannot fire (checked after the mesh
        program returns)."""
        if backend == "kernel":
            return kops.rans_decode(enc, n, tb, prob_bits=prob_bits,
                                    predictor=predictor, candidates=cand,
                                    interpret=interpret,
                                    exhausted_flags=flags)
        return coder.decode(enc, n, tb, prob_bits,
                            predictor=predictor, use_lut=use_lut,
                            candidates=cand, return_exhausted=flags)

    def _slab_decode(enc_loc, tbl_loc, chunk_major: bool, cand_loc=None):
        """Decode the local (n_loc, lanes, cap) chunk slab.  ``tbl_loc`` is
        chunk-major ``(n_loc, chunk_size, ...)`` when ``chunk_major`` else a
        replicated static/shared TableSet; ``cand_loc`` is the local
        chunk-major ``(n_loc, chunk_size, lanes, topk)`` candidate slab.
        Returns ``(sym3, per_chunk_probes, under)`` with ``under`` the
        per-(chunk, lane) exhaustion flags."""
        if backend == "kernel":
            # one pallas_call for the whole local slab: the kernel's chunk
            # grid axis decodes every local chunk in a single launch (the
            # candidate rows ride the chunk grid axis with the tables)
            lanes = enc_loc.buf.shape[1]
            tbl_flat = (jax.tree.map(
                lambda a: a.reshape((n_loc * chunk_size,) + a.shape[2:]),
                tbl_loc) if chunk_major else tbl_loc)
            cand_flat = (cand_loc.reshape((n_loc * chunk_size,)
                                          + cand_loc.shape[2:])
                         if cand_loc is not None else None)
            sym, _, cpro, cund = kops.rans_decode_chunked(
                enc_loc, n_loc * chunk_size, tbl_flat, chunk_size,
                prob_bits=prob_bits, predictor=predictor,
                candidates=cand_flat, interpret=interpret,
                chunk_probes=True, exhausted_flags=True)
            sym3 = sym.reshape(lanes, n_loc, chunk_size).swapaxes(0, 1)
            per_chunk = (jnp.sum(cpro.astype(jnp.float32), axis=1)
                         / (lanes * chunk_size))
            return sym3, per_chunk, cund
        # coder path: batch the local chunk slab through one vmapped scan
        if chunk_major:
            if cand_loc is not None:
                return jax.vmap(
                    lambda e, tb, cd: _decode_one(
                        EncodedLanes(*e), TableSet(*tb), cand=cd,
                        flags=True))(enc_loc, tbl_loc, cand_loc)
            return jax.vmap(
                lambda e, tb: _decode_one(EncodedLanes(*e), TableSet(*tb),
                                          flags=True))(enc_loc, tbl_loc)
        if cand_loc is not None:
            return jax.vmap(
                lambda e, cd: _decode_one(EncodedLanes(*e), tbl_loc,
                                          cand=cd, flags=True))(
                enc_loc, cand_loc)
        return jax.vmap(
            lambda e: _decode_one(EncodedLanes(*e), tbl_loc, flags=True))(
            enc_loc)

    # the candidate rows of the full-size chunks, chunk-major, sharded on
    # the same "chunks" axis as the stream slab
    cand_full = (candidates[:n_full * chunk_size].reshape(
        (n_full, chunk_size) + candidates.shape[1:])
        if candidates is not None else None)
    extra_args, extra_specs = [], []
    if cand_full is not None:
        extra_args.append(cand_full)
        extra_specs.append(P("chunks"))

    if per_position:
        tbl_full = coder.chunk_tables(tbl, n_full, chunk_size)

        def body(enc_loc, tbl_loc, *cand):
            return _slab_decode(ChunkedLanes(*enc_loc), TableSet(*tbl_loc),
                                True, cand[0] if cand else None)

        sym_full, probes_full, under_full = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("chunks"), sub),
                      _chunked_table_specs(tbl, sharded=True),
                      *extra_specs),
            out_specs=out_specs, check_rep=False)(sub, tbl_full,
                                                  *extra_args)
    else:
        def body(enc_loc, tbl_rep, *cand):
            return _slab_decode(ChunkedLanes(*enc_loc), TableSet(*tbl_rep),
                                False, cand[0] if cand else None)

        sym_full, probes_full, under_full = shard_map(
            body, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("chunks"), sub),
                      _chunked_table_specs(tbl, sharded=False),
                      *extra_specs),
            out_specs=out_specs, check_rep=False)(sub, tbl, *extra_args)

    coder._check_exhausted(under_full, "parallel.decode_chunked")
    lanes = sym_full.shape[1]
    syms = [sym_full.swapaxes(0, 1).reshape(lanes, n_full * chunk_size)]
    probe_sums = [jnp.sum(probes_full) * chunk_size]
    if tail_len:
        tbl_tail = (coder.slice_tables(tbl, n_full * chunk_size, n_symbols)
                    if per_position else tbl)
        sym_tail, probes_tail = _decode_one(
            coder.chunk_encoded(chunks, n_full), tbl_tail, n=tail_len,
            cand=(candidates[n_full * chunk_size:]
                  if candidates is not None else None))
        syms.append(sym_tail)
        probe_sums.append(probes_tail * tail_len)
    out = jnp.concatenate(syms, axis=1)
    return out, sum(probe_sums) / n_symbols
