"""Logical-axis -> mesh-axis sharding rules (DP / TP / FSDP / EP / SP).

The production mesh is ``(data=16, model=16)`` per pod, with a leading
``pod`` axis across pods.  Rules:

  * batch           -> (pod, data)            [DP; hierarchical reduce]
  * vocab/heads/mlp/ssm_inner/ssm_state -> model   [Megatron TP]
  * kv_heads        -> model iff divisible, else replicate ("kv_heads_repl")
  * experts         -> model when n_experts % tp == 0 (EP; phi3.5),
                       else per-expert TP on mlp (mixtral)
  * embed           -> data under FSDP (ZeRO-3-style weight sharding; the
                       default — every large arch needs it for optimizer
                       state), None otherwise
  * layers (scan stacks) -> never sharded
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.param import pspec_tree


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


def logical_rules(cfg: ModelConfig, *, multi_pod: bool = False,
                  fsdp: bool = True) -> dict:
    rules = {
        "batch": dp_axes(multi_pod),
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "kv_heads_repl": None,
        "embed": "data" if fsdp else None,
        "mlp": "model",
        "experts": None,
        "ssm_inner": "model",
        "ssm_state": "model",
        "layers": None,
    }
    if cfg.n_experts and cfg.n_experts % cfg.tp == 0:
        rules["experts"] = "model"   # true EP (phi3.5: E == tp)
        rules["mlp"] = None          # expert-internal ff replicated over model
    return rules


def param_shardings(cfg: ModelConfig, mesh: Mesh, defs_tree,
                    *, fsdp: bool = True):
    multi_pod = "pod" in mesh.axis_names
    specs = pspec_tree(defs_tree, logical_rules(cfg, multi_pod=multi_pod,
                                                fsdp=fsdp))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def batch_pspec(mesh: Mesh, global_batch: int, ndim: int = 2) -> P:
    """Shard dim0 (batch) over as many DP axes as divide it; rest replicated.

    long_500k has global_batch=1 -> fully replicated (single-stream decode
    does not data-parallelize; noted in EXPERIMENTS.md).
    """
    axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    use = []
    prod = 1
    for a in axes:
        n = mesh.shape[a]
        if global_batch % (prod * n) == 0:
            use.append(a)
            prod *= n
    spec = tuple(use) if use else None
    return P(spec, *([None] * (ndim - 1)))


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_tree,
                    global_batch: int):
    """KV/SSM cache shardings: batch over DP axes; kv-head dim over model
    when sharded; mamba2 ssm state dims replicate over model."""
    bspec = batch_pspec(mesh, global_batch, ndim=1)
    b_axes = bspec[0]

    def spec_for(leaf):
        dims = [None] * leaf.ndim
        dims[1] = b_axes  # leading dim is the scanned layer stack
        if (leaf.ndim == 5 and cfg.n_kv_heads and
                leaf.shape[3] == cfg.n_kv_heads and cfg.kv_sharded):
            dims[3] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree.map(spec_for, cache_tree)


def count_collective_free(mesh: Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))
