"""Distributed-optimization collectives.

``compressed_psum_tree`` — int8 error-feedback gradient compression for the
cross-pod hop: each pod quantizes its gradient shard to int8 with a per-
tensor scale, psums the int8 payload over the ``pod`` axis, dequantizes, and
keeps the quantization residual locally (error feedback) so the bias cancels
over steps.  This cuts the *slowest* link's bytes ~4x vs f32 (2x vs bf16) and
is wired into ``train/train_loop.make_train_step(..., compress_crosspod=
True)`` via shard_map over the pod axis.

``hierarchical_psum`` — reduce-scatter within the pod then all-reduce across
pods; XLA SPMD already emits this shape for the plain path, the explicit
version exists for the shard_map path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array):
    """Symmetric per-tensor int8 quantization (scale in f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, axis_name: str, error: jax.Array,
                    axis_size: int = 1):
    """int8 error-feedback mean-reduce over ``axis_name``.

    The payload crosses the wire as **raw int8** (a ring of ``axis_size-1``
    ppermute hops — a plain psum would upcast to >=32-bit on the wire, which
    is what XLA emitted for ``psum(int8.astype(int32))``).  Per-tensor f32
    scales ride along (negligible).  Returns (mean f32 tensor, new local
    error residual); the quantization residual stays local and cancels over
    steps (error feedback).
    """
    xf = x.astype(jnp.float32) + error
    q, scale = quantize_int8(xf)
    new_error = xf - dequantize_int8(q, scale)

    acc = q.astype(jnp.int32)
    scale_sum = scale
    buf, sbuf = q, scale
    perm = [(i, (i + 1) % axis_size) for i in range(axis_size)]
    for _ in range(max(axis_size - 1, 0)):
        buf = jax.lax.ppermute(buf, axis_name, perm)     # int8 on the wire
        sbuf = jax.lax.ppermute(sbuf, axis_name, perm)
        acc = acc + buf.astype(jnp.int32)
        scale_sum = scale_sum + sbuf
    n = float(max(axis_size, 1))
    # each shard used its own scale; the shared-mean-scale approximation's
    # residual also lands in the error feedback next step.
    out = acc.astype(jnp.float32) * (scale_sum / n) / n
    return out, new_error


def compressed_psum_tree(tree, axis_name: str, error_tree,
                         axis_size: int = 1):
    flat, treedef = jax.tree.flatten(tree)
    err_flat = jax.tree.leaves(error_tree)
    outs, errs = [], []
    for x, e in zip(flat, err_flat):
        o, ne = compressed_psum(x, axis_name, e, axis_size)
        outs.append(o.astype(x.dtype))
        errs.append(ne)
    return (jax.tree.unflatten(treedef, outs),
            jax.tree.unflatten(treedef, errs))


def init_error_tree(grads_tree):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_tree)


def hierarchical_psum(x: jax.Array, inner: str = "data", outer: str = "pod"):
    """reduce within pod, then across pods (explicit two-level reduce)."""
    x = jax.lax.psum(x, inner)
    return jax.lax.psum(x, outer)
