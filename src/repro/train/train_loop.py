"""Training step factory: grad accumulation, clipping, AdamW, mixed
precision, and the optional cross-pod compressed gradient reduce.

``make_train_step(cfg)`` returns a pure ``(state, batch) -> (state, metrics)``
function suitable for pjit (the dry-run lowers exactly this).  Gradient
accumulation runs as a ``lax.scan`` over microbatches — besides fitting
memory this overlaps each microbatch's backward collectives with the next
microbatch's compute (XLA pipelines the scan body).

``compress_crosspod=True`` wraps the step in shard_map over the ``pod`` axis
(data/model stay auto-sharded): per-pod gradients are int8-quantized with
error feedback and psum'd across pods — the distributed-optimization trick
for the slowest link (see parallel/collectives.py).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig
from repro.models.transformer import loss_fn
from repro.parallel.collectives import compressed_psum_tree, init_error_tree
from repro.train.optimizer import (AdamWState, adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_lr)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jax.Array
    error: dict | None = None     # compression error-feedback residuals


def init_train_state(params, moment_dtype=jnp.float32,
                     with_error: bool = False) -> TrainState:
    return TrainState(params=params,
                      opt=adamw_init(params, moment_dtype),
                      step=jnp.zeros((), jnp.int32),
                      error=init_error_tree(params) if with_error else None)


def _split_micro(batch: dict, n: int) -> dict:
    def f(x):
        b = x.shape[0]
        assert b % n == 0, (b, n)
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(f, batch)


def grads_fn(params, batch: dict, cfg: ModelConfig):
    """loss + grads with microbatch accumulation (mean over microbatches)."""
    if cfg.grad_accum <= 1:
        return jax.value_and_grad(loss_fn)(params, batch, cfg)
    micro = _split_micro(batch, cfg.grad_accum)

    def body(carry, mb):
        acc, total = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb, cfg)
        acc = jax.tree.map(jnp.add, acc, g)
        return (acc, total + loss), None

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, ltot), _ = jax.lax.scan(body, (zeros, jnp.float32(0.0)), micro)
    scale = 1.0 / cfg.grad_accum
    gdt = jnp.dtype(cfg.grad_dtype)   # bf16 grads: the 405b HBM lever
    grads = jax.tree.map(lambda g: (g * scale).astype(gdt), gsum)
    return ltot * scale, grads


def make_train_step(cfg: ModelConfig, *, base_lr: float = 3e-4,
                    max_grad_norm: float = 1.0,
                    compress_crosspod: bool = False, mesh=None):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def plain_step(state: TrainState, batch: dict):
        loss, grads = grads_fn(state.params, batch, cfg)
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_lr(state.step, base_lr=base_lr)
        new_params, new_opt = adamw_update(grads, state.opt, state.params, lr)
        new_state = TrainState(new_params, new_opt, state.step + 1,
                               state.error)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    if not compress_crosspod:
        return plain_step

    assert mesh is not None and "pod" in mesh.axis_names, (
        "compress_crosspod requires the multi-pod mesh")

    # inside the pod-Manual region the activation constraint may only name
    # Auto axes (data/model) — drop "pod" from any act_pspec tuples.
    if cfg.act_pspec is not None:
        inner_pspec = tuple(
            tuple(a for a in ax if a != "pod") if isinstance(ax, tuple)
            else (None if ax == "pod" else ax) for ax in cfg.act_pspec)
        inner_cfg = cfg.with_(act_pspec=inner_pspec)
    else:
        inner_cfg = cfg

    def pod_step(state: TrainState, batch: dict):
        # gradients here are per-pod partial means (batch dim0 is the pod
        # shard); reduce across pods with int8 error feedback.
        loss, grads = grads_fn(state.params, batch, inner_cfg)
        grads, error = compressed_psum_tree(grads, "pod", state.error,
                                    mesh.shape["pod"])
        loss = jax.lax.pmean(loss, "pod")
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        lr = cosine_lr(state.step, base_lr=base_lr)
        new_params, new_opt = adamw_update(grads, state.opt, state.params, lr)
        new_state = TrainState(new_params, new_opt, state.step + 1, error)
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    # shard_map over the pod axis only (axis_names={"pod"}); data/model stay
    # under the automatic partitioner so the inner model code is unchanged.
    def spec_tree(tree, leading_pod: bool):
        def f(x):
            dims = [None] * x.ndim
            if leading_pod and x.ndim:
                dims[0] = "pod"
            return P(*dims)
        return jax.tree.map(f, tree)

    def wrapped(state: TrainState, batch: dict):
        in_specs = (spec_tree(state, False), spec_tree(batch, True))
        out_specs = (spec_tree(state, False),
                     {"loss": P(), "grad_norm": P(), "lr": P()})
        fn = jax.shard_map(pod_step, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs,
                           axis_names=frozenset({"pod"}),
                           check_vma=False)
        return fn(state, batch)

    return wrapped
