"""Sharded checkpointing: step-addressed npz shards + json manifest.

Design for multi-host (each host writes its addressable shards; manifests
are atomic-renamed so a crash never leaves a half checkpoint visible), and
**elastic restore**: a checkpoint saved under one mesh can be restored onto
a different mesh — arrays are re-sharded on load via device_put with the new
shardings (the fault-tolerance path for shrinking/growing the cluster).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}{k}/")
    elif isinstance(tree, (tuple, list)) or hasattr(tree, "_fields"):
        items = tree._asdict().items() if hasattr(tree, "_asdict") else \
            enumerate(tree)
        for k, v in items:
            yield from _flatten(v, f"{prefix}{k}/")
    elif tree is None:
        return
    else:
        yield prefix[:-1], tree


def save(ckpt_dir: str, step: int, tree, *, host_id: int = 0,
         blocking: bool = True) -> str:
    """Write <ckpt_dir>/step_<n>/ with shard files + manifest."""
    out = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = out + f".tmp{host_id}"
    os.makedirs(tmp, exist_ok=True)

    flat = dict(_flatten(tree))
    arrays = {k.replace("/", "."): np.asarray(v) for k, v in flat.items()}

    def write():
        np.savez(os.path.join(tmp, f"host{host_id}.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "keys": sorted(arrays),
            "hosts": 1,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.isdir(out):
            shutil.rmtree(out)
        os.replace(tmp, out)      # atomic publish

    if blocking:
        write()
    else:
        threading.Thread(target=write, daemon=True).start()
    return out


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp0"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, shardings=None):
    """Load into the structure of ``like_tree``; optionally device_put with
    new shardings (elastic re-mesh)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "host0.npz")
    data = np.load(path)

    def build(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: build(tree[k], f"{prefix}{k}/") for k in sorted(tree)}
        if hasattr(tree, "_fields"):
            vals = {k: build(v, f"{prefix}{k}/")
                    for k, v in tree._asdict().items()}
            return type(tree)(**vals)
        if isinstance(tree, (tuple, list)):
            return type(tree)(build(v, f"{prefix}{i}/")
                              for i, v in enumerate(tree))
        if tree is None:
            return None
        key = prefix[:-1].replace("/", ".")
        arr = data[key]
        return arr

    host_tree = build(like_tree)
    if shardings is None:
        return jax.tree.map(jax.numpy.asarray, host_tree)
    return jax.tree.map(
        lambda a, s: jax.device_put(a, s), host_tree, shardings)
