"""Pure-JAX optimizers: AdamW (+ bf16-moment variant) with global-norm clip.

No optax in this container, so the optimizer substrate is built here.  The
``moment_dtype`` knob is the llama3-405b memory lever: bf16 first/second
moments halve optimizer HBM at negligible quality cost (stochastic-rounding
notes in DESIGN.md §5).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32)
                                   * scale).astype(g.dtype), tree), norm


def adamw_update(grads, state: AdamWState, params,
                 lr: jax.Array | float,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        upd = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
        upd = upd + weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * upd
        return new_p.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


def cosine_lr(step, *, base_lr: float = 3e-4, warmup: int = 100,
              total: int = 10_000, min_ratio: float = 0.1):
    t = step.astype(jnp.float32)
    warm = t / max(warmup, 1)
    frac = jnp.clip((t - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return base_lr * jnp.where(t < warmup, warm, cos)
