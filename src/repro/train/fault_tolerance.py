"""Fault tolerance: restart manager, straggler monitor, elastic re-mesh.

The contract at 1000+ nodes: any step may die (preemption, link flap,
device loss).  The framework's answer:

  * **checkpoint/restart** — ``RestartManager.run`` executes the step loop,
    snapshots every ``save_every`` steps (atomic publish), and on any
    exception reloads the newest complete checkpoint and resumes; bounded
    retry budget so a deterministic crash cannot loop forever;
  * **straggler mitigation** — per-step wall-time EMA; steps slower than
    ``straggler_factor`` x EMA are logged and counted (on real fleets this
    feeds the scheduler that drains the slow host; here the hook also lets
    tests inject delays and assert detection);
  * **elastic re-mesh** — ``remesh`` re-shards a full checkpoint onto a new
    (smaller or larger) mesh via device_put; tested by moving a train state
    between differently-shaped CPU meshes.
"""

from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field

import jax

from repro.train import checkpoint

log = logging.getLogger("repro.ft")


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    ema: float | None = None
    alpha: float = 0.2
    slow_steps: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ema is not None and dt > self.factor * self.ema
        if slow:
            self.slow_steps.append((step, dt, self.ema))
            log.warning("straggler: step %d took %.3fs (ema %.3fs)",
                        step, dt, self.ema)
        self.ema = dt if self.ema is None else \
            (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


@dataclass
class RestartManager:
    ckpt_dir: str
    save_every: int = 50
    max_failures: int = 3
    monitor: StragglerMonitor = field(default_factory=StragglerMonitor)
    failures: int = 0

    def run(self, state, step_fn, batch_fn, n_steps: int,
            fault_hook=None):
        """Run ``n_steps`` of ``state = step_fn(state, batch_fn(i))`` with
        checkpoint/restart.  ``fault_hook(i)`` may raise to simulate node
        loss (tests use this)."""
        start = int(state.step)
        i = start
        while i < n_steps:
            try:
                t0 = time.monotonic()
                if fault_hook is not None:
                    fault_hook(i)
                state, metrics = step_fn(state, batch_fn(i))
                jax.block_until_ready(metrics["loss"])
                self.monitor.observe(i, time.monotonic() - t0)
                i += 1
                if i % self.save_every == 0 or i == n_steps:
                    checkpoint.save(self.ckpt_dir, i, state)
            except Exception as e:  # noqa: BLE001 — any fault is restartable
                self.failures += 1
                log.warning("step %d failed (%s); restart %d/%d",
                            i, e, self.failures, self.max_failures)
                if self.failures > self.max_failures:
                    raise
                last = checkpoint.latest_step(self.ckpt_dir)
                if last is None:
                    i = start   # nothing saved yet: replay from the top
                    continue
                state = checkpoint.restore(self.ckpt_dir, last, state)
                i = last
        return state


def remesh(state, old_dir: str, step: int, new_shardings):
    """Elastic scaling: restore checkpoint ``step`` re-sharded for a new
    mesh (survivor set after failures, or a grown slice)."""
    return checkpoint.restore(old_dir, step, state, shardings=new_shardings)
