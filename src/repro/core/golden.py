"""Scalar numpy/python golden rANS — the definitional reference implementation.

This is the "software pipeline" whose bitstream the accelerator must
reproduce *bit-exactly* (paper Sec. V-B: "RAS reproduces the exact bitstreams
of the reference implementation").  It uses plain Python integers, the
textbook while-loop renormalization and direct // and % — no tricks — so it
serves as the oracle for:

  * the vectorized JAX multi-lane coder (core/coder.py),
  * the Pallas kernels (kernels/ref.py validates against this),
  * the seeded property sweeps in tests/ (tests/_prop.py).

Encode follows Eq. (1):  s' = floor(s/f) * 2**n + (s mod f) + C(x),
processing symbols in *reverse* (rANS is LIFO) and emitting renorm bytes
backward so the decoder reads forward.
"""

from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core import constants as C


def encode(symbols: Sequence[int],
           freq: np.ndarray,
           cdf: np.ndarray,
           prob_bits: int = C.PROB_BITS) -> bytes:
    """Encode one lane of symbols.  Returns the forward-readable stream."""
    C.check_prob_bits(prob_bits)
    scale = C.x_max_scale(prob_bits)
    freq = np.asarray(freq)
    cdf = np.asarray(cdf)
    s = C.RANS_L
    rev: list[int] = []  # bytes in emission order (reverse of read order)
    for x in reversed(list(symbols)):
        f = int(freq[x])
        c = int(cdf[x])
        assert f >= 1, "zero frequency symbol is unencodable"
        x_max = scale * f
        while s >= x_max:
            rev.append(s & C.BYTE_MASK)
            s >>= C.RENORM_SHIFT
        s = ((s // f) << prob_bits) + (s % f) + c  # Eq. (1)
        assert C.RANS_L <= s < C.STATE_UPPER, s
    # 4-byte big-endian state header, read first by the decoder.
    head = [(s >> 24) & 0xFF, (s >> 16) & 0xFF, (s >> 8) & 0xFF, s & 0xFF]
    return bytes(head + rev[::-1])


def decode(stream: bytes,
           n_symbols: int,
           freq: np.ndarray,
           cdf: np.ndarray,
           prob_bits: int = C.PROB_BITS) -> np.ndarray:
    """Decode ``n_symbols`` from a forward stream.  Inverse of :func:`encode`."""
    C.check_prob_bits(prob_bits)
    mask = (1 << prob_bits) - 1
    freq = np.asarray(freq)
    cdf = np.asarray(cdf)
    k = len(freq)
    s = int.from_bytes(stream[:4], "big")
    ptr = 4
    out = np.empty(n_symbols, np.int64)
    for t in range(n_symbols):
        slot = s & mask
        # textbook binary search: find x with cdf[x] <= slot < cdf[x+1]
        lo, hi = 0, k
        while hi - lo > 1:
            mid = (lo + hi) >> 1
            if int(cdf[mid]) <= slot:
                lo = mid
            else:
                hi = mid
        x = lo
        out[t] = x
        s = int(freq[x]) * (s >> prob_bits) + slot - int(cdf[x])
        while s < C.RANS_L:
            s = (s << C.RENORM_SHIFT) | stream[ptr]
            ptr += 1
    return out


def encode_per_position(symbols: Sequence[int],
                        freq: np.ndarray,   # (T, K)
                        cdf: np.ndarray,    # (T, K+1)
                        prob_bits: int = C.PROB_BITS) -> bytes:
    """Adaptive variant: position t uses its own table row (neural priors)."""
    C.check_prob_bits(prob_bits)
    scale = C.x_max_scale(prob_bits)
    s = C.RANS_L
    rev: list[int] = []
    for t in range(len(symbols) - 1, -1, -1):
        x = int(symbols[t])
        f = int(freq[t, x])
        c = int(cdf[t, x])
        x_max = scale * f
        while s >= x_max:
            rev.append(s & C.BYTE_MASK)
            s >>= C.RENORM_SHIFT
        s = ((s // f) << prob_bits) + (s % f) + c
    head = [(s >> 24) & 0xFF, (s >> 16) & 0xFF, (s >> 8) & 0xFF, s & 0xFF]
    return bytes(head + rev[::-1])


def decode_per_position(stream: bytes,
                        freq: np.ndarray,   # (T, K)
                        cdf: np.ndarray,    # (T, K+1)
                        prob_bits: int = C.PROB_BITS) -> np.ndarray:
    C.check_prob_bits(prob_bits)
    mask = (1 << prob_bits) - 1
    n_symbols, k = freq.shape
    s = int.from_bytes(stream[:4], "big")
    ptr = 4
    out = np.empty(n_symbols, np.int64)
    for t in range(n_symbols):
        slot = s & mask
        lo, hi = 0, k
        while hi - lo > 1:
            mid = (lo + hi) >> 1
            if int(cdf[t, mid]) <= slot:
                lo = mid
            else:
                hi = mid
        x = lo
        out[t] = x
        s = int(freq[t, x]) * (s >> prob_bits) + slot - int(cdf[t, x])
        while s < C.RANS_L:
            s = (s << C.RENORM_SHIFT) | stream[ptr]
            ptr += 1
    return out
