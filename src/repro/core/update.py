"""Shared two-stage rANS encode-update core (paper Sec. IV-A/B).

Single source of truth for the encoder's hot loop.  Every encode backend in
the repo — ``core.coder.encode_put`` (pure-JAX lanes, scatter emission),
``core.coder.encode_records`` (scan-stacked renorm records), and
``kernels.rans_encode`` (Pallas TPU kernel) — imports *this* module, so the
produced byte streams are structurally identical across backends rather than
merely tested equal.  This is the encoder mirror of :mod:`repro.core.search`
(the decode-side single source).  See DESIGN.md §6.

Paper map:

  * **Sec. IV-B two-stage update** — :func:`encode_step` stage B: the
    quotient path ``a1 = (s // f) << n`` and the remainder path
    ``a2 = (s mod f) + C(x)`` are independent vector ops.  We use the
    algebraically identical ryg form ``s + bias + q * cmpl`` (``bias`` folds
    ``C(x)`` and the f==1 corner, ``cmpl = 2**n - f``) so the hot loop is
    one mulhi, one shift, one madd — proof sketch in DESIGN.md §2.
  * **Sec. IV-A unified div/mod datapath** — :func:`barrett_div`: division
    is a Barrett multiply-high against the SPC-precomputed reciprocal,
    exact for every state < 2**31 (DESIGN.md §2), no integer divide on the
    hot path.  :func:`umulhi32` is the TPU-native 32x32 -> high-32 multiply
    from 16-bit limbs (carry proof in DESIGN.md §4).
  * **byte-level renormalization** — :func:`encode_step` stage A: the
    data-dependent while-loop is a fixed ``MAX_RENORM_STEPS``(=2)-step
    masked pipeline (bound proved in DESIGN.md §4).  Instead of writing
    bytes itself, the core *emits fixed-shape renorm records* — a
    ``(byte, emitted?)`` pair per step — and the caller decides how to land
    them: the lane coder scatters them backward into its per-lane buffers,
    ``encode_records`` stacks them as scan outputs, and the Pallas kernel
    writes them to VMEM record planes.  One emission rule, three sinks;
    compaction (records -> right-aligned streams) is
    :func:`repro.core.bitstream.compact_records` and is shared too.

Like the search core, the update core is parameterized over the gather
primitive because the backends address tables differently: the XLA path
uses :func:`repro.core.search.take_gather` (``take_along_axis``,
batch-aware) while the Pallas kernel substitutes one-hot contractions
(``kernels.common.onehot_gather`` / ``onehot_gather_lanes``).  The update
*logic* is identical either way.

All masks are numpy scalars (not jnp arrays) so Pallas kernels see integer
literals rather than captured device constants.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core.search import take_gather

_U32 = jnp.uint32
_U8 = jnp.uint8
_M16 = np.uint32(0xFFFF)
_M8 = np.uint32(0xFF)


def umulhi32(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact high 32 bits of a 32x32 unsigned product, in pure uint32 ops.

    TPU VPUs have no 64-bit integer path; the RTL has a real divider.  This
    limb decomposition is the TPU-native replacement: all partial products
    fit uint32 and every carry is accounted (proof in DESIGN.md §4).
    """
    a = a.astype(_U32)
    b = b.astype(_U32)
    al, ah = a & _M16, a >> 16
    bl, bh = b & _M16, b >> 16
    ll = al * bl
    lh = al * bh
    hl = ah * bl
    hh = ah * bh
    mid = (ll >> 16) + (lh & _M16) + (hl & _M16)
    return hh + (lh >> 16) + (hl >> 16) + (mid >> 16)


def barrett_div(s: jax.Array, rcp: jax.Array, rshift: jax.Array) -> jax.Array:
    """floor(s / f) via the SPC reciprocal; exact for s < 2**31, f >= 2
    (DESIGN.md §2)."""
    return umulhi32(s, rcp) >> rshift


class EncTables(NamedTuple):
    """The five encoder-side table planes of a TableSet (``C(x)`` is folded
    into ``bias``, so the encoder never touches freq/cdf directly).  Any
    object exposing these attributes works — a full
    :class:`repro.core.spc.TableSet` on the XLA path, or the VMEM-resident
    block rows inside the Pallas kernel."""

    rcp: jax.Array      # (..., K) Barrett reciprocal
    rshift: jax.Array   # (..., K) post-mulhi shift
    bias: jax.Array     # (..., K) additive bias (folds C(x) + f==1 case)
    cmpl: jax.Array     # (..., K) 2**n - f
    x_max: jax.Array    # (..., K) renorm threshold = x_max_scale * f


def encode_planes(tbl) -> EncTables:
    """Project a TableSet(-like) down to the encoder's five planes."""
    return EncTables(rcp=tbl.rcp, rshift=tbl.rshift, bias=tbl.bias,
                     cmpl=tbl.cmpl, x_max=tbl.x_max)


class EncEntry(NamedTuple):
    """Per-lane gathered table entries for one symbol vector."""

    rcp: jax.Array
    rshift: jax.Array
    bias: jax.Array
    cmpl: jax.Array
    x_max: jax.Array


def gather_encode_entry(tbl, x: jax.Array, gather=take_gather) -> EncEntry:
    """Gather the encode-side entries for symbols ``x`` (one per lane).

    ``tbl`` is anything exposing the :class:`EncTables` planes; ``gather``
    is the backend's table-addressing primitive (``take_gather`` on XLA,
    one-hot contraction in-kernel), exactly as in ``core.search``.
    """
    return EncEntry(rcp=gather(tbl.rcp, x),
                    rshift=gather(tbl.rshift, x),
                    bias=gather(tbl.bias, x),
                    cmpl=gather(tbl.cmpl, x),
                    x_max=gather(tbl.x_max, x))


def encode_step(s: jax.Array, e: EncEntry):
    """Push one symbol per lane: staged renorm + two-path update (Eq. 1).

    Returns ``(s', records)`` where ``records`` is a length-
    ``MAX_RENORM_STEPS`` tuple of ``(byte uint8, emitted bool)`` pairs in
    emission order.  The caller owns landing the records (backward scatter,
    scan stacking, or VMEM record planes) — see the module docstring.

    Stage A (byte renorm): the data-dependent ``while s >= x_max`` loop is
    a fixed 2-step masked pipeline — sufficient for every
    ``PROB_BITS in [8, 16]`` (DESIGN.md §4).  Stage B (two-path update):
    ``a1 = (s // f) << n`` (Barrett quotient path) and
    ``a2 = (s mod f) + C(x)`` (remainder + CDF path), fused into
    ``s + bias + q * cmpl`` — identical integer result, f==1 corner
    included (DESIGN.md §2).
    """
    records = []
    for _ in range(C.MAX_RENORM_STEPS):
        cond = s >= e.x_max
        records.append(((s & _M8).astype(_U8), cond))
        s = jnp.where(cond, s >> C.RENORM_SHIFT, s)
    q = barrett_div(s, e.rcp, e.rshift)
    s = s + e.bias + q * e.cmpl
    return s, tuple(records)
