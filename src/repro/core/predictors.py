"""Decoder-side speculation predictors (paper Sec. IV-C).

A predictor proposes where in the alphabet the next symbol probably lives so
the decoder can run a *window-gated* CDF search instead of a full binary
search.  The paper's contract, which we keep exactly:

  * the predictor emits an anchor ``mu`` and tolerance ``delta`` defining the
    bracket [mu - delta, mu + delta];
  * the decoder verifies the bracket against the CDF and falls back to the
    full search on a miss — **bit-exactness is never at risk**, only the
    number of CDF probes changes;
  * "more expressive fixed-point predictors can be plugged in without
    changing the interface".

Two families are provided:

  * :class:`NeighborAverage` — the paper's hardware-cheap image predictor
    (Fig. 3: window = [avg-8, avg+8], dichotomous refinement), with
    last-value / zero fallback, expressed over a running context of the
    previously *decoded* symbols (available identically in HW and here).
  * :class:`ModelTopK` — beyond-paper: when the probability generator is an
    LM, its own distribution already ranks candidates; speculate on the
    top-k token ids (each verified with a single O(1) CDF probe — the
    "trial symbol" path of Fig. 2 — before the windowed/binary fallback).

All predictors are pure functions over uint32/int32 arrays so they live
inside ``lax.scan`` decode loops.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

_I32 = jnp.int32


class Prediction(NamedTuple):
    mu: jax.Array        # (lanes,) int32 anchor symbol
    delta: jax.Array     # scalar or (lanes,) int32 half-window
    candidates: jax.Array | None = None  # (lanes, k) int32 trial symbols or None


def _static_config(cls):
    """Make a NamedTuple config hash/compare by *type* as well as fields.

    Predictor configs ride jit/trace caches as static arguments, and those
    caches key on ``__eq__``/``__hash__``.  Plain NamedTuples compare as bare
    tuples, so ``LastValue(delta=8) == ZeroPredictor(delta=8)`` — and a
    decode traced with one silently reuses the program traced for the other
    (same symbols, wrong probe accounting).  Tagging the key with the class
    keeps every config family a distinct cache entry.
    """

    def __eq__(self, other):
        return type(other) is type(self) and tuple(self) == tuple(other)

    cls.__eq__ = __eq__
    cls.__ne__ = lambda self, other: not __eq__(self, other)
    cls.__hash__ = lambda self: hash((cls.__qualname__,) + tuple(self))
    return cls


@_static_config
class NeighborAverage(NamedTuple):
    """Running-mean-of-last-``window`` predictor with last-value/zero fallback.

    Matches the paper's Fig. 3 mechanism for raster-scan image symbols: the
    anchor is the average of the most recent neighbourhood; ``delta`` is the
    static tolerance (paper uses 8).
    """

    window: int = 4
    delta: int = 8

    def init(self, lanes: int) -> jax.Array:
        # context: last `window` decoded symbols per lane; -1 = empty slot.
        return jnp.full((lanes, self.window), -1, _I32)

    def predict(self, ctx: jax.Array) -> Prediction:
        valid = ctx >= 0
        n_valid = jnp.sum(valid, axis=-1)
        ssum = jnp.sum(jnp.where(valid, ctx, 0), axis=-1)
        # average of valid neighbours; last-value when only one; zero when none
        mu = jnp.where(n_valid > 0, ssum // jnp.maximum(n_valid, 1), 0)
        return Prediction(mu=mu.astype(_I32), delta=jnp.int32(self.delta))

    def update(self, ctx: jax.Array, decoded: jax.Array) -> jax.Array:
        return jnp.concatenate(
            [ctx[:, 1:], decoded.astype(_I32)[:, None]], axis=1)


@_static_config
class LastValue(NamedTuple):
    """Degenerate neighbour predictor: anchor = previous symbol."""

    delta: int = 8

    def init(self, lanes: int) -> jax.Array:
        return jnp.zeros((lanes, 1), _I32)

    def predict(self, ctx: jax.Array) -> Prediction:
        return Prediction(mu=ctx[:, 0], delta=jnp.int32(self.delta))

    def update(self, ctx: jax.Array, decoded: jax.Array) -> jax.Array:
        return decoded.astype(_I32)[:, None]


@_static_config
class ZeroPredictor(NamedTuple):
    """Anchor 0 — the paper's "zero fallback"; useful for residual streams."""

    delta: int = 8

    def init(self, lanes: int) -> jax.Array:
        return jnp.zeros((lanes, 0), _I32)

    def predict(self, ctx: jax.Array) -> Prediction:
        lanes = ctx.shape[0]
        return Prediction(mu=jnp.zeros((lanes,), _I32),
                          delta=jnp.int32(self.delta))

    def update(self, ctx: jax.Array, decoded: jax.Array) -> jax.Array:
        return ctx


def model_topk_candidates(logits: jax.Array, k: int) -> jax.Array:
    """(lanes, V) logits -> (lanes, k) trial symbols for candidate speculation.

    The LM-compression analogue of the paper's trial-symbol path: the model's
    own top-k tokens are verified against the CDF with O(1) probes each.
    """
    _, idx = jax.lax.top_k(logits, k)
    return idx.astype(_I32)
