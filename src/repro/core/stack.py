"""Craystack-style push/pop stack interface over the multi-lane rANS coder.

The lane coder (:mod:`repro.core.coder`) is a *batch* codec: encode a whole
``(lanes, T)`` block, flush, decode it back.  Latent-variable compression
(bits-back / Bit-Swap, BB-ANS) needs the coder as a **stack**: interleaved
pushes and pops against one live state, where a *pop against the posterior*
recovers bits a *push against the prior* later pays back (the bits-back
identity).  This module is that stack:

  * :class:`StackState` — the live coder state: per-lane rANS states, the
    shared backward byte buffer, per-lane cursors and the per-lane
    ``underflow`` flag (a pop that reads past the stream end injects 0 and
    flags, exactly like :class:`repro.core.coder.DecState` — DESIGN.md §12);
  * **push/pop are inverses by construction**: push lands the single-source
    :func:`repro.core.update.encode_step` records backward, pop runs the
    single-source :func:`repro.core.search.find_symbol` inversion + the
    decoder's guarded forward refill.  Pop-then-push (and push-then-pop)
    restore the state bit-exactly because both directions share the same
    integer cores as the batch coder and the Pallas kernels;
  * **codecs** are ``(push, pop)`` pairs over symbol ↦ ``(start, freq)``
    statfuns in the fixed-point domain: :func:`NonUniform` (craystack's
    primitive), :func:`Uniform`, :func:`Categorical` /
    :func:`from_tableset` (tables from :mod:`repro.core.spc`, with a
    ``backend="kernel"`` pop through ``kernels.rans_decode_step``),
    :func:`DiagGaussian` and :func:`DiscretizedLogistic` (the observation
    codecs of the bits-back VAE), composed with :func:`serial` and
    :func:`substack`;
  * **initial bits** are explicit: :func:`stack_init` starts empty (a pop
    immediately *flags* — stream exhaustion is detectable, never silent),
    :func:`stack_init_bits` seeds the stack with random initial bits so
    posterior pops have entropy to draw from (the BB-ANS initial-bits
    protocol).

Every push gathers its ``(start, freq)`` pair and runs it through
:func:`repro.core.spc.barrett_planes` — the *same* single source
:func:`repro.core.spc.build_tables` maps over whole alphabets — so statfun
codecs and TableSet codecs are bit-identical by construction, not by test.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import search, spc, update
from repro.core.bitstream import EncodedLanes
from repro.core.coder import (StreamExhaustedError, _check_exhausted,  # noqa: F401
                              _emit_backward, _read_byte)
from repro.core.search import take_gather as _gather

_U32 = jnp.uint32
_U8 = jnp.uint8
_I32 = jnp.int32


class StackState(NamedTuple):
    """Live stack state: bytes in ``buf[lane, ptr[lane]:]`` are the stream
    (pushed backward, popped forward — rANS is LIFO, so the byte at
    ``ptr`` is always the most recently pushed unconsumed byte)."""

    s: jax.Array          # (lanes,) uint32 rANS states
    buf: jax.Array        # (lanes, cap) uint8 backward byte stack
    ptr: jax.Array        # (lanes,) int32: next pop reads buf[lane, ptr]
    underflow: jax.Array  # (lanes,) bool: a pop read past the stream end


class Codec(NamedTuple):
    """A craystack codec: ``push(state, symbol) -> state`` and
    ``pop(state) -> (state, symbol)`` — exact inverses of each other."""

    push: Callable[[StackState, Any], StackState]
    pop: Callable[[StackState], tuple[StackState, Any]]


# ---------------------------------------------------------------------------
# stack lifecycle: init / initial bits / flush / open
# ---------------------------------------------------------------------------

def stack_init(lanes: int, cap: int) -> StackState:
    """Empty stack at the rANS normalization floor.

    A pop from this state has no entropy to draw on: the refill reads past
    the (empty) stream and raises the lane's ``underflow`` flag — exhaustion
    is *detectable* (satellite bugfix semantics), unlike the pre-fix coder
    which silently re-read its last byte.
    """
    return StackState(s=jnp.full((lanes,), C.RANS_L, _U32),
                      buf=jnp.zeros((lanes, cap), _U8),
                      ptr=jnp.full((lanes,), cap, _I32),
                      underflow=jnp.zeros((lanes,), bool))


def stack_init_bits(lanes: int, cap: int, n_bytes: int = 64,
                    seed: int = 0) -> StackState:
    """Stack seeded with ``n_bytes`` random initial bytes per lane plus a
    random in-range state — the BB-ANS "initial bits" a bits-back pop
    consumes and the matching decode-side push provably restores.

    The state is drawn from ``[RANS_L, 2**31)`` (any valid mid-stream rANS
    state); the bytes are uniform.  Deterministic in ``seed``.
    """
    if n_bytes > cap:
        raise ValueError(f"n_bytes={n_bytes} exceeds stack cap={cap}")
    rng = np.random.default_rng(seed)
    buf = np.zeros((lanes, cap), np.uint8)
    if n_bytes:
        buf[:, cap - n_bytes:] = rng.integers(0, 256, (lanes, n_bytes),
                                              dtype=np.uint8)
    s = rng.integers(C.RANS_L, 1 << 31, (lanes,), dtype=np.uint32)
    return StackState(s=jnp.asarray(s), buf=jnp.asarray(buf),
                      ptr=jnp.full((lanes,), cap - n_bytes, _I32),
                      underflow=jnp.zeros((lanes,), bool))


def stack_bytes(st: StackState) -> jax.Array:
    """Per-lane live stack size in bytes: stream bytes plus the 4-byte
    state header a :func:`stack_flush` would emit.  The bits-back ratio
    accounting unit: net cost of a message = ``stack_bytes`` after minus
    before (the initial bits are capital, not cost)."""
    cap = st.buf.shape[1]
    return (cap - st.ptr) + 4


def stack_flush(st: StackState) -> EncodedLanes:
    """Serialize the live stack: emit the 4-byte big-endian state header
    (read back first by :func:`stack_open`) and package the streams as
    :class:`EncodedLanes` — byte-compatible with ``coder.encode`` output,
    so flushed stacks ride the existing container/bitstream tooling."""
    s, buf, ptr = st.s, st.buf, st.ptr
    true = jnp.ones_like(s, bool)
    for shift in (0, 8, 16, 24):
        buf, ptr = _emit_backward(
            buf, ptr, ((s >> shift) & _U32(0xFF)).astype(_U8), true)
    cap = buf.shape[1]
    return EncodedLanes(buf=buf, start=jnp.maximum(ptr, 0),
                        length=jnp.asarray(cap, _I32) - ptr,
                        overflow=ptr < 0)


def stack_open(enc: EncodedLanes) -> StackState:
    """Inverse of :func:`stack_flush`: read the state header back off the
    stream and resume the live stack.  A header read past the stream end
    flags ``underflow`` (truncated container)."""
    lanes, cap = enc.buf.shape
    lane_idx = jnp.arange(lanes)
    s = jnp.zeros((lanes,), _U32)
    ptr = enc.start
    under = jnp.zeros((lanes,), bool)
    for _ in range(4):
        byte, oob = _read_byte(enc.buf, lane_idx, ptr, cap)
        under = under | oob
        s = (s << 8) | byte
        ptr = ptr + 1
    return StackState(s=s, buf=enc.buf, ptr=ptr, underflow=under)


# ---------------------------------------------------------------------------
# primitive push / pop over (start, freq) in the fixed-point domain
# ---------------------------------------------------------------------------

def push_with(st: StackState, start: jax.Array, freq: jax.Array,
              prob_bits: int = C.PROB_BITS) -> StackState:
    """Push one symbol per lane given its gathered ``(start, freq)`` pair.

    The encoder planes come from :func:`repro.core.spc.barrett_planes` —
    the single source ``build_tables`` maps over alphabets — then the
    single-source :func:`repro.core.update.encode_step` runs and its renorm
    records land backward, exactly like ``coder.encode_put``.
    """
    rcp, rshift, bias, cmpl, x_max = spc.barrett_planes(freq, start,
                                                        prob_bits)
    e = update.EncEntry(rcp=rcp, rshift=rshift, bias=bias, cmpl=cmpl,
                        x_max=x_max)
    s, recs = update.encode_step(st.s, e)
    buf, ptr = st.buf, st.ptr
    for byte, cond in recs:
        buf, ptr = _emit_backward(buf, ptr, byte, cond)
    return StackState(s, buf, ptr, st.underflow)


def pop_update(st: StackState, slot: jax.Array, start: jax.Array,
               freq: jax.Array, prob_bits: int = C.PROB_BITS) -> StackState:
    """Finish a pop once the symbol is known: the decoder state update plus
    the guarded forward refill (reads past the stream end inject 0 and flag
    ``underflow`` — shared semantics with ``coder.decode_get`` and the
    kernels' ``masked_refill``)."""
    lanes, cap = st.buf.shape
    lane_idx = jnp.arange(lanes)
    s = (freq.astype(_U32) * (st.s >> prob_bits)
         + slot - start.astype(_U32))
    ptr, under = st.ptr, st.underflow
    for _ in range(C.MAX_RENORM_STEPS):
        cond = s < _U32(C.RANS_L)
        byte, oob = _read_byte(st.buf, lane_idx, ptr, cap)
        under = under | (cond & oob)
        s = jnp.where(cond, (s << C.RENORM_SHIFT) | byte, s)
        ptr = ptr + cond.astype(_I32)
    return StackState(s, st.buf, ptr, under)


def stack_slot(st: StackState, prob_bits: int = C.PROB_BITS) -> jax.Array:
    """The per-lane low-bits slot the next pop inverts."""
    return st.s & _U32((1 << prob_bits) - 1)


# ---------------------------------------------------------------------------
# codec combinators
# ---------------------------------------------------------------------------

def NonUniform(enc_statfun, dec_statfun,
               prob_bits: int = C.PROB_BITS) -> Codec:
    """Craystack's primitive codec over statfuns in the fixed-point domain.

    ``enc_statfun(x) -> (start, freq)`` maps per-lane symbols to their CDF
    interval (uint32, mass ``2**prob_bits``); ``dec_statfun(slot) -> x``
    inverts a slot to the symbol whose interval contains it.  The pop
    re-derives ``(start, freq)`` through ``enc_statfun`` so both directions
    consume one statfun — push/pop inverse-ness reduces to the interval
    identity ``start <= slot < start + freq``.
    """
    def push(st: StackState, x) -> StackState:
        start, freq = enc_statfun(x)
        return push_with(st, start, freq, prob_bits)

    def pop(st: StackState):
        slot = stack_slot(st, prob_bits)
        x = dec_statfun(slot)
        start, freq = enc_statfun(x)
        return pop_update(st, slot, start, freq, prob_bits), x

    return Codec(push=push, pop=pop)


def Uniform(bits: int, prob_bits: int = C.PROB_BITS) -> Codec:
    """Table-free uniform codec over ``2**bits`` symbols: every symbol owns
    an equal ``2**(prob_bits - bits)`` slice of the slot space.  The exact
    codec for equal-mass prior bins (a standard-normal prior over its own
    equal-mass quantile bins IS uniform — DESIGN.md §12)."""
    if not 0 < bits <= prob_bits:
        raise ValueError(f"Uniform bits must be in (0, {prob_bits}], "
                         f"got {bits}")
    shift = prob_bits - bits

    def enc_statfun(x):
        x = x.astype(_U32)
        return x << shift, jnp.full_like(x, _U32(1 << shift))

    def dec_statfun(slot):
        return (slot >> shift).astype(_I32)

    return NonUniform(enc_statfun, dec_statfun, prob_bits)


def Categorical(freq: jax.Array, cdf: jax.Array,
                prob_bits: int = C.PROB_BITS,
                backend: str = "coder", interpret: bool = True) -> Codec:
    """Codec over quantized ``(freq, cdf)`` planes (``spc.quantize_probs``
    / ``spc.freq_cdf_from_probs`` output), shared ``(K,)`` or per-lane
    ``(lanes, K)``.

    ``backend="coder"`` inverts slots with the single-source
    ``core.search.find_symbol``; ``backend="kernel"`` pops through the
    Pallas per-step decode kernel (``kernels.rans_decode_step``) — the
    same kernel the fused serve path scans, so stack pops are available on
    the accelerated path too.  Both are bit-identical (shared search and
    refill cores) and both flag stream exhaustion.
    """
    if backend not in ("coder", "kernel"):
        raise ValueError(f"unknown Categorical backend {backend!r}")
    k = freq.shape[-1]

    def enc_statfun(x):
        return _gather(cdf[..., :-1], x), _gather(freq, x)

    def push(st: StackState, x) -> StackState:
        start, f = enc_statfun(x)
        return push_with(st, start, f, prob_bits)

    if backend == "kernel":
        from repro.kernels.rans_decode import rans_decode_step

        def pop(st: StackState):
            s, ptr, x, _, u = rans_decode_step(
                st.buf.T, st.s, st.ptr, freq, cdf, prob_bits=prob_bits,
                interpret=interpret)
            under = st.underflow | (u > 0)
            return StackState(s, st.buf, ptr, under), x

        return Codec(push=push, pop=pop)

    def pop(st: StackState):
        slot = stack_slot(st, prob_bits)
        x, _ = search.find_symbol(cdf, k, slot)
        start, f = enc_statfun(x)
        return pop_update(st, slot, start, f, prob_bits), x

    return Codec(push=push, pop=pop)


def from_tableset(tbl: spc.TableSet, prob_bits: int = C.PROB_BITS,
                  backend: str = "coder", interpret: bool = True) -> Codec:
    """Codec over a full :class:`repro.core.spc.TableSet` — the batch
    coder's table object, reused as a stack codec."""
    return Categorical(tbl.freq, tbl.cdf, prob_bits, backend=backend,
                       interpret=interpret)


def serial(codecs) -> Codec:
    """Compose codecs sequentially: ``pop`` yields symbols in list order,
    so ``push`` runs in *reverse* order (LIFO stack discipline — craystack's
    ``serial``).  Symbols travel as a tuple matching ``codecs``."""
    codecs = list(codecs)

    def push(st: StackState, xs) -> StackState:
        if len(xs) != len(codecs):
            raise ValueError(f"serial push got {len(xs)} symbols for "
                             f"{len(codecs)} codecs")
        for codec, x in reversed(list(zip(codecs, xs))):
            st = codec.push(st, x)
        return st

    def pop(st: StackState):
        xs = []
        for codec in codecs:
            st, x = codec.pop(st)
            xs.append(x)
        return st, tuple(xs)

    return Codec(push=push, pop=pop)


def substack(codec: Codec, idx) -> Codec:
    """Run ``codec`` on the lane subset ``idx`` only (shape-splitting: each
    lane owns an independent state/stream row, so a lane-slice of the stack
    is itself a stack).  Other lanes are untouched bit-for-bit."""
    idx = jnp.asarray(idx, _I32)

    def view(st: StackState) -> StackState:
        return StackState(st.s[idx], st.buf[idx], st.ptr[idx],
                          st.underflow[idx])

    def merge(st: StackState, sub: StackState) -> StackState:
        return StackState(st.s.at[idx].set(sub.s),
                          st.buf.at[idx].set(sub.buf),
                          st.ptr.at[idx].set(sub.ptr),
                          st.underflow.at[idx].set(sub.underflow))

    def push(st: StackState, x) -> StackState:
        return merge(st, codec.push(view(st), x))

    def pop(st: StackState):
        sub, x = codec.pop(view(st))
        return merge(st, sub), x

    return Codec(push=push, pop=pop)


# ---------------------------------------------------------------------------
# array codecs: scan a (lanes, T) symbol block through per-position tables
# ---------------------------------------------------------------------------

def _position_tables(freq: jax.Array, cdf: jax.Array, t_len: int) -> bool:
    # leading-T contract, same as coder.is_per_position: a (T, K) /
    # (T, lanes, K) layout is per-position exactly when its leading dim
    # matches the block length (cdf carries the matching K+1 trailing dim)
    del cdf
    return freq.ndim >= 2 and freq.shape[0] == t_len


def push_symbols(st: StackState, x: jax.Array, freq: jax.Array,
                 cdf: jax.Array,
                 prob_bits: int = C.PROB_BITS) -> StackState:
    """Push a ``(lanes, T)`` symbol block; position tables are shared
    ``(K,)``, per-position ``(T, K)`` or per-position-per-lane
    ``(T, lanes, K)``.  Pushed in reverse position order (one reverse
    ``lax.scan``) so :func:`pop_symbols` pops positions forward — the array
    analogue of ``coder.encode`` against the live stack."""
    t_len = x.shape[1]
    per_position = _position_tables(freq, cdf, t_len)

    def step(carry, xs):
        if per_position:
            x_t, f_t, c_t = xs
        else:
            x_t, f_t, c_t = xs, freq, cdf
        start = _gather(c_t[..., :-1], x_t)
        f = _gather(f_t, x_t)
        return push_with(carry, start, f, prob_bits), None

    xs = (x.T, freq, cdf) if per_position else x.T
    st, _ = jax.lax.scan(step, st, xs, reverse=True)
    return st


def pop_symbols(st: StackState, n: int, freq: jax.Array, cdf: jax.Array,
                prob_bits: int = C.PROB_BITS, backend: str = "coder",
                interpret: bool = True):
    """Pop ``n`` symbols per lane; returns ``(state, symbols (lanes, n))``.

    Table layouts as in :func:`push_symbols`.  ``backend="kernel"`` scans
    the Pallas per-step decode kernel (the fused serve path's primitive);
    both backends are bit-identical.  Pops never write ``buf``, so the
    scan carries only ``(s, ptr, underflow)`` and the kernel path
    transposes the buffer once, not per step.
    """
    if backend not in ("coder", "kernel"):
        raise ValueError(f"unknown pop_symbols backend {backend!r}")
    per_position = _position_tables(freq, cdf, n)
    k = freq.shape[-1]
    buf = st.buf
    buf_t = buf.T if backend == "kernel" else None

    def step(carry, xs):
        s, ptr, under = carry
        f_t, c_t = xs if per_position else (freq, cdf)
        if backend == "kernel":
            from repro.kernels.rans_decode import rans_decode_step
            s, ptr, x, _, u = rans_decode_step(
                buf_t, s, ptr, f_t, c_t, prob_bits=prob_bits,
                interpret=interpret)
            return (s, ptr, under | (u > 0)), x
        sub = StackState(s, buf, ptr, under)
        slot = stack_slot(sub, prob_bits)
        x, _ = search.find_symbol(c_t, k, slot)
        sub = pop_update(sub, slot, _gather(c_t[..., :-1], x),
                         _gather(f_t, x), prob_bits)
        return (sub.s, sub.ptr, sub.underflow), x

    xs = (freq, cdf) if per_position else None
    (s, ptr, under), sym_t = jax.lax.scan(
        step, (st.s, st.ptr, st.underflow), xs, length=n)
    return StackState(s, buf, ptr, under), sym_t.T


# ---------------------------------------------------------------------------
# observation codecs: continuous densities -> fixed-point bin codecs
# ---------------------------------------------------------------------------

def std_gaussian_bins(n_bins: int):
    """Equal-mass bins of the standard normal: ``n_bins - 1`` interior
    edges at the quantiles and the per-bin mass centres.  The canonical
    BB-ANS latent discretization: a ``N(0, 1)`` prior over these bins is
    *exactly* uniform, so the top-level prior codec is :func:`Uniform`."""
    i = np.arange(1, n_bins) / n_bins
    edges = jax.scipy.special.ndtri(jnp.asarray(i, jnp.float32))
    centres = jax.scipy.special.ndtri(
        jnp.asarray((np.arange(n_bins) + 0.5) / n_bins, jnp.float32))
    return edges, centres


def gaussian_bin_probs(mu: jax.Array, sigma: jax.Array,
                       edges: jax.Array) -> jax.Array:
    """``N(mu, sigma)`` mass per bin of ``edges`` (batched over leading
    dims; bins on the trailing axis; endpoint bins take the tails)."""
    z = (edges - mu[..., None]) / sigma[..., None]
    cdf = jax.scipy.special.ndtr(z.astype(jnp.float32))
    ones = jnp.ones(cdf.shape[:-1] + (1,), jnp.float32)
    cdf = jnp.concatenate([jnp.zeros_like(ones), cdf, ones], axis=-1)
    return cdf[..., 1:] - cdf[..., :-1]


def DiagGaussian(mu: jax.Array, sigma: jax.Array, edges: jax.Array,
                 prob_bits: int = C.PROB_BITS,
                 backend: str = "coder", interpret: bool = True) -> Codec:
    """Diagonal-Gaussian codec over fixed bin edges: the bits-back
    *posterior* codec (pop a latent bin index against ``q(z|x)``, push it
    back against the same ``q`` on decode).  ``mu``/``sigma`` are per-lane
    ``(lanes,)`` (or any batch matching the lane axis); probabilities ride
    the BF16 storage + quantization path of :mod:`repro.core.spc`."""
    probs = gaussian_bin_probs(mu, sigma, edges)
    freq, cdf = spc.freq_cdf_from_probs(spc.store_bf16(probs), prob_bits)
    return Categorical(freq, cdf, prob_bits, backend=backend,
                       interpret=interpret)


def logistic_bin_probs(mu: jax.Array, log_s: jax.Array,
                       n_bins: int) -> jax.Array:
    """Discretized-logistic mass over ``n_bins`` equal pixel bins of
    ``[-1, 1]`` (PixelCNN++-style observation model: interior edges through
    the logistic CDF, endpoint bins take the open tails)."""
    i = np.arange(1, n_bins) / n_bins
    edges = jnp.asarray(2.0 * i - 1.0, jnp.float32)
    inv_s = jnp.exp(-log_s.astype(jnp.float32))
    z = (edges - mu[..., None].astype(jnp.float32)) * inv_s[..., None]
    cdf = jax.nn.sigmoid(z)
    ones = jnp.ones(cdf.shape[:-1] + (1,), jnp.float32)
    cdf = jnp.concatenate([jnp.zeros_like(ones), cdf, ones], axis=-1)
    return cdf[..., 1:] - cdf[..., :-1]


def DiscretizedLogistic(mu: jax.Array, log_s: jax.Array, n_bins: int,
                        prob_bits: int = C.PROB_BITS,
                        backend: str = "coder",
                        interpret: bool = True) -> Codec:
    """Discretized-logistic observation codec over ``n_bins`` pixel levels
    in normalized ``[-1, 1]`` units — the ``p(x|z)`` codec of the
    bits-back VAE."""
    probs = logistic_bin_probs(mu, log_s, n_bins)
    freq, cdf = spc.freq_cdf_from_probs(spc.store_bf16(probs), prob_bits)
    return Categorical(freq, cdf, prob_bits, backend=backend,
                       interpret=interpret)
