"""repro.core — the RAS paper's contribution as a composable JAX module.

Public surface:
  spc        — mixed-precision probability module (BF16 -> fixed point, T1)
  coder      — multi-lane two-stage rANS encode/decode (T2, T4)
  search     — shared prediction-guided CDF search core + canonical
               Fig. 4(b) probe accounting (consumed by coder AND kernels)
  update     — shared two-stage encode-update core + fixed-depth renorm
               record emission (consumed by coder AND kernels; DESIGN.md §6)
  predictors — prediction-guided decoding anchors (T3)
  bitstream  — per-lane container format + device stream types +
               record-stream compaction
  golden     — scalar numpy reference (the bit-exactness oracle)
  python_baseline — the paper's Fig-4(a) software comparison target
"""

from repro.core import constants, search, update
from repro.core.spc import (TableSet, build_tables, quantize_probs,
                            tables_from_logits, tables_from_probs, decode_lut,
                            store_bf16)
from repro.core.coder import (EncState, DecState, EncodedLanes, ChunkedLanes,
                              encode, decode, encode_chunked, decode_chunked,
                              encode_put, decode_get, encoder_init,
                              encoder_flush, decoder_init, find_symbol,
                              umulhi32, barrett_div, default_cap, num_chunks,
                              chunk_lengths, chunk_encoded)
from repro.core.predictors import (NeighborAverage, LastValue, ZeroPredictor,
                                   Prediction, model_topk_candidates)

__all__ = [
    "constants", "search", "update", "TableSet", "build_tables",
    "quantize_probs",
    "tables_from_logits", "tables_from_probs", "decode_lut", "store_bf16",
    "EncState", "DecState", "EncodedLanes", "ChunkedLanes", "encode",
    "decode", "encode_chunked", "decode_chunked", "encode_put", "decode_get",
    "encoder_init", "encoder_flush", "decoder_init", "find_symbol",
    "umulhi32", "barrett_div", "default_cap", "num_chunks", "chunk_lengths",
    "chunk_encoded",
    "NeighborAverage", "LastValue", "ZeroPredictor", "Prediction",
    "model_topk_candidates",
]
