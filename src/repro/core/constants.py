"""Fixed-point / rANS constants shared by every layer of the RAS pipeline.

The paper (Sec. IV-A/B) fixes:
  - rANS state: 32-bit unsigned integer
  - re-normalization radix R = 2**PROB_BITS (probability total)
  - byte-level re-normalization (radix-256 emission)
  - state invariant  s in [RANS_L, 256 * RANS_L)

With RANS_L = 2**23 and PROB_BITS <= 16 the canonical range fits uint32 and at
most ``MAX_RENORM_STEPS`` bytes are moved per symbol per direction, which lets
the data-dependent ``while`` re-norm loop be unrolled into a fixed 2-stage
masked pipeline (the TPU analogue of the paper's staged byte re-normalization).
"""

from __future__ import annotations

# Probability precision: frequencies sum to 2**PROB_BITS exactly.
PROB_BITS: int = 14
# Lower bound of the canonical state interval [L, 256L).
RANS_L: int = 1 << 23
# Byte renormalization: base-256 digits.
RENORM_SHIFT: int = 8
RENORM_BASE: int = 1 << RENORM_SHIFT
BYTE_MASK: int = RENORM_BASE - 1
# State is uint32; the canonical upper bound 256*L = 2**31 < 2**32.
STATE_BITS: int = 32
STATE_UPPER: int = RANS_L * RENORM_BASE  # exclusive

# Provable bound on byte moves per symbol per direction (see DESIGN.md §4):
#   encode: s < 256L = 2**31 and x_max >= 2**(23 - n + 8) * 1  -> <= 2 emits
#   decode: s >= f*(s>>n) >= 2**(23-n) post-update             -> <= 2 reads
# for every PROB_BITS in [8, 16].
MAX_RENORM_STEPS: int = 2

# Default lane count of the multi-lane fabric.  128 matches the TPU VREG lane
# width so one lane group is exactly one vector register row.
DEFAULT_LANES: int = 128


def x_max_scale(prob_bits: int) -> int:
    """Per-unit-frequency renorm threshold: x_max(f) = x_max_scale * f."""
    return (RANS_L >> prob_bits) << RENORM_SHIFT


def check_prob_bits(prob_bits: int) -> None:
    if not (8 <= prob_bits <= 16):
        raise ValueError(f"PROB_BITS must be in [8, 16], got {prob_bits}")
    # renorm bound check: ceil((31 - log2(x_max_scale)) / 8) <= MAX_RENORM_STEPS
    import math

    scale = x_max_scale(prob_bits)
    need = max(0, math.ceil((31 - math.floor(math.log2(scale))) / 8))
    assert need <= MAX_RENORM_STEPS, (prob_bits, scale, need)
