"""The paper's comparison target: a plain single-lane Python rANS codec.

Fig. 4(a) of the RAS paper normalizes against "a Python rANS implementation"
running on an Apple M4.  This module is that baseline, kept deliberately
idiomatic-Python (dicts, lists, per-symbol interpreter loop, no numpy
vectorization) so the speedup measured by ``benchmarks/bench_speed.py`` is an
apples-to-apples reproduction of the paper's measurement protocol
("cycle-normalized compute cost ... same symbolization and CDFs, so the
bitstreams are identical").
"""

from __future__ import annotations

from repro.core import constants as C


class PyRans:
    """Single-lane software rANS with while-loop renorm and binary search."""

    def __init__(self, freq, cdf, prob_bits: int = C.PROB_BITS):
        self.prob_bits = prob_bits
        self.mask = (1 << prob_bits) - 1
        self.scale = C.x_max_scale(prob_bits)
        self.freq = [int(f) for f in freq]
        self.cdf = [int(c) for c in cdf]
        self.k = len(self.freq)
        self.search_steps = 0  # instrumentation for Fig. 4(b)

    # -- encode ------------------------------------------------------------
    def encode(self, symbols) -> bytes:
        s = C.RANS_L
        rev = []
        freq, cdf, scale, n = self.freq, self.cdf, self.scale, self.prob_bits
        for x in reversed(symbols):
            f = freq[x]
            x_max = scale * f
            while s >= x_max:
                rev.append(s & 0xFF)
                s >>= 8
            s = ((s // f) << n) + (s % f) + cdf[x]
        head = [(s >> 24) & 0xFF, (s >> 16) & 0xFF, (s >> 8) & 0xFF, s & 0xFF]
        rev.reverse()
        return bytes(head + rev)

    # -- decode ------------------------------------------------------------
    def _search(self, slot: int) -> int:
        """Baseline binary search over the CDF; counts steps like Fig. 4(b)."""
        lo, hi = 0, self.k
        while hi - lo > 1:
            self.search_steps += 1
            mid = (lo + hi) >> 1
            if self.cdf[mid] <= slot:
                lo = mid
            else:
                hi = mid
        return lo

    def decode(self, stream: bytes, n_symbols: int) -> list:
        s = int.from_bytes(stream[:4], "big")
        ptr = 4
        out = []
        freq, cdf, n, mask = self.freq, self.cdf, self.prob_bits, self.mask
        for _ in range(n_symbols):
            slot = s & mask
            x = self._search(slot)
            out.append(x)
            s = freq[x] * (s >> n) + slot - cdf[x]
            while s < C.RANS_L:
                s = (s << 8) | stream[ptr]
                ptr += 1
        return out
