"""Streaming Prefetch Converter (SPC): the paper's mixed-precision probability module.

Implements Sec. IV-A of the RAS paper:

  * distributions are *stored* in BF16 ("half the table storage of fp32");
  * a **single** BF16 -> fixed-point conversion produces integer frequencies
        f(x) = max(1, round(p_x * 2**n))
    followed by a deterministic **mass-correction** pass enforcing
        sum_x f(x) == 2**n
    and a strictly monotone CDF  C(x) = sum_{y<x} f(y);
  * all subsequent division / modulo work happens purely in the fixed-point
    domain — here we go one step further than the RTL and fold the divider
    into the table: the SPC also emits per-symbol Barrett reciprocals
    (rcp, rshift, bias, cmpl) so the hot path needs no integer division at
    all (see DESIGN.md §2, "Barrett/Alverson reciprocal division").

Everything is pure jnp and jit-compatible, so the conversion can run inside
the compression graph (the "streams shared CDF/frequency tables" role).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C

_U32 = jnp.uint32
_I32 = jnp.int32


class TableSet(NamedTuple):
    """Fixed-point coding tables for one distribution (or a batch ``(..., K)``).

    All integer fields are uint32.  ``cdf`` has one more entry than the others
    (``cdf[..., K] == 2**prob_bits``).
    """

    freq: jax.Array      # (..., K)   quantized frequencies, >= 1
    cdf: jax.Array       # (..., K+1) exclusive prefix sums, cdf[...,0] == 0
    rcp: jax.Array       # (..., K)   Barrett reciprocal
    rshift: jax.Array    # (..., K)   post-mulhi shift
    bias: jax.Array      # (..., K)   additive bias (folds CDF + f==1 case)
    cmpl: jax.Array      # (..., K)   2**n - f   (ryg "complement frequency")
    x_max: jax.Array     # (..., K)   encoder renorm threshold  = scale * f

    @property
    def alphabet_size(self) -> int:
        return self.freq.shape[-1]


# ---------------------------------------------------------------------------
# BF16 storage + quantization + mass correction
# ---------------------------------------------------------------------------

def store_bf16(probs: jax.Array) -> jax.Array:
    """Simulate the paper's BF16 global-memory storage of distributions."""
    return probs.astype(jnp.bfloat16)


def quantize_probs(probs: jax.Array, prob_bits: int = C.PROB_BITS) -> jax.Array:
    """BF16/float probabilities -> integer frequencies with exact mass 2**n.

    Faithful to the paper: ``f0 = max(1, round(p * 2**n))`` then one
    deterministic largest-remainder correction pass so ``sum(f) == 2**n`` and
    the CDF is strictly monotone (every symbol keeps f >= 1).

    Works on a single distribution ``(K,)`` or a batch ``(..., K)``.

    §Perf: the correction runs on ONE stable ascending sort (XLA's CPU
    sort is a scalar loop — it was 80% of the serve profile at four sorts
    per call).  In sorted order the ascending rank is the position itself;
    the descending stable rank follows exactly from tie-run bookkeeping
    (``rank_desc = K - runlen + 2*pos_in_run - rank_asc`` — stable sorts
    keep equal keys in index order, so a run member's position within its
    run is its tie-break count for BOTH directions); inverse permutations
    are scatters, not second sorts.  All integer identities — bit-identical
    to the four-argsort form, pinned in tests/test_core_rans.py.
    """
    C.check_prob_bits(prob_bits)
    total = 1 << prob_bits
    k = probs.shape[-1]
    if k > total:
        raise ValueError(
            f"alphabet size {k} exceeds 2**prob_bits={total}; raise prob_bits")

    # Single BF16 -> fixed-point conversion (mass correction keeps it exact).
    p = probs.astype(jnp.bfloat16).astype(jnp.float32)
    p = jnp.where(jnp.isfinite(p) & (p > 0), p, 0.0)
    scaled = p * jnp.float32(total)

    f0 = jnp.maximum(1, jnp.round(scaled)).astype(_I32)
    delta = total - jnp.sum(f0, axis=-1, keepdims=True)  # (..., 1)
    resid = scaled - f0.astype(jnp.float32)

    order_asc = jnp.argsort(resid, axis=-1, stable=True)
    sortd = jnp.take_along_axis(resid, order_asc, axis=-1)
    pidx = jnp.broadcast_to(jnp.arange(k, dtype=_I32), resid.shape)
    edge = jnp.ones(resid.shape[:-1] + (1,), bool)
    first = jnp.concatenate([edge, sortd[..., 1:] != sortd[..., :-1]], -1)
    last = jnp.concatenate([first[..., 1:], edge], -1)
    ax = resid.ndim - 1
    start = jax.lax.cummax(jnp.where(first, pidx, 0), axis=ax)
    end = jax.lax.cummin(jnp.where(last, pidx, k - 1), axis=ax, reverse=True)
    runlen = end - start + 1                  # tie-run extent at each slot
    rank_desc_sorted = k - runlen + 2 * (pidx - start) - pidx

    # --- delta > 0: distribute delta units; BF16 storage error can make
    # delta exceed K, so give floor(delta/K) to every symbol and the
    # remainder to the largest residuals (stable largest-remainder rule).
    rank_desc = jnp.put_along_axis(jnp.zeros_like(pidx), order_asc,
                                   rank_desc_sorted, axis=-1, inplace=False)
    f_pos = f0 + delta // k + (rank_desc < delta % k).astype(_I32)

    # --- delta < 0: remove `-delta` units, smallest residual first, never
    # below 1.  capacity = f0 - 1; waterfill along ascending residual.
    need = (-delta).astype(_I32)                              # (..., 1)
    cap_sorted = jnp.take_along_axis(f0 - 1, order_asc, axis=-1)
    cum_excl = jnp.cumsum(cap_sorted, axis=-1) - cap_sorted
    take_sorted = jnp.clip(need - cum_excl, 0, cap_sorted)
    take = jnp.put_along_axis(jnp.zeros_like(pidx), order_asc, take_sorted,
                              axis=-1, inplace=False)
    f_neg = f0 - take

    f = jnp.where(delta >= 0, f_pos, f_neg)
    return f.astype(_U32)


# ---------------------------------------------------------------------------
# Barrett reciprocal construction (exact uint32 long division, no x64 needed)
# ---------------------------------------------------------------------------

def _ceil_div_pow2_u32(shift_amt: jax.Array, f: jax.Array) -> jax.Array:
    """ceil(2**(31 + shift_amt) / f) computed exactly in uint32.

    Uses  2**(31+s) // f = (2**31 // f) << s  +  ((2**31 % f) << s) // f
    (all pieces < 2**32 because f >= 2 and s = ceil(log2 f) <= 16).
    """
    two31 = _U32(1 << 31)
    a = two31 // f                       # <= 2**30
    r = two31 - a * f                    # < f <= 2**16
    hi = a << shift_amt                  # < 2**32 (since 2**s < 2f)
    num = r << shift_amt                 # < 2**32
    q2 = num // f
    rem = num - q2 * f
    rcp = hi + q2 + (rem > 0).astype(_U32)
    return rcp


def barrett_planes(freq: jax.Array, start: jax.Array, prob_bits: int):
    """``(freq, start)`` -> the five encoder planes ``(rcp, rshift, bias,
    cmpl, x_max)``.

    This is the *single source* of the Barrett reciprocal construction:
    :func:`build_tables` maps it over whole alphabets, and the stack codecs
    (``core.stack``) call it per-symbol on gathered ``(start, freq)`` pairs —
    structurally the same math, so push/pop over statfuns is bit-identical
    to the table path by construction.
    """
    total = _U32(1 << prob_bits)
    f = freq.astype(_U32)
    start = start.astype(_U32)

    is_one = f == 1
    # shift = ceil(log2 f) = bit_length(f - 1) for f >= 2.
    fm1 = jnp.maximum(f, 2) - 1
    shift = (_U32(32) - jax.lax.clz(fm1)).astype(_U32)
    rcp_ge2 = _ceil_div_pow2_u32(shift, jnp.maximum(f, 2))

    rcp = jnp.where(is_one, _U32(0xFFFFFFFF), rcp_ge2)
    rshift = jnp.where(is_one, _U32(0), shift - 1)
    bias = jnp.where(is_one, start + total - 1, start)
    cmpl = total - f
    x_max = _U32(C.x_max_scale(prob_bits)) * f
    return rcp, rshift, bias, cmpl, x_max


def build_tables(freq: jax.Array, prob_bits: int = C.PROB_BITS) -> TableSet:
    """Quantized frequencies -> full fixed-point TableSet (batched OK)."""
    C.check_prob_bits(prob_bits)
    f = freq.astype(_U32)

    cdf_hi = jnp.cumsum(f.astype(_I32), axis=-1).astype(_U32)
    zeros = jnp.zeros(f.shape[:-1] + (1,), _U32)
    cdf = jnp.concatenate([zeros, cdf_hi], axis=-1)          # (..., K+1)
    start = cdf[..., :-1]

    rcp, rshift, bias, cmpl, x_max = barrett_planes(f, start, prob_bits)
    return TableSet(freq=f, cdf=cdf, rcp=rcp, rshift=rshift,
                    bias=bias, cmpl=cmpl, x_max=x_max)


def freq_cdf_from_probs(probs: jax.Array, prob_bits: int = C.PROB_BITS):
    """Decode-only SPC fast path: probabilities -> ``(freq, cdf)``.

    The decoder's hot loop touches only the frequencies and the exclusive
    CDF — the Barrett reciprocal planes (rcp/rshift/bias/cmpl/x_max) are
    encoder-side machinery.  This helper runs the identical
    :func:`quantize_probs` mass correction and the *verbatim* CDF
    construction of :func:`build_tables`, so
    ``freq_cdf_from_probs(p) == (t.freq, t.cdf)`` for
    ``t = tables_from_probs(p)`` bit-for-bit, at ~2/7 the table FLOPs/bytes.
    The fused serve decode (serve.compress, DESIGN.md §9) quantizes each
    model step through this path just-in-time.
    """
    f = quantize_probs(probs, prob_bits)
    cdf_hi = jnp.cumsum(f.astype(_I32), axis=-1).astype(_U32)
    zeros = jnp.zeros(f.shape[:-1] + (1,), _U32)
    return f, jnp.concatenate([zeros, cdf_hi], axis=-1)


def tables_from_probs(probs: jax.Array,
                      prob_bits: int = C.PROB_BITS) -> TableSet:
    """One-shot SPC: BF16 probabilities -> coding tables (the paper's path)."""
    return build_tables(quantize_probs(probs, prob_bits), prob_bits)


def tables_from_logits(logits: jax.Array,
                       prob_bits: int = C.PROB_BITS) -> TableSet:
    """Model logits -> coding tables (softmax in f32, stored via BF16)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return tables_from_probs(store_bf16(probs), prob_bits)


def decode_lut(tables: TableSet, prob_bits: int = C.PROB_BITS) -> jax.Array:
    """Optional O(1) slot->symbol lookup table (static-table fast path).

    Beyond-paper optimization: for a *static* table the 2**n-entry inverse LUT
    replaces the binary search entirely (one gather per symbol).  Memory is
    2**n entries so this is only built for shared/static tables.
    """
    slots = jnp.arange(1 << prob_bits, dtype=_U32)
    # symbol = number of cdf entries <= slot, minus one.
    return (jnp.searchsorted(tables.cdf, slots, side="right") - 1).astype(_U32)


# ---------------------------------------------------------------------------
# numpy convenience (host-side table prep, container tooling)
# ---------------------------------------------------------------------------

def tables_from_counts_np(counts: np.ndarray,
                          prob_bits: int = C.PROB_BITS) -> TableSet:
    """Host-side helper: raw symbol counts -> TableSet (adds +1 smoothing)."""
    counts = np.asarray(counts, np.float64)
    probs = (counts + 1.0) / (counts + 1.0).sum(-1, keepdims=True)
    with jax.default_device(jax.devices("cpu")[0]):
        return jax.tree.map(np.asarray,
                            tables_from_probs(jnp.asarray(probs, jnp.float32),
                                              prob_bits))
