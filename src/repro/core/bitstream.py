"""Multi-lane bitstream container (host-side pack/unpack).

The RAS bitstream is per-lane independent (the fabric's lanes never share
coder state — Sec. III), so the container is simply:

    magic(4) | version(1) | prob_bits(1) | reserved(2)
    | lanes(u32) | n_symbols(u32)
    | per-lane length (u32 * lanes)
    | concatenated lane payloads

Pack/unpack are numpy-only; the device-side representation is
``coder.EncodedLanes`` (padded (lanes, cap) uint8 + start/length).
"""

from __future__ import annotations

import struct
from typing import NamedTuple

import numpy as np

from repro.core import constants as C

MAGIC = b"RAS1"
_HEADER = struct.Struct("<4sBBHII")


class Container(NamedTuple):
    payload: bytes
    prob_bits: int
    lanes: int
    n_symbols: int


def pack(enc_buf: np.ndarray, start: np.ndarray, length: np.ndarray,
         n_symbols: int, prob_bits: int = C.PROB_BITS) -> bytes:
    """EncodedLanes arrays (host numpy) -> container bytes."""
    enc_buf = np.asarray(enc_buf, np.uint8)
    start = np.asarray(start, np.int64)
    length = np.asarray(length, np.int64)
    lanes = enc_buf.shape[0]
    out = bytearray()
    out += _HEADER.pack(MAGIC, 1, prob_bits, 0, lanes, n_symbols)
    out += np.asarray(length, np.uint32).tobytes()
    for i in range(lanes):
        out += enc_buf[i, start[i]:start[i] + length[i]].tobytes()
    return bytes(out)


def unpack(blob: bytes) -> tuple[np.ndarray, np.ndarray, Container]:
    """Container bytes -> ((lanes, cap) uint8 padded buf, start, meta).

    The returned buffer is forward-readable from ``start`` per lane, i.e.
    directly consumable by ``coder.decoder_init``.
    """
    magic, version, prob_bits, _, lanes, n_symbols = _HEADER.unpack_from(blob)
    if magic != MAGIC:
        raise ValueError("not a RAS container")
    if version != 1:
        raise ValueError(f"unsupported container version {version}")
    off = _HEADER.size
    length = np.frombuffer(blob, np.uint32, lanes, off).astype(np.int64)
    off += 4 * lanes
    cap = int(length.max()) if lanes else 0
    buf = np.zeros((lanes, cap), np.uint8)
    start = (cap - length).astype(np.int32)
    for i in range(lanes):
        n = int(length[i])
        buf[i, cap - n:] = np.frombuffer(blob, np.uint8, n, off)
        off += n
    meta = Container(payload=b"", prob_bits=prob_bits, lanes=lanes,
                     n_symbols=n_symbols)
    return buf, start, meta


def compressed_size(length: np.ndarray) -> int:
    """Total container size in bytes for reporting compression ratios."""
    lanes = len(length)
    return _HEADER.size + 4 * lanes + int(np.sum(length))
