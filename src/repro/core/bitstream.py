"""Multi-lane bitstream containers (host-side pack/unpack).

The RAS bitstream is per-lane independent (the fabric's lanes never share
coder state — Sec. III).  Two wire formats exist:

**Container v1** (``RAS1``) — one monolithic stream per lane::

    magic "RAS1"(4) | version u8 = 1 | prob_bits u8 | reserved u16
    | lanes u32 | n_symbols u32
    | per-lane length (u32 * lanes)
    | concatenated lane payloads (lane-major)

**Container v2** (``RAS2``) — the chunked streaming format.  The payload is
cut into fixed-size symbol chunks; every (chunk, lane) cell is a complete
standalone rANS stream with its own flush, so chunks decode independently,
in parallel, and in any order (the interleaved-ANS construction).  Layout::

    header (24 bytes):
        magic "RAS2"(4) | version u8 = 2 | prob_bits u8 | flags u16
        | lanes u32 | n_symbols u32 | chunk_size u32 | n_chunks u32
    chunk index table (12 bytes per cell, 16 with FLAG_CHUNK_CRC32,
    chunk-major then lane):
        offset u64   -- byte offset of this cell's stream from payload base
        length u32   -- byte length of this cell's stream
        crc32 u32    -- only when flags & FLAG_CHUNK_CRC32: zlib CRC32 of
                        this cell's payload bytes
    payload:
        concatenated (chunk, lane) streams, chunk-major then lane, each a
        self-delimiting rANS stream (4-byte big-endian state header first)

``flags`` was the always-zero reserved u16 of the original v2 layout, so
checksum-less v2 blobs (flags == 0) and v1 blobs keep unpacking unchanged.
Writers default to ``FLAG_CHUNK_CRC32``: per-(chunk, lane) integrity at
chunk granularity, verified on unpack with an error naming the corrupt
cell — a torn or bit-flipped chunk is caught before the decoder walks it,
and intact chunks stay independently decodable.

``n_chunks = ceil(n_symbols / chunk_size)``; the final chunk covers the
ragged tail ``n_symbols - (n_chunks - 1) * chunk_size`` symbols.  Offsets
are stored explicitly (though derivable from lengths) so a reader can seek
to any (chunk, lane) cell in O(1) — random access into the compressed
stream, chunk-granular.

This module also owns the **device-side stream representations** —
:class:`EncodedLanes` (padded (lanes, cap) uint8 + start/length) and
:class:`ChunkedLanes` ((n_chunks, lanes, cap) + per-cell start/length) —
and the stream compaction :func:`compact_records` that turns the
fixed-shape renorm records of :mod:`repro.core.update` into right-aligned
per-lane streams.  Compaction lives here (not in ``kernels``) because it is
part of the *wire format*: it is the **pure-JAX reference** for the layout
every encode backend must produce, consumed by
``core.coder.encode_records`` and by the kernel *records* path
(``kernels.rans_encode.rans_encode_records``).  The production kernel
datapath (``kernels.rans_encode.rans_encode_lanes``) fuses this compaction
into the kernel itself — same cursor semantics, same overflow clamp,
differential-tested byte-identical (DESIGN.md §8) — so the kernel encode
wrappers no longer call it host-side; ``repro.kernels.ops`` re-exports it
for back-compat.  Pack/unpack remain numpy-only host-side.
``unpack`` keeps full back-compat for v1 blobs; ``unpack_chunked`` reads
both versions (a v1 blob is presented as a single-chunk stream).
"""

from __future__ import annotations

import functools
import os
import struct
import zlib
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C

_U32J = jnp.uint32
_U8J = jnp.uint8
_I32J = jnp.int32


class EncodedLanes(NamedTuple):
    """Device-side multi-lane streams: ``buf[lane, start[lane]:start[lane] +
    length[lane]]`` is lane ``lane``'s forward-readable byte stream.

    ``overflow`` (when present) flags lanes whose stream did not fit the
    ``cap`` the encoder was given: their buffer holds a *truncated* stream
    (writes past the buffer head are dropped, never wrapped — see
    :func:`compact_records`), ``length`` reports the bytes that were
    *needed*, and the lane must be re-encoded with a larger cap before the
    stream is decodable or packable.  ``None`` means the producer predates
    the flag (e.g. a container unpack) — overflow cannot occur there.
    """

    buf: jax.Array      # (lanes, cap) uint8
    start: jax.Array    # (lanes,) int32: stream begins at buf[lane, start:]
    length: jax.Array   # (lanes,) int32 bytes per lane
    overflow: jax.Array | None = None   # (lanes,) bool: cap exceeded


class ChunkedLanes(NamedTuple):
    """Chunked multi-lane streams (the streaming container's device form).

    Chunk ``c`` of lane ``l`` occupies
    ``buf[c, l, start[c, l] : start[c, l] + length[c, l]]`` and is a complete
    standalone rANS stream (own 4-byte state header, own flush): byte-for-byte
    identical to ``coder.encode`` of that chunk's symbols alone.  Chunks
    therefore decode independently and in any order — the handle the
    ``parallel`` package shards across devices.  ``overflow`` is the
    per-(chunk, lane) analogue of :attr:`EncodedLanes.overflow`.
    """

    buf: jax.Array      # (n_chunks, lanes, cap) uint8
    start: jax.Array    # (n_chunks, lanes) int32
    length: jax.Array   # (n_chunks, lanes) int32
    overflow: jax.Array | None = None   # (n_chunks, lanes) bool


@functools.partial(jax.jit, static_argnames=("cap",))
def compact_records(bytes_rec: jax.Array,   # (T, 2, lanes) uint8
                    mask_rec: jax.Array,    # (T, 2, lanes) uint8 0/1
                    states: jax.Array,      # (lanes,) uint32 final states
                    cap: int) -> EncodedLanes:
    """Fixed-shape renorm records -> right-aligned per-lane streams.

    Emission order is t descending then renorm step ascending (exactly the
    order :func:`repro.core.update.encode_step` produces); the stream
    stores emissions reversed, preceded by the 4-byte big-endian state
    header.  Rows with mask 0 (non-emitting steps, or padding rows from a
    blocked kernel) contribute nothing.

    Overflow guard: when a lane's stream (4 + emitted bytes) exceeds
    ``cap``, its would-be indices go negative; they are clamped to the
    out-of-bounds drop sentinel instead of being scattered (negative
    indices wrap under numpy semantics and would silently corrupt the
    buffer head).  The lane's ``overflow`` flag is set and ``length``
    reports the bytes that were needed.  This contract is position-exact —
    any ``cap`` (including ``cap < 4``, where even the state header is
    clipped) yields the same surviving bytes and the same flags as the
    coder's backward cursor and the fused kernel's in-kernel cursor, so a
    stream that overflows is flagged identically on the monolithic and
    chunked paths of all three backends (pinned by the tiny-cap parity
    tests in ``tests/test_update_unified.py``).
    """
    t_len, r, lanes = bytes_rec.shape
    seq_b = bytes_rec[::-1].reshape(t_len * r, lanes)
    seq_m = mask_rec[::-1].reshape(t_len * r, lanes).astype(_I32J)
    n_emit = jnp.sum(seq_m, axis=0)                   # (lanes,)
    pos = jnp.cumsum(seq_m, axis=0) - seq_m           # exclusive prefix
    length = 4 + n_emit
    start = cap - length                              # may go negative
    overflow = length > cap
    idx = start[None, :] + 4 + (n_emit[None, :] - 1 - pos)
    # dropped when not emitted OR past the buffer head (overflow clamp)
    idx = jnp.where((seq_m > 0) & (idx >= 0), idx, cap)
    lane_ix = jnp.broadcast_to(jnp.arange(lanes)[None, :], idx.shape)
    buf = jnp.zeros((lanes, cap), _U8J)
    buf = buf.at[lane_ix.reshape(-1), idx.reshape(-1)].set(
        seq_b.reshape(-1), mode="drop")
    lane = jnp.arange(lanes)
    for i, shift in enumerate((24, 16, 8, 0)):
        hidx = jnp.where(start + i >= 0, start + i, cap)
        buf = buf.at[lane, hidx].set(
            ((states >> shift) & _U32J(0xFF)).astype(_U8J), mode="drop")
    return EncodedLanes(buf=buf, start=jnp.maximum(start, 0),
                        length=length, overflow=overflow)

MAGIC = b"RAS1"
MAGIC_V2 = b"RAS2"
FLAG_CHUNK_CRC32 = 1 << 0   # v2 flags bit: index cells carry payload CRC32s
_HEADER = struct.Struct("<4sBBHII")
_HEADER_V2 = struct.Struct("<4sBBHIIII")
_INDEX_V2 = struct.Struct("<QI")
# the index cell as a numpy record, for vectorized table I/O (12 bytes
# plain, 16 with the per-cell CRC32)
_INDEX_V2_DT = np.dtype([("offset", "<u8"), ("length", "<u4")])
_INDEX_V2C_DT = np.dtype([("offset", "<u8"), ("length", "<u4"),
                          ("crc", "<u4")])


class Container(NamedTuple):
    payload: bytes
    prob_bits: int
    lanes: int
    n_symbols: int


class ChunkedContainer(NamedTuple):
    prob_bits: int
    lanes: int
    n_symbols: int
    chunk_size: int
    n_chunks: int


class ContainerSlab(NamedTuple):
    """Zero-copy container handle: the raw payload slab + index planes.

    Produced by :func:`parse_chunked` — the *validation-only* half of
    :func:`unpack_chunked`.  No payload byte is copied or re-aligned: cell
    (c, l)'s stream is ``slab[offset[c, l] : offset[c, l] + length[c, l]]``
    exactly as it sits in the blob.  This is the decode-side memory format
    the zero-copy kernel path consumes (the index planes ride the grid as
    scalar-prefetch inputs, DESIGN.md §10); the dense right-aligned
    :class:`ChunkedLanes` form survives as the differential reference via
    :func:`unpack_chunked` / :func:`slab_to_chunked`.

    Every named :class:`ValueError` of :func:`unpack_chunked` (truncated
    header / index / payload span, overlapping or inflated spans, CRC
    mismatch at a specific (chunk, lane)) has already been raised by the
    time a ``ContainerSlab`` exists, so downstream consumers never see a
    hostile index.
    """

    slab: np.ndarray    # (S,) uint8 raw payload bytes (a view of the blob)
    offset: np.ndarray  # (n_chunks, lanes) int64 payload byte offsets
    length: np.ndarray  # (n_chunks, lanes) int64 span byte lengths
    cap: int            # max cell length (the dense form's row stride)
    meta: ChunkedContainer


def _check_no_overflow(overflow) -> None:
    if overflow is not None and np.asarray(overflow).any():
        bad = np.argwhere(np.asarray(overflow)).tolist()
        raise ValueError(
            f"cannot pack overflowed streams (cells {bad}): the encoder ran "
            "out of buffer capacity and the payload is truncated — "
            "re-encode with a larger cap")


def pack(enc_buf: np.ndarray, start: np.ndarray, length: np.ndarray,
         overflow: np.ndarray | None = None, *,
         n_symbols: int, prob_bits: int = C.PROB_BITS) -> bytes:
    """EncodedLanes arrays (host numpy) -> container v1 bytes.

    ``overflow`` (the optional 4th EncodedLanes field, so
    ``pack(*map(np.asarray, enc), n_symbols=...)`` forwards it) is
    validated: packing a truncated stream raises instead of shipping a
    blob that cannot decode.
    """
    _check_no_overflow(overflow)
    enc_buf = np.asarray(enc_buf, np.uint8)
    start = np.asarray(start, np.int64)
    length = np.asarray(length, np.int64)
    lanes = enc_buf.shape[0]
    out = bytearray()
    out += _HEADER.pack(MAGIC, 1, prob_bits, 0, lanes, n_symbols)
    out += np.asarray(length, np.uint32).tobytes()
    for i in range(lanes):
        out += enc_buf[i, start[i]:start[i] + length[i]].tobytes()
    return bytes(out)


def _parse_v1(blob: bytes) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                                    Container]:
    """Validation-only v1 parse -> (payload view, offsets, length, meta).

    v1 payloads are lane-major and contiguous, so the per-lane offsets are
    just the length prefix sums — the blob's payload region IS the slab and
    no byte needs to move to index it.
    """
    if blob[:4] == MAGIC_V2:
        raise ValueError("chunked container v2: use bitstream.unpack_chunked")
    if blob[:4] != MAGIC:
        raise ValueError("not a RAS container")
    if len(blob) < _HEADER.size:
        raise ValueError(
            f"truncated container v1: header needs {_HEADER.size} bytes, "
            f"blob has {len(blob)}")
    magic, version, prob_bits, _, lanes, n_symbols = _HEADER.unpack_from(blob)
    if version != 1:
        raise ValueError(f"unsupported container version {version}")
    off = _HEADER.size
    if off + 4 * lanes > len(blob):
        raise ValueError(
            f"truncated container v1: lane-length table needs bytes "
            f"[{off}, {off + 4 * lanes}) for {lanes} lanes, blob has "
            f"{len(blob)}")
    length = np.frombuffer(blob, np.uint32, lanes, off).astype(np.int64)
    off += 4 * lanes
    if off + int(length.sum()) > len(blob):
        bad = int(np.argmax(off + np.cumsum(length) > len(blob)))
        raise ValueError(
            f"truncated payload at lane {bad}: lane lengths claim "
            f"{int(length.sum())} payload bytes but blob has "
            f"{len(blob) - off}")
    payload = np.frombuffer(blob, np.uint8, int(length.sum()), off)
    offsets = np.cumsum(length) - length
    meta = Container(payload=b"", prob_bits=prob_bits, lanes=lanes,
                     n_symbols=n_symbols)
    return payload, offsets, length, meta


def unpack(blob: bytes) -> tuple[np.ndarray, np.ndarray, Container]:
    """Container v1 bytes -> ((lanes, cap) uint8 padded buf, start, meta).

    The returned buffer is forward-readable from ``start`` per lane, i.e.
    directly consumable by ``coder.decoder_init``.  v2 blobs are chunked —
    read them with :func:`unpack_chunked`.

    Corrupt input raises :class:`ValueError` naming the damaged region
    (truncated header / length table / per-lane payload) — never a raw
    struct/numpy error and never a silently short buffer.
    """
    payload, offsets, length, meta = _parse_v1(blob)
    cap = int(length.max()) if meta.lanes else 0
    start = (cap - length).astype(np.int32)
    buf = _right_align_cells(payload, offsets[None], length[None], cap)[0]
    return buf, start, meta


def _span_indices(start: np.ndarray, length: np.ndarray,
                  row_stride: int) -> np.ndarray:
    """Flat indices of every cell's ``[start, start+length)`` span in a
    dense ``(cells, row_stride)`` buffer, cell-major.

    O(total bytes) with no ``(cells, cap)`` intermediates.  With
    ``row_stride=0`` the rows collapse and the result indexes a flat byte
    region at per-cell ``start`` offsets (the payload-side gather).
    """
    start = np.asarray(start, np.int64)
    length = np.asarray(length, np.int64)
    total = int(length.sum())
    excl = np.cumsum(length) - length          # exclusive prefix
    within = np.arange(total, dtype=np.int64) - np.repeat(excl, length)
    rows = np.repeat(np.arange(length.size, dtype=np.int64), length)
    return rows * row_stride + np.repeat(start, length) + within


def _right_align_cells_loop(payload: np.ndarray, offsets: np.ndarray,
                            length: np.ndarray, cap: int) -> np.ndarray:
    """Per-cell Python-loop reference for :func:`_right_align_cells`.

    Kept only as the micro-assert oracle (``RAS_BITSTREAM_SELFTEST``) and
    for tests — production unpack is always the one-gather vectorized path.
    """
    shape = length.shape
    buf = np.zeros(shape + (cap,), np.uint8)
    flat = buf.reshape(-1, cap) if cap else buf.reshape(-1, 0)
    off_f = offsets.reshape(-1)
    len_f = length.reshape(-1)
    for cell in range(len_f.size):
        o, n = int(off_f[cell]), int(len_f[cell])
        flat[cell, cap - n:] = payload[o:o + n]
    return buf


def _right_align_cells(payload: np.ndarray, offsets: np.ndarray,
                       length: np.ndarray, cap: int) -> np.ndarray:
    """Right-align every cell's payload span into a dense ``(..., cap)``
    uint8 buffer — ONE vectorized gather via :func:`_span_indices` on every
    code path (v1 and v2 unpack both land here).

    This host-side copy is the *differential reference* for the zero-copy
    kernel decode path (DESIGN.md §10): ``ops.rans_decode_chunked(
    from_container=...)`` reads the slab directly and must produce
    byte-identical symbols; tests poison this function to pin that the
    copy never runs on the kernel hot path.

    With ``RAS_BITSTREAM_SELFTEST=1`` the per-cell loop reference is run
    alongside and asserted buffer-identical (the satellite micro-assert).
    """
    offsets = np.asarray(offsets, np.int64)
    length = np.asarray(length, np.int64)
    buf = np.zeros(length.shape + (cap,), np.uint8)
    flat_len = length.reshape(-1)
    dest = _span_indices(cap - flat_len, flat_len, cap)
    src = _span_indices(offsets.reshape(-1), flat_len, 0)
    buf.reshape(-1)[dest] = payload[src]
    if os.environ.get("RAS_BITSTREAM_SELFTEST"):
        ref = _right_align_cells_loop(payload, offsets, length, cap)
        assert np.array_equal(buf, ref), (
            "bitstream selftest: vectorized right-align diverges from the "
            "per-cell loop reference")
    return buf


def pack_chunked(buf: np.ndarray, start: np.ndarray, length: np.ndarray,
                 overflow: np.ndarray | None = None, *,
                 chunk_size: int, n_symbols: int,
                 prob_bits: int = C.PROB_BITS,
                 checksums: bool = True) -> bytes:
    """ChunkedLanes arrays (host numpy) -> container v2 bytes.

    ``buf`` is (n_chunks, lanes, cap); cell (c, l) holds its stream at
    ``buf[c, l, start[c, l] : start[c, l] + length[c, l]]``.  ``overflow``
    (the optional 4th ChunkedLanes field) is validated — truncated cells
    refuse to pack (see :func:`pack`).

    ``checksums`` (default on) stores a CRC32 of every cell's payload in the
    index (``FLAG_CHUNK_CRC32``); :func:`unpack_chunked` verifies them and
    names the corrupt (chunk, lane) on mismatch.
    """
    _check_no_overflow(overflow)
    buf = np.asarray(buf, np.uint8)
    start = np.asarray(start, np.int64)
    length = np.asarray(length, np.int64)
    n_chunks, lanes = buf.shape[:2]
    flags = FLAG_CHUNK_CRC32 if checksums else 0
    out = bytearray()
    out += _HEADER_V2.pack(MAGIC_V2, 2, prob_bits, flags, lanes, n_symbols,
                           chunk_size, n_chunks)
    # payload: one O(total-bytes) gather of every cell's span (built first
    # so the index can checksum the exact bytes that ship)
    flat_len = length.reshape(-1)
    idx = _span_indices(start.reshape(-1), flat_len, buf.shape[2])
    payload = buf.reshape(-1)[idx]
    # explicit (offset, length[, crc]) index for O(1) chunk/lane random
    # access; one vectorized record write, not a per-cell struct.pack loop
    offsets = np.concatenate([[0], np.cumsum(flat_len)[:-1]]).astype(np.int64)
    index = np.empty(flat_len.size, _INDEX_V2C_DT if checksums
                     else _INDEX_V2_DT)
    index["offset"] = offsets
    index["length"] = flat_len
    if checksums:
        # zlib.crc32 takes buffer views directly — no per-cell copies
        index["crc"] = np.fromiter(
            (zlib.crc32(payload[o:o + n])
             for o, n in zip(offsets, flat_len)),
            dtype=np.uint32, count=flat_len.size)
    out += index.tobytes()
    out += payload.tobytes()
    return bytes(out)


def parse_chunked(blob: bytes) -> ContainerSlab:
    """Validation-only container parse (v2 or v1) -> :class:`ContainerSlab`.

    Runs every structural check :func:`unpack_chunked` runs — same named
    :class:`ValueError`\\ s, same order (truncated header / index / payload
    span, offset wrap, overlapping or inflated spans, CRC mismatch at a
    specific (chunk, lane)) — but moves **no payload byte**: the returned
    slab is a read-only view of the blob's payload region and the per-cell
    ``(offset, length)`` planes index into it.  This is the zero-copy
    decode entry point; :func:`unpack_chunked` is this plus the dense
    right-align gather.

    v1 blobs are presented as a single chunk of ``n_symbols`` symbols —
    their lane-major payload is already one contiguous slab.
    """
    magic = blob[:4]
    if magic == MAGIC:
        payload, offsets, length, meta = _parse_v1(blob)
        cap = int(length.max()) if meta.lanes else 0
        return ContainerSlab(
            slab=payload, offset=offsets[None], length=length[None],
            cap=cap,
            meta=ChunkedContainer(prob_bits=meta.prob_bits, lanes=meta.lanes,
                                  n_symbols=meta.n_symbols,
                                  chunk_size=max(meta.n_symbols, 1),
                                  n_chunks=1))
    if magic != MAGIC_V2:
        raise ValueError("not a RAS container")
    if len(blob) < _HEADER_V2.size:
        raise ValueError(
            f"truncated container v2: header needs {_HEADER_V2.size} bytes, "
            f"blob has {len(blob)}")
    (magic, version, prob_bits, flags, lanes, n_symbols, chunk_size,
     n_chunks) = _HEADER_V2.unpack_from(blob)
    if version != 2:
        raise ValueError(f"unsupported container version {version}")
    has_crc = bool(flags & FLAG_CHUNK_CRC32)
    off = _HEADER_V2.size
    cells = n_chunks * lanes
    index_dt = _INDEX_V2C_DT if has_crc else _INDEX_V2_DT
    base = off + cells * index_dt.itemsize
    if base > len(blob):
        raise ValueError(
            f"truncated container v2: chunk index table needs bytes "
            f"[{off}, {base}) for {n_chunks} chunks x {lanes} lanes, blob "
            f"has {len(blob)}")
    index = np.frombuffer(blob, index_dt, cells, off)
    offsets_u = index["offset"]                 # u64: validate BEFORE any
    length = index["length"].astype(np.int64)   # signed use — a corrupt
    payload_len = len(blob) - base              # offset must not wrap
    oob = offsets_u > np.uint64(payload_len)
    spans = offsets_u.astype(np.int64) + length
    bad_cell = oob | (spans > payload_len)
    if cells and bad_cell.any():
        bad = int(np.argmax(bad_cell))
        c, lane = divmod(bad, lanes)
        raise ValueError(
            f"truncated payload at chunk {c}, lane {lane}: cell claims "
            f"payload bytes [{int(offsets_u[bad])}, "
            f"{int(offsets_u[bad]) + int(length[bad])}) but the payload "
            f"holds {payload_len}")
    offsets = offsets_u.astype(np.int64)
    if cells and int(length.sum()) > payload_len:
        raise ValueError(
            f"corrupt chunk index: cells claim {int(length.sum())} total "
            f"payload bytes but the payload holds {payload_len} — "
            "overlapping or inflated spans")
    payload = np.frombuffer(blob, np.uint8, payload_len, base)
    if has_crc and cells:
        # one vectorized CRC comparison over all cells (zlib.crc32 takes
        # buffer views directly — no per-cell payload copies)
        got = np.fromiter(
            (zlib.crc32(payload[o:o + n])
             for o, n in zip(offsets, length)),
            dtype=np.uint32, count=cells)
        bad_crc = got != index["crc"]
        if bad_crc.any():
            bad = int(np.argmax(bad_crc))
            c, lane = divmod(bad, lanes)
            raise ValueError(
                f"container v2 checksum mismatch at chunk {c}, lane "
                f"{lane}: stored CRC32 0x{int(index['crc'][bad]):08x}, "
                f"computed 0x{int(got[bad]):08x} — chunk payload corrupt")
    cap = int(length.max()) if cells else 0
    meta = ChunkedContainer(prob_bits=prob_bits, lanes=lanes,
                            n_symbols=n_symbols, chunk_size=chunk_size,
                            n_chunks=n_chunks)
    return ContainerSlab(slab=payload,
                         offset=offsets.reshape(n_chunks, lanes),
                         length=length.reshape(n_chunks, lanes),
                         cap=cap, meta=meta)


def unpack_chunked(blob: bytes) -> tuple[np.ndarray, np.ndarray,
                                         ChunkedContainer]:
    """Container bytes (v2 or v1) -> ((n_chunks, lanes, cap) buf, start, meta).

    Streams are right-aligned per cell (``start = cap - length``) so each
    chunk slice is directly consumable by ``coder.decoder_init``.  v1 blobs
    are presented as a single chunk of ``n_symbols`` symbols — the
    back-compat path for pre-chunking archives.

    This is :func:`parse_chunked` plus the dense right-align gather
    (:func:`_right_align_cells` — writers may order/pad payloads freely, so
    the gather goes through the index's per-cell offsets).  The zero-copy
    kernel decode path skips the gather entirely and consumes the
    :class:`ContainerSlab` directly.

    Corrupt input raises :class:`ValueError` naming the damaged cell or
    region (truncated header / index / payload span, CRC mismatch at a
    specific (chunk, lane)) — never a raw struct/numpy error and never a
    silently short stream.
    """
    cs = parse_chunked(blob)
    buf = _right_align_cells(cs.slab, cs.offset, cs.length, cs.cap)
    start = (cs.cap - cs.length).astype(np.int32)
    return buf, start, cs.meta


def slab_to_chunked(cs: ContainerSlab) -> ChunkedLanes:
    """Device-side ``ContainerSlab`` -> dense :class:`ChunkedLanes`.

    One jnp gather on-device (clip + mask, exactly the kernel's span-bounds
    clamp semantics: bytes outside a cell's span read 0) — used where a
    consumer needs the dense right-aligned form from a slab without ever
    touching host memory (the coder-backend differential paths).  The
    host-side analogue is :func:`_right_align_cells`.
    """
    slab, off, ln = _slab_i32(cs)
    n_chunks, lanes = cs.meta.n_chunks, cs.meta.lanes
    cap = cs.cap
    start = cap - ln
    if cap == 0 or slab.shape[0] == 0:
        buf = jnp.zeros((n_chunks, lanes, cap), _U8J)
        return ChunkedLanes(buf=buf, start=start, length=ln)
    col = jnp.arange(cap, dtype=_I32J)
    src = off[..., None] + (col - start[..., None])
    valid = col >= start[..., None]
    buf = jnp.where(valid, slab[jnp.clip(src, 0, slab.shape[0] - 1)],
                    _U8J(0))
    return ChunkedLanes(buf=buf, start=start, length=ln)


def chunk_encoded_from_slab(cs: ContainerSlab, c: int) -> EncodedLanes:
    """Device-side right-align of ONE chunk's cells -> :class:`EncodedLanes`.

    The serve loops consume chunks one at a time; this gathers chunk ``c``'s
    spans straight from the slab on-device (no host copy, no dense
    (n_chunks, lanes, cap) intermediate).
    """
    one = ContainerSlab(slab=cs.slab, offset=cs.offset[c:c + 1],
                        length=cs.length[c:c + 1], cap=cs.cap,
                        meta=cs.meta._replace(n_chunks=1))
    ch = slab_to_chunked(one)
    return EncodedLanes(buf=ch.buf[0], start=ch.start[0], length=ch.length[0])


def _slab_i32(cs: ContainerSlab) -> tuple[jax.Array, jax.Array, jax.Array]:
    """ContainerSlab planes as device arrays with int32-safe indices.

    The kernels (and jnp's default x64-off mode) index with int32, so a
    payload must fit in 2**31-1 bytes to take a device slab path; the
    validated spans guarantee every offset is <= payload length.
    """
    if cs.slab.shape[0] >= 2 ** 31:
        raise ValueError(
            f"container payload of {cs.slab.shape[0]} bytes exceeds the "
            "int32 index range of the device slab paths")
    return (jnp.asarray(cs.slab, _U8J),
            jnp.asarray(cs.offset.astype(np.int32)),
            jnp.asarray(cs.length.astype(np.int32)))


def compressed_size(length: np.ndarray) -> int:
    """Total v1 container size in bytes for reporting compression ratios."""
    lanes = len(length)
    return _HEADER.size + 4 * lanes + int(np.sum(length))


def compressed_size_chunked(length: np.ndarray, checksums: bool = True) -> int:
    """Total v2 container size: header + index table + payload bytes."""
    length = np.asarray(length)
    cell = _INDEX_V2C_DT.itemsize if checksums else _INDEX_V2.size
    return _HEADER_V2.size + cell * length.size + int(np.sum(length))
