"""Shared prediction-guided CDF search core (paper Sec. IV-C).

Single source of truth for the decoder's state-to-symbol inversion.  Every
decode backend in the repo — ``core.coder.decode_get`` (pure-JAX lanes),
``kernels.rans_decode`` (Pallas TPU kernel), and ``kernels.ref`` (the
per-kernel oracle, which delegates to the coder) — imports *this* module, so
decoded symbols and probe counters are structurally identical across
backends rather than merely tested equal.

Paper map:

  * **Sec. IV-C window gating** — :func:`find_symbol` with ``mu``/``delta``:
    the predictor's bracket ``[mu - delta, mu + delta]`` is verified against
    the CDF with one probe; on a hit the binary search starts from the
    narrowed bracket, on a miss it falls back to the full ``[0, K)`` range
    (the paper's bounded penalty — bit-exactness is never at risk, only the
    probe count changes).
  * **Fig. 2 trial-symbol path** — :func:`find_symbol` with ``candidates``:
    each speculated symbol is verified with a single O(1) CDF probe before
    any windowed/binary work (the model-top-k speculation of the serve
    pipeline).
  * **Fig. 4(b) counters** — the canonical probe accounting below.  The
    figure's unit is one CDF access; ``benchmarks/bench_search.py`` reports
    the 7.00 -> 3.15 search-step reduction from these counters regardless of
    which backend executed the decode.

Canonical probe accounting (normative — every backend must charge exactly
this; the differential tests assert per-lane integer equality):

  1. each candidate verify costs 1 probe per lane **not yet resolved**;
     lanes resolved by an earlier candidate stop paying;
  2. the window verify costs 1 probe per lane not resolved by candidate
     speculation — charged identically on a bracket hit and on a bracket
     miss (a miss buys nothing: the bracket stays ``[0, K)``);
  3. every **active** binary-search iteration costs 1 probe; the equality
     early-commit (``cdf[mid] == slot`` proves ``symbol == mid``) collapses
     the bracket so later iterations stop counting;
  4. the static-table LUT fast path costs exactly 1 probe (one gather).

The search is parameterized over the gather primitive because the two
backends address tables differently: the XLA path uses
:func:`take_gather` (``take_along_axis``, batch-aware) while the Pallas
kernels substitute one-hot contractions (``kernels.common.onehot_gather`` /
``onehot_gather_lanes``) — the TPU-native replacement for the RTL's table
SRAM port.  The search *logic* is identical either way.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_I32 = jnp.int32


def ceil_log2(k: int) -> int:
    """Fixed binary-search depth covering an alphabet of ``k`` symbols."""
    return max(1, (k - 1).bit_length())


def take_gather(field: jax.Array, x: jax.Array) -> jax.Array:
    """``field[..., x]`` for shared ``(K,)`` or per-lane ``(lanes, K)`` tables.

    The XLA-backend gather primitive; Pallas kernels pass their one-hot
    contraction equivalents instead.
    """
    if field.ndim == 1:
        return field[x]
    return jnp.take_along_axis(field, x[..., None].astype(_I32),
                               axis=-1)[..., 0]


def bsearch(cdf: jax.Array, slot: jax.Array, lo: jax.Array, hi: jax.Array,
            n_iter: int, gather=take_gather):
    """Masked fixed-depth binary search: find x with cdf[x] <= slot < cdf[x+1].

    Counts only the *active* iterations per lane — each one is a CDF probe,
    the unit of Fig. 4(b) (accounting rule 3 above).
    """
    steps = jnp.zeros_like(lo)
    for _ in range(n_iter):
        active = (hi - lo) > 1
        mid = (lo + hi) >> 1
        c_mid = gather(cdf, mid)
        # equality early-commit: cdf[mid] == slot proves symbol == mid
        # (f >= 1 guarantees slot < cdf[mid+1]); the bracket collapses and
        # later iterations stop counting — matches the paper's <log2|S|
        # baseline averages.
        eq = active & (c_mid == slot)
        go_right = c_mid <= slot
        lo = jnp.where(active & go_right, mid, lo)
        hi = jnp.where(eq, mid + 1, jnp.where(active & ~go_right, mid, hi))
        steps = steps + active.astype(_I32)
    return lo, steps


def find_symbol(cdf: jax.Array, k: int, slot: jax.Array,
                mu: jax.Array | None = None,
                delta=None,
                candidates: jax.Array | None = None,
                gather=take_gather):
    """State-to-symbol inversion with optional speculation (Sec. IV-C).

    ``cdf`` is the ``(..., K+1)`` exclusive prefix table (shared or
    per-lane, matching ``gather``); ``k`` the alphabet size; ``slot`` the
    ``(lanes,)`` low-bits slot of each lane's rANS state.  ``candidates``
    is a ``(lanes, topk)`` row of trial symbols (one row of the serve
    pipeline's ``(T, lanes, topk)`` model-top-k candidate planes); a
    zero-width row (``topk == 0``) is the explicit "no speculation" point
    of the decode-backend sweeps and costs nothing.

    Returns ``(symbol, probes)`` where ``probes`` charges CDF accesses per
    lane exactly per the canonical accounting in the module docstring.
    Fallback lanes pay the verify + the full search — the paper's "bounded
    penalty" — so the worst case equals the baseline binary search.
    """
    if candidates is not None and candidates.shape[-1] == 0:
        candidates = None
    lanes = slot.shape[0]
    lo0 = jnp.zeros((lanes,), _I32)
    hi0 = jnp.full((lanes,), k, _I32)
    probes = jnp.zeros((lanes,), _I32)
    found = jnp.zeros((lanes,), bool)
    x_spec = jnp.zeros((lanes,), _I32)

    # --- candidate speculation (model-top-k trial symbols, O(1) verify each)
    if candidates is not None:
        for j in range(candidates.shape[-1]):
            cand = jnp.clip(candidates[:, j].astype(_I32), 0, k - 1)
            ok = ((gather(cdf, cand) <= slot)
                  & (slot < gather(cdf, cand + 1)))
            probes = probes + (~found).astype(_I32)   # rule 1
            x_spec = jnp.where(~found & ok, cand, x_spec)
            found = found | ok

    # --- window-gated search (predictor bracket [mu-d, mu+d])
    if mu is not None:
        d = jnp.asarray(delta, _I32)
        lo_w = jnp.clip(mu.astype(_I32) - d, 0, k - 1)
        hi_w = jnp.clip(mu.astype(_I32) + d + 1, 1, k)
        hit = ((gather(cdf, lo_w) <= slot) & (slot < gather(cdf, hi_w))
               & ~found)
        probes = probes + (~found).astype(_I32)       # rule 2: verify probe
        lo0 = jnp.where(hit, lo_w, lo0)
        hi0 = jnp.where(hit, hi_w, hi0)

    # --- binary search over the (possibly narrowed) bracket
    lo0 = jnp.where(found, x_spec, lo0)
    hi0 = jnp.where(found, x_spec + 1, hi0)
    x, steps = bsearch(cdf, slot, lo0, hi0, ceil_log2(k), gather=gather)
    return x, probes + steps
