"""Vectorized multi-lane rANS coder (the RAS fabric, TPU-native).

This is the paper's Fig. 2 middle block re-derived for a SIMD machine:

  * **multi-lane fabric** (Sec. III): ``lanes`` independent rANS states are
    updated in lockstep as vectors; each lane owns a private byte stream
    (the RTL's per-lane MS/low-bit state memories become a ``(lanes, cap)``
    buffer with per-lane write pointers);
  * **two-stage update** (Sec. IV-B): the quotient path ``a1 = (s//f) << n``
    and remainder path ``a2 = (s mod f) + C`` are independent vector ops —
    we use the algebraically identical ryg form ``s + bias + q * cmpl``
    (bias folds C and the f==1 corner, cmpl = 2**n - f) so the hot loop is
    one mulhi, one shift, one madd;
  * **unified div/mod datapath** (Sec. IV-A): division is Barrett
    multiply-high against the SPC-precomputed reciprocal — exact for every
    state < 2**31 (property-swept in tests), no integer divide on the hot
    path;
  * **byte-level renormalization**: the data-dependent while-loop is a fixed
    ``MAX_RENORM_STEPS``(=2)-stage masked pipeline (provably sufficient,
    see core/constants.py) — the TPU analogue of the paper's staged renorm;
  * **prediction-guided decoding** (Sec. IV-C): window-gated binary search
    with verified fallback, plus the beyond-paper candidate (model-top-k)
    speculation; both leave the bitstream untouched and are instrumented to
    reproduce Fig. 4(b)'s search-step counts.

Bit-exactness contract: for identical tables, :func:`encode` produces byte
streams identical to ``core.golden`` / ``core.python_baseline``, and
:func:`decode` inverts them exactly.  Everything is jit/scan-compatible.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import search, update
from repro.core.bitstream import (ChunkedLanes, EncodedLanes,  # noqa: F401
                                  compact_records)
from repro.core.search import take_gather as _gather
from repro.core.spc import TableSet
from repro.core.update import barrett_div, umulhi32  # noqa: F401  (re-export)

_U32 = jnp.uint32
_U8 = jnp.uint8
_I32 = jnp.int32


# ---------------------------------------------------------------------------
# encoder — the two-stage update itself lives in core/update.py (single
# source, shared verbatim with the Pallas encode kernel); this layer owns
# the per-lane backward byte buffers the records land in.
# ---------------------------------------------------------------------------

class EncState(NamedTuple):
    """Multi-lane encoder state.  ``buf[lane, ptr[lane]:]`` is the stream
    (written backward so the decoder reads forward — rANS is LIFO)."""

    s: jax.Array     # (lanes,) uint32
    buf: jax.Array   # (lanes, cap) uint8
    ptr: jax.Array   # (lanes,) int32, next free slot - 1 is at ptr-1


def encoder_init(lanes: int, cap: int) -> EncState:
    return EncState(s=jnp.full((lanes,), C.RANS_L, _U32),
                    buf=jnp.zeros((lanes, cap), _U8),
                    ptr=jnp.full((lanes,), cap, _I32))


def _emit_backward(buf, ptr, byte, cond):
    """Masked one-byte backward emit; non-emitting lanes scatter out of
    bounds and are dropped (the RTL's lane clock gating).  Lanes whose
    cursor ran past the buffer head (cap overflow) also hit the drop
    sentinel — a negative scatter index would *wrap* under numpy semantics
    and silently corrupt the stream tail.  The cursor keeps decrementing so
    the caller can report the true byte need and flag the overflow."""
    lanes, cap = buf.shape
    lane_idx = jnp.arange(lanes)
    widx = jnp.where(cond & (ptr > 0), ptr - 1, cap)
    buf = buf.at[lane_idx, widx].set(byte, mode="drop")
    return buf, ptr - cond.astype(_I32)


def encode_put(st: EncState, x: jax.Array, tbl: TableSet) -> EncState:
    """Push one symbol per lane (Eq. 1 + two-stage renorm).

    Delegates the staged renorm + two-path update to
    :func:`repro.core.update.encode_step` (the single-source core shared
    with the Pallas kernel) and lands the emitted records backward in the
    per-lane buffers.
    """
    e = update.gather_encode_entry(tbl, x)
    s, recs = update.encode_step(st.s, e)
    buf, ptr = st.buf, st.ptr
    for byte, cond in recs:
        buf, ptr = _emit_backward(buf, ptr, byte, cond)
    return EncState(s, buf, ptr)


def encoder_flush(st: EncState) -> EncState:
    """Write the 4-byte big-endian final state header (read first on decode)."""
    s, buf, ptr = st.s, st.buf, st.ptr
    true = jnp.ones_like(s, bool)
    for shift in (0, 8, 16, 24):
        buf, ptr = _emit_backward(
            buf, ptr, ((s >> shift) & _U32(0xFF)).astype(_U8), true)
    return EncState(s, buf, ptr)


def default_cap(n_symbols: int) -> int:
    # worst case 2 bytes/symbol + 4-byte state header, padded for alignment
    return 2 * n_symbols + 8


@functools.partial(jax.jit, static_argnames=("cap",))
def encode_records(symbols: jax.Array, tbl: TableSet,
                   cap: int | None = None) -> EncodedLanes:
    """Scatter-free encode: §Perf hillclimb H2 (see EXPERIMENTS.md).

    The scan carries only the lane states and *stacks* fixed-shape renorm
    records as scan outputs (a sequential write, not a scatter); one
    vectorized compaction pass builds the byte streams.  Bit-identical to
    :func:`encode` (same emission order, same compaction as the Pallas
    kernel path).
    """
    lanes, t_len = symbols.shape
    cap = default_cap(t_len) if cap is None else cap
    per_position = tbl.freq.ndim in (2, 3) and tbl.freq.shape[0] == t_len

    def step(s, xs):
        if per_position:
            x_t, tbl_t = xs
        else:
            x_t, tbl_t = xs, tbl
        e = update.gather_encode_entry(tbl_t, x_t)
        s, recs = update.encode_step(s, e)
        (b0, c0), (b1, c1) = recs
        return s, (b0, c0, b1, c1)

    xs = (symbols.T, tbl) if per_position else symbols.T
    s0 = jnp.full((lanes,), C.RANS_L, _U32)
    s, (b0, c0, b1, c1) = jax.lax.scan(step, s0, xs, reverse=True)
    # stack into kernel-compatible (T, 2, lanes) records and compact
    bytes_rec = jnp.stack([b0, b1], axis=1)
    mask_rec = jnp.stack([c0, c1], axis=1).astype(_U8)
    return compact_records(bytes_rec, mask_rec, s, cap)


@functools.partial(jax.jit, static_argnames=("cap",))
def encode(symbols: jax.Array, tbl: TableSet,
           cap: int | None = None) -> EncodedLanes:
    """Encode ``(lanes, T)`` int symbols against shared tables ``(K,)``.

    Per-position tables: pass a TableSet whose fields have a leading T dim,
    matched to ``symbols.shape[1]`` (all lanes share position tables — the
    neural-prior layout where the model emits one distribution per step).
    """
    lanes, t_len = symbols.shape
    cap = default_cap(t_len) if cap is None else cap
    # per-position tables: leading T dim, rows either shared (T, K) or
    # per-lane (T, lanes, K) — the neural-prior layouts.
    per_position = tbl.freq.ndim in (2, 3) and tbl.freq.shape[0] == t_len

    def step(st, xs):
        if per_position:
            x_t, tbl_t = xs
            return encode_put(st, x_t, tbl_t), None
        return encode_put(st, xs, tbl), None

    xs = (symbols.T, tbl) if per_position else symbols.T  # scan over T
    st, _ = jax.lax.scan(step, encoder_init(lanes, cap), xs, reverse=True)
    st = encoder_flush(st)
    # a cursor past the buffer head means the stream did not fit `cap`:
    # the writes were dropped (never wrapped), length reports the need.
    return EncodedLanes(buf=st.buf, start=jnp.maximum(st.ptr, 0),
                        length=jnp.asarray(cap, _I32) - st.ptr,
                        overflow=st.ptr < 0)


# ---------------------------------------------------------------------------
# chunked streaming encode (independent per-chunk flush -> parallel decode)
# ---------------------------------------------------------------------------

def num_chunks(n_symbols: int, chunk_size: int) -> int:
    """Chunk count covering ``n_symbols`` (last chunk may be ragged)."""
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk_size}")
    return -(-n_symbols // chunk_size)


def chunk_lengths(n_symbols: int, chunk_size: int) -> list[int]:
    """Per-chunk symbol counts; all ``chunk_size`` except a ragged tail."""
    n = num_chunks(n_symbols, chunk_size)
    return [min(chunk_size, n_symbols - c * chunk_size) for c in range(n)]


def is_per_position(tbl: TableSet, t_len: int) -> bool:
    """True when the TableSet carries a leading per-position T dim."""
    return tbl.freq.ndim in (2, 3) and tbl.freq.shape[0] == t_len


def slice_tables(tbl: TableSet, t0: int, t1: int) -> TableSet:
    """Per-position table rows for the position range [t0, t1)."""
    return jax.tree.map(lambda a: a[t0:t1], tbl)


def chunk_tables(tbl: TableSet, n_full: int, chunk_size: int) -> TableSet:
    """Per-position tables -> chunk-major ``(n_full, chunk_size, ...)`` form
    (the layout both the vmap and shard_map chunk paths map over)."""
    return jax.tree.map(
        lambda a: a[:n_full * chunk_size].reshape(
            (n_full, chunk_size) + a.shape[1:]), tbl)


def chunk_encoded(enc: ChunkedLanes, c) -> EncodedLanes:
    """View chunk ``c`` as a standalone :class:`EncodedLanes`."""
    return EncodedLanes(buf=enc.buf[c], start=enc.start[c],
                        length=enc.length[c],
                        overflow=None if enc.overflow is None
                        else enc.overflow[c])


def encode_chunked(symbols: jax.Array, tbl: TableSet, chunk_size: int,
                   cap: int | None = None) -> ChunkedLanes:
    """Encode ``(lanes, T)`` as independent fixed-size chunks.

    Every chunk gets its own flush (4-byte state header) so the produced
    streams decode independently — the interleaved-ANS construction that
    turns the LIFO coder into a parallel/streaming one.  Bit-exactness
    contract: chunk ``c``'s bytes equal ``encode(symbols[:, c*S:(c+1)*S],
    tbl_c)`` exactly, where ``tbl_c`` is the matching per-position table
    slice (or the shared table).  The final chunk may be ragged
    (``T % chunk_size`` symbols); all chunks share one ``cap`` so the result
    is a single dense ``(n_chunks, lanes, cap)`` buffer.
    """
    lanes, t_len = symbols.shape
    n_total = num_chunks(t_len, chunk_size)
    n_full, tail_len = divmod(t_len, chunk_size)
    cap = default_cap(min(chunk_size, t_len)) if cap is None else cap
    per_position = is_per_position(tbl, t_len)

    if t_len == 0:  # degenerate: zero chunks, empty (0, lanes, cap) stream
        z = jnp.zeros((0, lanes), _I32)
        return ChunkedLanes(buf=jnp.zeros((0, lanes, cap), _U8),
                            start=z, length=z,
                            overflow=jnp.zeros((0, lanes), bool))

    parts = []
    if n_full:
        full = symbols[:, :n_full * chunk_size]
        full = full.reshape(lanes, n_full, chunk_size).swapaxes(0, 1)
        if per_position:
            enc = jax.vmap(lambda s, tb: encode(s, tb, cap=cap))(
                full, chunk_tables(tbl, n_full, chunk_size))
        else:
            enc = jax.vmap(lambda s: encode(s, tbl, cap=cap))(full)
        parts.append(enc)
    if tail_len:
        tbl_tail = (slice_tables(tbl, n_full * chunk_size, t_len)
                    if per_position else tbl)
        enc_tail = encode(symbols[:, n_full * chunk_size:], tbl_tail, cap=cap)
        parts.append(jax.tree.map(lambda a: a[None], enc_tail))
    out = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *parts)
    assert out.buf.shape[0] == n_total
    return ChunkedLanes(buf=out.buf, start=out.start, length=out.length,
                        overflow=out.overflow)


def decode_chunked(chunks: ChunkedLanes, n_symbols: int, tbl: TableSet,
                   chunk_size: int, prob_bits: int = C.PROB_BITS,
                   use_lut: bool = False, predictor=None,
                   lane_probes: bool = False,
                   candidates: jax.Array | None = None):
    """Decode a chunked stream; returns (symbols (lanes, T), avg_probes).

    Full-size chunks decode in parallel (vmap over the chunk axis — see
    ``repro.parallel.chunked`` for the multi-device shard_map version); the
    ragged tail, if any, decodes standalone.  Bit-exact inverse of
    :func:`encode_chunked`.  ``predictor`` drives prediction-guided search
    inside every chunk (context resets at chunk boundaries — the chunks are
    independent streams); ``lane_probes`` also returns the per-lane probe
    totals summed across chunks.  ``candidates`` is an optional
    ``(T, lanes, topk)`` model-top-k candidate plane, cut chunk-major like
    the per-position tables (rows [c*S, c*S+n) speculate chunk ``c``).
    """
    n_total = num_chunks(n_symbols, chunk_size)
    if chunks.buf.shape[0] != n_total:
        raise ValueError(
            f"stream has {chunks.buf.shape[0]} chunks but n_symbols="
            f"{n_symbols} at chunk_size={chunk_size} implies {n_total}; "
            "decode with the chunk_size the stream was encoded with")
    n_full, tail_len = divmod(n_symbols, chunk_size)
    per_position = is_per_position(tbl, n_symbols)
    if candidates is not None and candidates.shape[-1] == 0:
        candidates = None

    if n_symbols == 0:  # degenerate: no chunks to decode
        lanes = chunks.buf.shape[1] if chunks.buf.ndim == 3 else 0
        out = (jnp.zeros((lanes, 0), _I32), jnp.float32(0.0))
        return out + (jnp.zeros((lanes,), _I32),) if lane_probes else out

    syms, probe_sums, lane_sums, unders = [], [], [], []
    if n_full:
        sub = jax.tree.map(lambda a: a[:n_full], chunks)
        cand_full = (candidates[:n_full * chunk_size].reshape(
            (n_full, chunk_size) + candidates.shape[1:])
            if candidates is not None else None)
        if per_position:
            dec = jax.vmap(
                lambda e, tb, cd: decode(EncodedLanes(*e), chunk_size, tb,
                                         prob_bits, predictor=predictor,
                                         use_lut=use_lut,
                                         lane_probes=lane_probes,
                                         candidates=cd,
                                         return_exhausted=True))(
                sub, chunk_tables(tbl, n_full, chunk_size), cand_full)
        else:
            dec = jax.vmap(
                lambda e, cd: decode(EncodedLanes(*e), chunk_size, tbl,
                                     prob_bits, predictor=predictor,
                                     use_lut=use_lut,
                                     lane_probes=lane_probes,
                                     candidates=cd,
                                     return_exhausted=True))(sub, cand_full)
        if lane_probes:
            sym_full, probes_full, lp_full, und_full = dec
            lane_sums.append(jnp.sum(lp_full, axis=0))
        else:
            sym_full, probes_full, und_full = dec  # (n_full, lanes, S), ...
        unders.append(jnp.any(und_full, axis=0))
        lanes = sym_full.shape[1]
        syms.append(sym_full.swapaxes(0, 1).reshape(
            lanes, n_full * chunk_size))
        probe_sums.append(jnp.sum(probes_full) * chunk_size)
    if tail_len:
        tbl_tail = (slice_tables(tbl, n_full * chunk_size, n_symbols)
                    if per_position else tbl)
        dec_tail = decode(
            chunk_encoded(chunks, n_full), tail_len, tbl_tail, prob_bits,
            predictor=predictor, use_lut=use_lut, lane_probes=lane_probes,
            candidates=(candidates[n_full * chunk_size:]
                        if candidates is not None else None),
            return_exhausted=True)
        if lane_probes:
            sym_tail, probes_tail, lp_tail, und_tail = dec_tail
            lane_sums.append(lp_tail)
        else:
            sym_tail, probes_tail, und_tail = dec_tail
        unders.append(und_tail)
        syms.append(sym_tail)
        probe_sums.append(probes_tail * tail_len)
    under = functools.reduce(jnp.logical_or, unders)
    _check_exhausted(under, "decode_chunked")
    out = jnp.concatenate(syms, axis=1)
    avg_probes = sum(probe_sums) / n_symbols
    if lane_probes:
        return out, avg_probes, sum(lane_sums)
    return out, avg_probes


# ---------------------------------------------------------------------------
# decoder
# ---------------------------------------------------------------------------

class StreamExhaustedError(ValueError):
    """Decode read past the end of a lane's byte window.

    Raised on every *host* decode path when more symbols are requested than
    the stream encodes (or the stream was truncated).  Inside traced
    contexts the condition travels as the per-lane ``DecState.underflow``
    flag instead (checked by the caller once values are concrete)."""


def _check_exhausted(underflow, where: str = "decode") -> None:
    """Host-side gate on the per-lane underflow flag (no-op on tracers)."""
    if underflow is None or isinstance(underflow, jax.core.Tracer):
        return
    u = np.asarray(underflow)
    if u.any():
        bad = np.nonzero(u.reshape(-1))[0].tolist()
        raise StreamExhaustedError(
            f"{where}: {int(u.sum())} lane stream(s) exhausted mid-decode "
            f"(flat lane indices {bad[:16]}{'...' if len(bad) > 16 else ''}) "
            "— more symbols were requested than the stream encodes, or the "
            "stream is truncated; symbols past that point are garbage")


class DecState(NamedTuple):
    s: jax.Array    # (lanes,) uint32
    ptr: jax.Array  # (lanes,) int32 read cursor into buf
    # (lanes,) bool, True once a lane read past its byte window.  Optional
    # (None == all clear) so positional DecState(s, ptr) callers keep working.
    underflow: jax.Array | None = None


def _read_byte(buf, lane_idx, ptr, cap):
    """One guarded forward byte read: out-of-window reads yield 0 (matching
    the kernels' one-hot gather semantics) and report the violation."""
    oob = (ptr < 0) | (ptr >= cap)
    byte = buf[lane_idx, jnp.clip(ptr, 0, cap - 1)].astype(_U32)
    return jnp.where(oob, _U32(0), byte), oob


def decoder_init(enc: EncodedLanes) -> DecState:
    lanes, cap = enc.buf.shape
    lane_idx = jnp.arange(lanes)
    s = jnp.zeros((lanes,), _U32)
    ptr = enc.start
    under = jnp.zeros((lanes,), bool)
    for _ in range(4):
        byte, oob = _read_byte(enc.buf, lane_idx, ptr, cap)
        under = under | oob
        s = (s << 8) | byte
        ptr = ptr + 1
    return DecState(s=s, ptr=ptr, underflow=under)


def find_symbol(tbl: TableSet, slot: jax.Array,
                mu: jax.Array | None = None,
                delta: int | jax.Array | None = None,
                candidates: jax.Array | None = None):
    """State-to-symbol inversion (Sec. IV-C) — delegates to ``core.search``.

    The search itself (window gating, candidate speculation, fixed-depth
    binary search) and the canonical Fig. 4(b) probe accounting live in
    :mod:`repro.core.search`, shared verbatim with the Pallas decode kernel.
    """
    return search.find_symbol(tbl.cdf, tbl.alphabet_size, slot,
                              mu=mu, delta=delta, candidates=candidates)


def decode_get(st: DecState, buf: jax.Array, tbl: TableSet,
               prob_bits: int = C.PROB_BITS,
               mu: jax.Array | None = None,
               delta: int | jax.Array | None = None,
               candidates: jax.Array | None = None,
               lut: jax.Array | None = None):
    """Pop one symbol per lane.  Returns (state', symbol, probes).

    ``lut``: optional 2**prob_bits slot->symbol table (spc.decode_lut) —
    beyond-paper O(1) inversion for *static* tables: one gather replaces
    the whole CDF search (§Perf hillclimb H3).
    """
    lanes, cap = buf.shape
    lane_idx = jnp.arange(lanes)
    mask = _U32((1 << prob_bits) - 1)
    s, ptr = st.s, st.ptr

    slot = s & mask
    if lut is not None:
        x = lut[slot].astype(_I32)
        probes = jnp.ones((lanes,), _I32)
    else:
        x, probes = find_symbol(tbl, slot, mu=mu, delta=delta,
                                candidates=candidates)
    f = _gather(tbl.freq, x)
    start = _gather(tbl.cdf[..., :-1], x)
    s = f * (s >> prob_bits) + slot - start
    under = (jnp.zeros((lanes,), bool) if st.underflow is None
             else st.underflow)
    # fixed 2-step masked byte refill; a refill that would read past the
    # window injects 0 and raises the lane's underflow flag instead of
    # silently re-reading the final byte.
    for _ in range(C.MAX_RENORM_STEPS):
        cond = s < _U32(C.RANS_L)
        byte, oob = _read_byte(buf, lane_idx, ptr, cap)
        under = under | (cond & oob)
        s = jnp.where(cond, (s << C.RENORM_SHIFT) | byte, s)
        ptr = ptr + cond.astype(_I32)
    return DecState(s, ptr, under), x, probes


@functools.partial(jax.jit, static_argnames=("n_symbols", "prob_bits",
                                             "predictor", "use_lut",
                                             "lane_probes"))
def _decode_traced(enc: EncodedLanes, n_symbols: int, tbl: TableSet,
                   prob_bits: int = C.PROB_BITS, predictor=None,
                   use_lut: bool = False, lane_probes: bool = False,
                   candidates: jax.Array | None = None):
    """Decode ``n_symbols`` per lane.  Returns (symbols (lanes,T), avg_probes).

    ``predictor`` is one of core.predictors (hashable NamedTuple of static
    config) driving prediction-guided decoding; None = baseline full binary
    search.  Per-position tables: TableSet with leading T dim as in encode.
    ``use_lut``: static tables only — O(1) slot->symbol inversion.
    ``lane_probes``: also return the per-lane probe totals ``(lanes,)`` int32
    — the raw Fig. 4(b) counters the cross-backend differential tests pin.
    ``candidates``: optional ``(T, lanes, topk)`` plane of model-top-k trial
    symbols (the serve pipeline's candidate speculation), scanned row-by-row
    into :func:`decode_get` — the pure-JAX reference for the kernel's
    candidate-plane input (topk == 0 disables speculation).
    """
    lanes = enc.buf.shape[0]
    per_position = (tbl.freq.ndim in (2, 3)
                    and tbl.freq.shape[0] == n_symbols)
    if candidates is not None and candidates.shape[-1] == 0:
        candidates = None
    if candidates is not None and candidates.shape[:2] != (n_symbols, lanes):
        raise ValueError(
            f"candidate planes must be (T, lanes, topk)=({n_symbols}, "
            f"{lanes}, *); got {candidates.shape}")
    ctx0 = predictor.init(lanes) if predictor is not None else jnp.zeros((lanes, 0), _I32)
    lut = None
    if use_lut:
        assert not per_position, "LUT path requires a static table"
        if candidates is not None:
            raise ValueError("use_lut and candidate planes are exclusive: "
                             "the LUT already inverts in one probe")
        from repro.core.spc import decode_lut
        lut = decode_lut(tbl, prob_bits)

    def step(carry, xs):
        st, ctx = carry
        tbl_t, cand_t = xs
        t = tbl if not per_position else tbl_t
        if predictor is not None:
            pred = predictor.predict(ctx)
            cands = cand_t if cand_t is not None else pred.candidates
            st, x, probes = decode_get(st, enc.buf, t, prob_bits,
                                       mu=pred.mu, delta=pred.delta,
                                       candidates=cands)
            ctx = predictor.update(ctx, x)
        else:
            st, x, probes = decode_get(st, enc.buf, t, prob_bits, lut=lut,
                                       candidates=cand_t)
        return (st, ctx), (x, probes)

    xs = (tbl if per_position else None,
          candidates.astype(_I32) if candidates is not None else None)
    (st_f, _), (sym_t, probes_t) = jax.lax.scan(
        step, (decoder_init(enc), ctx0), xs, length=n_symbols)
    avg_probes = (jnp.mean(probes_t.astype(jnp.float32)) if n_symbols
                  else jnp.float32(0.0))
    if lane_probes:
        return sym_t.T, avg_probes, jnp.sum(probes_t, axis=0), st_f.underflow
    return sym_t.T, avg_probes, st_f.underflow


def decode(enc: EncodedLanes, n_symbols: int, tbl: TableSet,
           prob_bits: int = C.PROB_BITS, predictor=None,
           use_lut: bool = False, lane_probes: bool = False,
           candidates: jax.Array | None = None,
           return_exhausted: bool = False):
    """Host entry around :func:`_decode_traced`.

    Same return shape as before (``(symbols, avg_probes[, lane_probes])``)
    but raises :class:`StreamExhaustedError` when any lane decoded past the
    end of its byte window — unless ``return_exhausted`` is set, in which
    case the per-lane flag is appended instead (the traced-caller form:
    vmap/shard_map bodies cannot raise, so they thread the flag out).
    """
    out = _decode_traced(enc, n_symbols, tbl, prob_bits, predictor,
                         use_lut, lane_probes, candidates)
    *vals, under = out
    if return_exhausted:
        return (*vals, under)
    _check_exhausted(under)
    return tuple(vals)
