"""GQA attention: self/cross, naive & blockwise(flash-style), KV/ring caches.

TP mapping (DESIGN.md §5): q/out heads are padded to a multiple of ``cfg.tp``
and sharded over the model axis; kv projections shard only when
``n_kv_heads % tp == 0`` (else they replicate over model and FSDP-shard over
data).  Padded q heads are zero-initialized in both wq and wo so the function
equals the true-head architecture at init.

Two attention schedules:
  * ``naive``     — full (B,H,Sq,Skv) score tensor; baseline for roofline.
  * ``blockwise`` — lax.scan over KV chunks with online softmax (flash-style
    in pure XLA); the memory-roofline lever for the 32k shapes.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rmsnorm
from repro.models.param import ParamDef

_NEG = -1e30

# Ring-reduction tile (slots).  The decode softmax/value reduction runs in
# fixed tiles of this many cache slots, accumulated sequentially, so the
# reduction tree is a function of slot *content* only — never of the ring
# length.  See _ring_blocks below for why that invariance is load-bearing.
_RING_BLOCK = 32


def _ring_blocks(x: jax.Array, axis: int) -> jax.Array:
    """Zero-pad ``axis`` to a multiple of ``_RING_BLOCK`` and split it into
    a leading scan axis of ``(n_blocks, ..., _RING_BLOCK, ...)`` tiles.

    §Bit-exactness: XLA retiles a fused reduction with the extent of the
    reduced axis, so the *same* 16 live cache slots summed under a 20-slot
    vs a 32-slot ring round differently (~1 ulp).  One ulp is enough to
    flip a quantized coding table entry, and a flipped table desyncs the
    batched engine's rANS decode from the single-request encode it must be
    byte-identical to.  Scanning fixed-size tiles pins every reduction tree:
    a longer ring only appends all-zero tiles, each contributing an exact
    +0.0 to the running accumulator.
    """
    n = x.shape[axis]
    nb = -(-n // _RING_BLOCK)
    pad = nb * _RING_BLOCK - n
    ax = axis % x.ndim
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[ax] = (0, pad)
        x = jnp.pad(x, widths)
    x = x.reshape(x.shape[:ax] + (nb, _RING_BLOCK) + x.shape[ax + 1:])
    return jnp.moveaxis(x, ax, 0)


def _ring_attn(prob: jax.Array, v: jax.Array, contract) -> jax.Array:
    """Ring-length-invariant ``contract(prob, v)`` summed over cache tiles.

    ``prob`` carries the cache axis last, ``v`` carries it at axis 1;
    ``contract`` reduces one ``_RING_BLOCK`` tile pair.  Invalid slots must
    already hold exact zeros in ``prob`` (padding adds more zeros).
    """
    pb = _ring_blocks(prob, -1)
    vb = _ring_blocks(v, 1)
    out0 = jax.eval_shape(contract, pb[0], vb[0])

    def body(acc, xs):
        return acc + contract(*xs), None

    acc, _ = jax.lax.scan(body, jnp.zeros(out0.shape, out0.dtype), (pb, vb))
    return acc


def _ring_sum(e: jax.Array) -> jax.Array:
    """Ring-length-invariant sum of ``e`` over its last (cache) axis."""
    eb = _ring_blocks(e, -1)

    def body(acc, blk):
        return acc + jnp.sum(blk, axis=-1), None

    acc, _ = jax.lax.scan(body, jnp.zeros(e.shape[:-1], e.dtype), eb)
    return acc


def kv_head_map(cfg: ModelConfig) -> np.ndarray:
    """Static q-head -> kv-head index map (GQA groups; padded heads -> 0)."""
    h, kv, hp = cfg.n_heads, cfg.n_kv_heads, cfg.n_heads_padded
    g = h // kv
    return np.asarray([min(i // g, kv - 1) for i in range(h)]
                      + [0] * (hp - h), np.int32)


def make_attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.head_dim_
    hp, kv = cfg.n_heads_padded, cfg.n_kv_heads
    kv_axis = "kv_heads" if cfg.kv_sharded else "kv_heads_repl"
    out = {
        "wq": ParamDef((d, hp, dh), ("embed", "heads", None),
                       true_sizes=(None, cfg.n_heads, None)),
        "wk": ParamDef((d, kv, dh), ("embed", kv_axis, None)),
        "wv": ParamDef((d, kv, dh), ("embed", kv_axis, None)),
        "wo": ParamDef((hp, dh, d), ("heads", None, "embed"),
                       true_sizes=(cfg.n_heads, None, None)),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((hp, dh), ("heads", None), init="zeros")
        out["bk"] = ParamDef((kv, dh), (kv_axis, None), init="zeros")
        out["bv"] = ParamDef((kv, dh), (kv_axis, None), init="zeros")
    if cfg.qk_norm:
        out["q_norm"] = ParamDef((dh,), (None,), init="ones")
        out["k_norm"] = ParamDef((dh,), (None,), init="ones")
    return out


def _project_qkv(p: dict, x: jax.Array, cfg: ModelConfig,
                 mem: jax.Array | None = None):
    """x -> q (B,S,Hp,Dh); kv source is ``mem`` for cross attention."""
    src = x if mem is None else mem
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    return q, k, v


def _expand_kv(k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B,S,KV,Dh) -> (B,S,Hp,Dh) via the static GQA head map."""
    if k.shape[2] == cfg.n_heads_padded:
        return k
    return jnp.take(k, jnp.asarray(kv_head_map(cfg)), axis=2)


def _mask(q_idx, kv_idx, causal: bool, window: int):
    ok = jnp.ones(jnp.broadcast_shapes(q_idx.shape, kv_idx.shape), bool)
    if causal:
        ok &= kv_idx <= q_idx
    if window:
        ok &= kv_idx > q_idx - window
    return ok


def _naive_attn(q, k, v, causal, window, q_offset=0):
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    # bf16 operands, f32 accumulation (MXU-native); no f32 copies of q/k —
    # §Perf memory-term lever (bit-identical: bf16 products are exact in f32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    q_idx = (jnp.arange(sq) + q_offset)[:, None]
    kv_idx = jnp.arange(skv)[None, :]
    s = jnp.where(_mask(q_idx, kv_idx, causal, window)[None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _blockwise_attn(q, k, v, causal, window, block, q_offset=0):
    """Flash-style online-softmax scan over KV chunks (pure XLA)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    blk = min(block, skv)
    n_chunks = math.ceil(skv / blk)
    pad = n_chunks * blk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, blk, h, dh).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, blk, h, dh).swapaxes(0, 1)
    scale = 1.0 / math.sqrt(dh)
    q_idx = (jnp.arange(sq) + q_offset)[:, None]

    def step(carry, xs):
        m, l, acc = carry
        j, kj, vj = xs
        kv_idx = j * blk + jnp.arange(blk)[None, :]
        ok = _mask(q_idx, kv_idx, causal, window)          # (Sq, blk)
        ok = ok & (kv_idx < skv)                           # kv padding
        # bf16-in / f32-accumulate: no materialized f32 q/k/v copies
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(ok[None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None]) * ok[None, None]
        l = l * corr + jnp.sum(p, axis=-1)
        acc = (acc * corr[..., None]
               + jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vj,
                            preferred_element_type=jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)


def attn_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                 mem: jax.Array | None = None,
                 window: int | None = None,
                 positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence attention (training / prefill).  Cross if mem given."""
    cross = mem is not None
    q, k, v = _project_qkv(p, x, cfg, mem)
    if not cross:
        pos = (positions if positions is not None
               else jnp.arange(x.shape[1])[None, :])
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k = _expand_kv(k, cfg)
    v = _expand_kv(v, cfg)
    win = cfg.sliding_window if window is None else window
    causal = not cross
    if cfg.attn_impl == "blockwise":
        out = _blockwise_attn(q, k, v, causal, win, cfg.attn_block)
    else:
        out = _naive_attn(q, k, v, causal, win)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# KV cache (decode) — linear or ring-buffer (local attention)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.head_dim_
    return {"k": jnp.zeros((batch, max_len, kv, dh), dtype),
            "v": jnp.zeros((batch, max_len, kv, dh), dtype)}


def _attend_slots(q: jax.Array, ck: jax.Array, cv: jax.Array,
                  valid: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Single-position ring attention core: q (B,1,Hp,Dh) against the cache
    (B,R,KV,Dh) under a (B|1, R) slot-validity mask -> (B,1,Hp,Dh).

    This is the ONE implementation of the decode score/softmax/value chain.
    Both the step path (:func:`attn_decode`) and the prefill fast path
    (:func:`attn_prefill`, which ``lax.map``s it over chunk positions) go
    through it with identical q-extent-1 shapes — a multi-query einsum
    rounds ~1 ulp differently than S single-query ones, which is enough to
    flip a quantized coding table, so the shapes must literally match.
    """
    scale = 1.0 / math.sqrt(cfg.head_dim_)
    hp, kv = cfg.n_heads_padded, cfg.n_kv_heads
    grouped = kv > 0 and hp % kv == 0
    # Scores are computed per _RING_BLOCK tile of cache slots: a full-width
    # GEMM rounds its remainder columns (cache_len % vector width) through a
    # different instruction path, so the same slot's score drifts ~1 ulp
    # with the ring length.  Per-tile GEMMs have one fixed shape.
    if grouped:
        # §Perf: grouped GQA decode — contract q-head groups against the kv
        # cache directly, never materializing the (S, H) expanded cache
        # (16x the cache bytes for kv=8, H=128).
        g = hp // kv
        qg = q.reshape(q.shape[0], 1, kv, g, q.shape[-1])
        sb = jax.lax.map(
            lambda kb: jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                                  preferred_element_type=jnp.float32),
            _ring_blocks(ck, 1))
    else:
        sb = jax.lax.map(
            lambda kb: jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                                  preferred_element_type=jnp.float32),
            _ring_blocks(_expand_kv(ck, cfg), 1))
    # (nb, ..., BLOCK) -> (..., nb * BLOCK): the padded cache axis
    sb = jnp.moveaxis(sb, 0, -2)
    s = sb.reshape(sb.shape[:-2] + (-1,)) * scale
    # Slots past cache_len are tile padding: never valid.
    validp = jnp.pad(valid, ((0, 0), (0, s.shape[-1] - valid.shape[-1])))
    vshape = (validp.shape[0],) + (1,) * (s.ndim - 2) + (s.shape[-1],)
    vmask = validp.reshape(vshape)
    s = jnp.where(vmask, s, _NEG)
    # Ring-length-invariant softmax: max is exactly associative, exp is
    # elementwise, and the two reductions (denominator, weighted values)
    # run over fixed slot tiles — see _ring_blocks.  Invalid slots are
    # forced to an exact 0.0 weight rather than trusting exp underflow.
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.where(vmask, jnp.exp(s - m), 0.0)
    prob = (e / _ring_sum(e)[..., None]).astype(q.dtype)
    if grouped:
        out = _ring_attn(prob, cv,
                         lambda pb, vb: jnp.einsum("bhgqk,bkhd->bqhgd",
                                                   pb, vb))
        return out.reshape(out.shape[0], 1, hp, out.shape[-1])
    return _ring_attn(prob, _expand_kv(cv, cfg),
                      lambda pb, vb: jnp.einsum("bhqk,bkhd->bqhd", pb, vb))


def attn_decode(p: dict, x1: jax.Array, cache: dict, pos: jax.Array,
                cfg: ModelConfig, mem: jax.Array | None = None,
                window: int | None = None):
    """One-token decode.  x1: (B,1,D); pos: absolute position — a scalar
    int32, or a ``(B,)`` vector of per-row positions (the batched serve
    engine's continuous-batching slots: every row advances its own ring
    independently).  The scalar path is float-identical to the vector path
    with a constant vector (same broadcasted graph, one row of masks).

    With ``window`` (or cfg.sliding_window/local_window) and a cache sized
    to the window, indexing is a ring buffer — O(window) memory at 500k+
    context.  A cache shorter than the sequence *always* rings (slot =
    pos % cache_len; entries older than cache_len are overwritten and
    masked out by age), window or not — the engine's shared-cache wrap
    contract, pinned logit-level in tests/test_serve_engine.py.
    Cross-attention decodes against full ``mem`` (no cache).
    """
    if mem is not None:
        q, k, v = _project_qkv(p, x1, cfg, mem)
        k = _expand_kv(k, cfg)
        v = _expand_kv(v, cfg)
        out = _naive_attn(q, k, v, causal=False, window=0)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    q, k, v = _project_qkv(p, x1, cfg, None)
    pos_v = jnp.asarray(pos)
    # rows: (B,) per-row positions, or a broadcast (1,) row for scalar pos
    pos_b = pos_v if pos_v.ndim == 1 else pos_v[None]
    q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
    k = apply_rope(k, pos_b[:, None], cfg.rope_theta)
    cache_len = cache["k"].shape[1]
    slot = pos_b % cache_len
    # §Perf (llama3-405b decode_32k): masked ring write instead of
    # dynamic_update_slice — elementwise select keeps the context-parallel
    # cache sharded (DUS at a traced offset forced SPMD to materialize the
    # full cache per chip: 2x cache temp + reshard).
    hot = (jnp.arange(cache_len)[None, :] == slot[:, None])[:, :, None, None]
    ck = jnp.where(hot, k.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(hot, v.astype(cache["v"].dtype), cache["v"])

    idx = jnp.arange(cache_len)
    # Unified ring semantics (covers the linear cache too, where slot == pos):
    # age of the entry in each slot; unwritten slots have age > pos.
    # Per-row when pos is a vector — each batch row masks its own ring.
    age = (slot[:, None] - idx[None, :]) % cache_len      # (1|B, cache_len)
    valid = age <= pos_b[:, None]
    win = window if window is not None else (cfg.local_window
                                             or cfg.sliding_window)
    if win:
        valid &= age < win
    out = _attend_slots(q, ck, cv, valid, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}


def attn_prefill(p: dict, xs: jax.Array, cache: dict, pos0: jax.Array,
                 n_valid: jax.Array, cfg: ModelConfig,
                 window: int | None = None):
    """Teacher-forced multi-position decode: one block-parallel pass over
    ``S`` positions per row, bit-identical to ``S`` sequential
    :func:`attn_decode` steps.  ``xs``: (B,S,D); ``pos0``/``n_valid``: (B,)
    per-row chunk start and live step count (rows beyond ``n_valid`` are
    frozen — their queries are computed and discarded, nothing is written).

    Identity argument: projections/norms are batch-extent-independent on
    the target backend (each output element of a GEMM/rmsnorm is its own
    fixed-order reduction), and the attend itself runs the SAME q-extent-1
    :func:`_attend_slots` core as the step path, ``lax.map``-ed over the S
    positions — a multi-query score/value einsum rounds ~1 ulp differently
    than S single-query ones, so the shapes must literally match.  The one
    structural divergence — this writes all S entries before any query
    attends — is masked out: a future in-chunk entry is ``valid=False``
    for earlier queries exactly where the step path would have seen a dead
    zero slot.  That argument needs ``pos0 + S <= cache_len`` (no slot
    still visible to a query is overwritten); callers gate wrapped streams
    to the step path.
    """
    q, k, v = _project_qkv(p, xs, cfg, None)
    S = xs.shape[1]
    pq = pos0[:, None] + jnp.minimum(jnp.arange(S)[None, :],
                                     n_valid[:, None])          # (B, S)
    q = apply_rope(q, pq, cfg.rope_theta)
    k = apply_rope(k, pq, cfg.rope_theta)
    cache_len = cache["k"].shape[1]
    offs = (jnp.arange(cache_len)[None, :] - pos0[:, None]) % cache_len
    wr = offs < n_valid[:, None]                                # (B, R)
    src = jnp.minimum(offs, S - 1)
    knew = jnp.take_along_axis(k, src[..., None, None], axis=1)
    vnew = jnp.take_along_axis(v, src[..., None, None], axis=1)
    ck = jnp.where(wr[..., None, None], knew.astype(cache["k"].dtype),
                   cache["k"])
    cv = jnp.where(wr[..., None, None], vnew.astype(cache["v"].dtype),
                   cache["v"])
    # absolute position each slot holds after the chunk's writes; slots the
    # chunk left alone hold pre-chunk entries (negative = never written)
    spos = jnp.where(wr, pos0[:, None] + offs,
                     pos0[:, None] - cache_len + offs)          # (B, R)
    valid = ((spos[:, None, :] <= pq[:, :, None])
             & (spos[:, None, :] >= 0))                         # (B, S, R)
    win = window if window is not None else (cfg.local_window
                                             or cfg.sliding_window)
    if win:
        valid &= (pq[:, :, None] - spos[:, None, :]) < win

    # Per-position attend at the step path's exact q-extent-1 shapes; only
    # the O(S·R) attend loops — the O(S·D²) projections/norms stay batched,
    # which is where the prefill speedup lives.
    def one_pos(xs_t):
        q1, val = xs_t                                      # (B,Hp,Dh),(B,R)
        return _attend_slots(q1[:, None], ck, cv, val, cfg)[:, 0]

    out = jax.lax.map(one_pos, (jnp.moveaxis(q, 1, 0),
                                jnp.moveaxis(valid, 1, 0)))
    out = jnp.moveaxis(out, 0, 1)                           # (B,S,Hp,Dh)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}
