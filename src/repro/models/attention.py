"""GQA attention: self/cross, naive & blockwise(flash-style), KV/ring caches.

TP mapping (DESIGN.md §5): q/out heads are padded to a multiple of ``cfg.tp``
and sharded over the model axis; kv projections shard only when
``n_kv_heads % tp == 0`` (else they replicate over model and FSDP-shard over
data).  Padded q heads are zero-initialized in both wq and wo so the function
equals the true-head architecture at init.

Two attention schedules:
  * ``naive``     — full (B,H,Sq,Skv) score tensor; baseline for roofline.
  * ``blockwise`` — lax.scan over KV chunks with online softmax (flash-style
    in pure XLA); the memory-roofline lever for the 32k shapes.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rmsnorm
from repro.models.param import ParamDef

_NEG = -1e30


def kv_head_map(cfg: ModelConfig) -> np.ndarray:
    """Static q-head -> kv-head index map (GQA groups; padded heads -> 0)."""
    h, kv, hp = cfg.n_heads, cfg.n_kv_heads, cfg.n_heads_padded
    g = h // kv
    return np.asarray([min(i // g, kv - 1) for i in range(h)]
                      + [0] * (hp - h), np.int32)


def make_attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.head_dim_
    hp, kv = cfg.n_heads_padded, cfg.n_kv_heads
    kv_axis = "kv_heads" if cfg.kv_sharded else "kv_heads_repl"
    out = {
        "wq": ParamDef((d, hp, dh), ("embed", "heads", None),
                       true_sizes=(None, cfg.n_heads, None)),
        "wk": ParamDef((d, kv, dh), ("embed", kv_axis, None)),
        "wv": ParamDef((d, kv, dh), ("embed", kv_axis, None)),
        "wo": ParamDef((hp, dh, d), ("heads", None, "embed"),
                       true_sizes=(cfg.n_heads, None, None)),
    }
    if cfg.qkv_bias:
        out["bq"] = ParamDef((hp, dh), ("heads", None), init="zeros")
        out["bk"] = ParamDef((kv, dh), (kv_axis, None), init="zeros")
        out["bv"] = ParamDef((kv, dh), (kv_axis, None), init="zeros")
    if cfg.qk_norm:
        out["q_norm"] = ParamDef((dh,), (None,), init="ones")
        out["k_norm"] = ParamDef((dh,), (None,), init="ones")
    return out


def _project_qkv(p: dict, x: jax.Array, cfg: ModelConfig,
                 mem: jax.Array | None = None):
    """x -> q (B,S,Hp,Dh); kv source is ``mem`` for cross attention."""
    src = x if mem is None else mem
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm:
        q = rmsnorm({"scale": p["q_norm"]}, q, cfg.norm_eps)
        k = rmsnorm({"scale": p["k_norm"]}, k, cfg.norm_eps)
    return q, k, v


def _expand_kv(k: jax.Array, cfg: ModelConfig) -> jax.Array:
    """(B,S,KV,Dh) -> (B,S,Hp,Dh) via the static GQA head map."""
    if k.shape[2] == cfg.n_heads_padded:
        return k
    return jnp.take(k, jnp.asarray(kv_head_map(cfg)), axis=2)


def _mask(q_idx, kv_idx, causal: bool, window: int):
    ok = jnp.ones(jnp.broadcast_shapes(q_idx.shape, kv_idx.shape), bool)
    if causal:
        ok &= kv_idx <= q_idx
    if window:
        ok &= kv_idx > q_idx - window
    return ok


def _naive_attn(q, k, v, causal, window, q_offset=0):
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / math.sqrt(dh)
    # bf16 operands, f32 accumulation (MXU-native); no f32 copies of q/k —
    # §Perf memory-term lever (bit-identical: bf16 products are exact in f32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    q_idx = (jnp.arange(sq) + q_offset)[:, None]
    kv_idx = jnp.arange(skv)[None, :]
    s = jnp.where(_mask(q_idx, kv_idx, causal, window)[None, None], s, _NEG)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def _blockwise_attn(q, k, v, causal, window, block, q_offset=0):
    """Flash-style online-softmax scan over KV chunks (pure XLA)."""
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    blk = min(block, skv)
    n_chunks = math.ceil(skv / blk)
    pad = n_chunks * blk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, blk, h, dh).swapaxes(0, 1)
    vc = v.reshape(b, n_chunks, blk, h, dh).swapaxes(0, 1)
    scale = 1.0 / math.sqrt(dh)
    q_idx = (jnp.arange(sq) + q_offset)[:, None]

    def step(carry, xs):
        m, l, acc = carry
        j, kj, vj = xs
        kv_idx = j * blk + jnp.arange(blk)[None, :]
        ok = _mask(q_idx, kv_idx, causal, window)          # (Sq, blk)
        ok = ok & (kv_idx < skv)                           # kv padding
        # bf16-in / f32-accumulate: no materialized f32 q/k/v copies
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kj,
                       preferred_element_type=jnp.float32) * scale
        s = jnp.where(ok[None, None], s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None]) * ok[None, None]
        l = l * corr + jnp.sum(p, axis=-1)
        acc = (acc * corr[..., None]
               + jnp.einsum("bhqk,bkhd->bhqd", p.astype(q.dtype), vj,
                            preferred_element_type=jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, h, sq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.swapaxes(1, 2).astype(q.dtype)


def attn_forward(p: dict, x: jax.Array, cfg: ModelConfig,
                 mem: jax.Array | None = None,
                 window: int | None = None,
                 positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence attention (training / prefill).  Cross if mem given."""
    cross = mem is not None
    q, k, v = _project_qkv(p, x, cfg, mem)
    if not cross:
        pos = (positions if positions is not None
               else jnp.arange(x.shape[1])[None, :])
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    k = _expand_kv(k, cfg)
    v = _expand_kv(v, cfg)
    win = cfg.sliding_window if window is None else window
    causal = not cross
    if cfg.attn_impl == "blockwise":
        out = _blockwise_attn(q, k, v, causal, win, cfg.attn_block)
    else:
        out = _naive_attn(q, k, v, causal, win)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# KV cache (decode) — linear or ring-buffer (local attention)
# ---------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype) -> dict:
    kv, dh = cfg.n_kv_heads, cfg.head_dim_
    return {"k": jnp.zeros((batch, max_len, kv, dh), dtype),
            "v": jnp.zeros((batch, max_len, kv, dh), dtype)}


def attn_decode(p: dict, x1: jax.Array, cache: dict, pos: jax.Array,
                cfg: ModelConfig, mem: jax.Array | None = None,
                window: int | None = None):
    """One-token decode.  x1: (B,1,D); pos: scalar int32 absolute position.

    With ``window`` (or cfg.sliding_window/local_window) and a cache sized
    to the window, indexing is a ring buffer — O(window) memory at 500k+
    context.  Cross-attention decodes against full ``mem`` (no cache).
    """
    if mem is not None:
        q, k, v = _project_qkv(p, x1, cfg, mem)
        k = _expand_kv(k, cfg)
        v = _expand_kv(v, cfg)
        out = _naive_attn(q, k, v, causal=False, window=0)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), cache

    q, k, v = _project_qkv(p, x1, cfg, None)
    posb = jnp.asarray(pos)[None]
    q = apply_rope(q, posb[None, :], cfg.rope_theta)
    k = apply_rope(k, posb[None, :], cfg.rope_theta)
    cache_len = cache["k"].shape[1]
    slot = pos % cache_len
    # §Perf (llama3-405b decode_32k): masked ring write instead of
    # dynamic_update_slice — elementwise select keeps the context-parallel
    # cache sharded (DUS at a traced offset forced SPMD to materialize the
    # full cache per chip: 2x cache temp + reshard).
    hot = (jnp.arange(cache_len) == slot)[None, :, None, None]
    ck = jnp.where(hot, k.astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(hot, v.astype(cache["v"].dtype), cache["v"])

    scale = 1.0 / math.sqrt(cfg.head_dim_)
    hp, kv = cfg.n_heads_padded, cfg.n_kv_heads
    grouped = kv > 0 and hp % kv == 0
    if grouped:
        # §Perf: grouped GQA decode — contract q-head groups against the kv
        # cache directly, never materializing the (S, H) expanded cache
        # (16x the cache bytes for kv=8, H=128).
        g = hp // kv
        qg = q.reshape(q.shape[0], 1, kv, g, q.shape[-1])
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ck,
                       preferred_element_type=jnp.float32) * scale
    else:
        kf = _expand_kv(ck, cfg)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kf,
                       preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(cache_len)
    # Unified ring semantics (covers the linear cache too, where slot == pos):
    # age of the entry in each slot; unwritten slots have age > pos.
    age = (slot - idx) % cache_len
    valid = age <= pos
    win = window if window is not None else (cfg.local_window
                                             or cfg.sliding_window)
    if win:
        valid &= age < win
    vshape = (1,) * (s.ndim - 1) + (cache_len,)
    s = jnp.where(valid.reshape(vshape), s, _NEG)
    prob = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    if grouped:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", prob, cv)
        out = out.reshape(out.shape[0], 1, hp, out.shape[-1])
    else:
        vf = _expand_kv(cv, cfg)
        out = jnp.einsum("bhqk,bkhd->bqhd", prob, vf)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv}
