"""Shared layers: RMSNorm, RoPE, gated MLP, embeddings, losses."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.param import ParamDef


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_defs() -> dict:
    return {"scale": None}  # shape filled by caller via make


def make_rmsnorm(d: int) -> dict:
    return {"scale": ParamDef((d,), ("embed",), init="ones")}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    # f32-accumulated second moment without materializing an f32 copy of x
    # (§Perf memory-term lever; bf16 squares are exact in f32)
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32)[..., None]
    var = var / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)
    return (x.astype(jnp.float32) * inv
            * p["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float) -> jax.Array:
    """x: (..., S, H, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), jnp.float32)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# gated (SiLU) MLP
# ---------------------------------------------------------------------------

def make_mlp(d: int, ff: int) -> dict:
    return {
        "wi_gate": ParamDef((d, ff), ("embed", "mlp")),
        "wi_up": ParamDef((d, ff), ("embed", "mlp")),
        "wo": ParamDef((ff, d), ("mlp", "embed")),
    }


def mlp(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["wi_gate"])
    u = jnp.einsum("...d,df->...f", x, p["wi_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["wo"])


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------

def make_embedding(cfg: ModelConfig) -> dict:
    v, d = cfg.vocab_padded, cfg.d_model
    out = {"embedding": ParamDef((v, d), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef((d, v), ("embed", "vocab"))
    return out


def embed(p: dict, tokens: jax.Array, dtype) -> jax.Array:
    return p["embedding"].astype(dtype)[tokens]


def logits(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return jnp.einsum("...d,vd->...v", x, p["embedding"])
    return jnp.einsum("...d,dv->...v", x, p["lm_head"])


def xent_loss(lg: jax.Array, labels: jax.Array,
              vocab_size: int) -> jax.Array:
    """Mean token cross-entropy in f32; padded vocab tail masked out."""
    lg = lg.astype(jnp.float32)
    v = lg.shape[-1]
    if v > vocab_size:
        neg = jnp.full((v - vocab_size,), -1e30, jnp.float32)
        lg = lg.at[..., vocab_size:].add(neg)
    lse = jax.scipy.special.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_xent_loss(p: dict, x: jax.Array, labels: jax.Array,
                      cfg: ModelConfig, chunk: int) -> jax.Array:
    """Sequence-chunked loss: never materializes the full (B,S,V) logits.

    Memory-roofline lever for the 128k-vocab archs (see EXPERIMENTS.md §Perf).
    """
    b, s, _ = x.shape
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, s // chunk, chunk, -1).swapaxes(0, 1)
    lc = labels.reshape(b, s // chunk, chunk).swapaxes(0, 1)

    def step(acc, xs):
        xi, li = xs
        lg = logits(p, xi, cfg)
        return acc + xent_loss(lg, li, cfg.vocab_size), None

    total, _ = jax.lax.scan(step, jnp.float32(0.0), (xc, lc))
    return total / (s // chunk)
