"""Model assembly: heterogeneous layer patterns, scanned stages, enc-dec.

A model is a sequence of **stages**; each stage is a layer-kind *pattern*
(e.g. recurrentgemma's ("rec","rec","attn")) stacked ``reps`` times and run
under ``lax.scan`` (optionally ``jax.checkpoint``-rematerialized).  Layer
kinds:

    attn       self-attention + gated MLP            (dense archs)
    attn_moe   self-attention + MoE FFN              (mixtral, phi3.5)
    cross      cross-attention + MLP                 (llama3.2-vision layers)
    dec        self-attn + cross-attn + MLP          (seamless decoder)
    ssm        mamba2 SSD mixer (no FFN)
    rec        RG-LRU recurrent block + MLP          (recurrentgemma)

Both directions are provided: ``forward``/``loss_fn`` (training & prefill)
and ``init_cache``/``decode_step`` (serving, one token against a cache).
"""

from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.models.attention import (attn_decode, attn_forward, attn_prefill,
                                    init_kv_cache, make_attn_defs)
from repro.models.config import ModelConfig
from repro.models.layers import (chunked_xent_loss, embed, logits,
                                 make_embedding, make_mlp, make_rmsnorm, mlp,
                                 rmsnorm, xent_loss)
from repro.models.moe import make_moe_defs, moe
from repro.models.param import ParamDef, abstract_params, init_params
from repro.models.rglru import (init_rglru_cache, make_rglru_defs,
                                rglru_decode_step, rglru_forward)
from repro.models.ssm import (init_ssm_cache, make_ssm_defs, ssm_decode_step,
                              ssm_forward)

FFN_KINDS = ("attn", "attn_moe", "cross", "dec", "rec")


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# per-kind block definitions
# ---------------------------------------------------------------------------

def make_block_defs(kind: str, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    out = {"ln1": make_rmsnorm(d)}
    if kind in ("attn", "attn_moe", "dec"):
        out["attn"] = make_attn_defs(cfg)
    if kind == "cross":
        out["cross"] = make_attn_defs(cfg, cross=True)
    if kind == "dec":
        out["ln_cross"] = make_rmsnorm(d)
        out["cross"] = make_attn_defs(cfg, cross=True)
    if kind == "ssm":
        out["ssm"] = make_ssm_defs(cfg)
        return out
    if kind == "rec":
        out["rec"] = make_rglru_defs(cfg)
    out["ln2"] = make_rmsnorm(d)
    out["ffn"] = make_moe_defs(cfg) if kind == "attn_moe" else \
        make_mlp(d, cfg.d_ff)
    return out


def block_forward(kind: str, p: dict, x: jax.Array, cfg: ModelConfig,
                  mem: jax.Array | None = None):
    aux = jnp.float32(0.0)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind in ("attn", "attn_moe", "dec"):
        h = attn_forward(p["attn"], h, cfg)
    elif kind == "cross":
        h = attn_forward(p["cross"], h, cfg, mem=mem)
    elif kind == "ssm":
        return x + ssm_forward(p["ssm"], h, cfg), aux
    elif kind == "rec":
        h = rglru_forward(p["rec"], h, cfg)
    x = x + h
    if kind == "dec":
        h = rmsnorm(p["ln_cross"], x, cfg.norm_eps)
        x = x + attn_forward(p["cross"], h, cfg, mem=mem)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind == "attn_moe":
        h, aux = moe(p["ffn"], h, cfg)
    else:
        h = mlp(p["ffn"], h)
    return x + h, aux


def block_decode(kind: str, p: dict, x1: jax.Array, cache: dict,
                 pos: jax.Array, cfg: ModelConfig,
                 mem: jax.Array | None = None):
    h = rmsnorm(p["ln1"], x1, cfg.norm_eps)
    new_cache = cache
    if kind in ("attn", "attn_moe", "dec"):
        h, kv = attn_decode(p["attn"], h, cache["kv"], pos, cfg)
        new_cache = dict(cache, kv=kv)
    elif kind == "cross":
        h, _ = attn_decode(p["cross"], h, None, pos, cfg, mem=mem)
    elif kind == "ssm":
        y, st = ssm_decode_step(p["ssm"], h, cache["ssm"], cfg)
        return x1 + y, dict(cache, ssm=st)
    elif kind == "rec":
        h, st = rglru_decode_step(p["rec"], h, cache["rec"], cfg)
        new_cache = dict(cache, rec=st)
    x1 = x1 + h
    if kind == "dec":
        h = rmsnorm(p["ln_cross"], x1, cfg.norm_eps)
        y, _ = attn_decode(p["cross"], h, None, pos, cfg, mem=mem)
        x1 = x1 + y
    h = rmsnorm(p["ln2"], x1, cfg.norm_eps)
    if kind == "attn_moe":
        h, _ = moe(p["ffn"], h, cfg)
    else:
        h = mlp(p["ffn"], h)
    return x1 + h, new_cache


def block_prefill(kind: str, p: dict, xs: jax.Array, cache: dict,
                  pos0: jax.Array, n_valid: jax.Array, cfg: ModelConfig):
    """Teacher-forced block over S positions — bit-identical to S
    sequential ``block_decode`` steps (attention kinds only; see
    :func:`can_prefill`)."""
    h = rmsnorm(p["ln1"], xs, cfg.norm_eps)
    h, kv = attn_prefill(p["attn"], h, cache["kv"], pos0, n_valid, cfg)
    new_cache = dict(cache, kv=kv)
    xs = xs + h
    h = rmsnorm(p["ln2"], xs, cfg.norm_eps)
    if kind == "attn_moe":
        h, _ = moe(p["ffn"], h, cfg)
    else:
        h = mlp(p["ffn"], h)
    return xs + h, new_cache


def can_prefill(cfg: ModelConfig) -> bool:
    """True when every block is a self-attention kind, so teacher-forced
    chunks can run block-parallel (ssm/rec/cross carry sequential state or
    memory the prefill path does not model)."""
    return not cfg.is_encdec and all(
        kind in ("attn", "attn_moe")
        for pat, _reps in cfg.stages for kind in pat)


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype) -> dict:
    if kind in ("attn", "attn_moe", "dec"):
        # local/sliding-window archs only ever need a window-sized ring
        win = cfg.local_window or cfg.sliding_window
        eff = min(max_len, win) if win else max_len
        return {"kv": init_kv_cache(cfg, batch, eff, dtype)}
    if kind == "ssm":
        return {"ssm": init_ssm_cache(cfg, batch, dtype)}
    if kind == "rec":
        return {"rec": init_rglru_cache(cfg, batch, dtype)}
    return {}  # cross: attends precomputed memory, nothing cached


# ---------------------------------------------------------------------------
# stages (pattern x reps, scanned)
# ---------------------------------------------------------------------------

def _stack_defs(tree, reps: int):
    def f(d: ParamDef) -> ParamDef:
        ts = None if d.true_sizes is None else (None,) + d.true_sizes
        return ParamDef((reps,) + d.shape, ("layers",) + d.axes,
                        init=d.init, scale=d.scale, true_sizes=ts)
    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, ParamDef))


def make_stage_defs(pattern: tuple[str, ...], reps: int,
                    cfg: ModelConfig) -> dict:
    unit = {f"b{i}_{kind}": make_block_defs(kind, cfg)
            for i, kind in enumerate(pattern)}
    return _stack_defs(unit, reps)


def stage_forward(params: dict, x: jax.Array, pattern: tuple[str, ...],
                  cfg: ModelConfig, mem: jax.Array | None = None):
    def unit(x, layer_p):
        aux = jnp.float32(0.0)
        for i, kind in enumerate(pattern):
            x, a = block_forward(kind, layer_p[f"b{i}_{kind}"], x, cfg, mem)
            if cfg.act_pspec is not None:
                # e.g. sequence-parallel residuals (llama3-405b fit lever)
                from jax.sharding import PartitionSpec as P
                x = jax.lax.with_sharding_constraint(x, P(*cfg.act_pspec))
            aux = aux + a
        return x, aux

    body = jax.checkpoint(unit) if cfg.remat else unit
    if cfg.scan_layers:
        x, auxs = jax.lax.scan(lambda c, p: body(c, p), x, params)
        return x, jnp.sum(auxs)
    reps = jax.tree.leaves(params)[0].shape[0]
    aux = jnp.float32(0.0)
    for r in range(reps):
        layer_p = jax.tree.map(lambda a: a[r], params)
        x, a = body(x, layer_p)
        aux = aux + a
    return x, aux


def stage_decode(params: dict, cache: dict, x1: jax.Array, pos: jax.Array,
                 pattern: tuple[str, ...], cfg: ModelConfig,
                 mem: jax.Array | None = None):
    def unit(x1, layer_p, layer_c):
        new_c = {}
        for i, kind in enumerate(pattern):
            key = f"b{i}_{kind}"
            x1, c = block_decode(kind, layer_p[key], x1, layer_c[key],
                                 pos, cfg, mem)
            new_c[key] = c
        return x1, new_c

    if cfg.scan_layers:
        def body(carry, xs):
            layer_p, layer_c = xs
            return unit(carry, layer_p, layer_c)
        x1, new_cache = jax.lax.scan(body, x1, (params, cache))
        return x1, new_cache
    reps = jax.tree.leaves(params)[0].shape[0]
    outs = []
    for r in range(reps):
        layer_p = jax.tree.map(lambda a: a[r], params)
        layer_c = jax.tree.map(lambda a: a[r], cache)
        x1, c = unit(x1, layer_p, layer_c)
        outs.append(c)
    new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    return x1, new_cache


def stage_prefill(params: dict, cache: dict, xs: jax.Array, pos0: jax.Array,
                  n_valid: jax.Array, pattern: tuple[str, ...],
                  cfg: ModelConfig):
    def unit(xs, layer_p, layer_c):
        new_c = {}
        for i, kind in enumerate(pattern):
            key = f"b{i}_{kind}"
            xs, c = block_prefill(kind, layer_p[key], xs, layer_c[key],
                                  pos0, n_valid, cfg)
            new_c[key] = c
        return xs, new_c

    if cfg.scan_layers:
        def body(carry, xs_):
            layer_p, layer_c = xs_
            return unit(carry, layer_p, layer_c)
        xs, new_cache = jax.lax.scan(body, xs, (params, cache))
        return xs, new_cache
    reps = jax.tree.leaves(params)[0].shape[0]
    outs = []
    for r in range(reps):
        layer_p = jax.tree.map(lambda a: a[r], params)
        layer_c = jax.tree.map(lambda a: a[r], cache)
        xs, c = unit(xs, layer_p, layer_c)
        outs.append(c)
    new_cache = jax.tree.map(lambda *x: jnp.stack(x), *outs)
    return xs, new_cache


def init_stage_cache(pattern: tuple[str, ...], reps: int, cfg: ModelConfig,
                     batch: int, max_len: int, dtype) -> dict:
    unit = {f"b{i}_{kind}": init_block_cache(kind, cfg, batch, max_len,
                                             dtype)
            for i, kind in enumerate(pattern)}
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a, (reps,) + a.shape).copy(), unit)


# ---------------------------------------------------------------------------
# whole models
# ---------------------------------------------------------------------------

def make_model_defs(cfg: ModelConfig) -> dict:
    out = {"tok": make_embedding(cfg), "final_norm": make_rmsnorm(cfg.d_model)}
    out["stages"] = {f"s{i}": make_stage_defs(pat, reps, cfg)
                     for i, (pat, reps) in enumerate(cfg.stages)}
    if cfg.is_encdec:
        enc_cfg = replace(cfg, sliding_window=0)
        out["encoder"] = {
            "stack": make_stage_defs(("attn",), cfg.encoder_layers, enc_cfg),
            "final_norm": make_rmsnorm(cfg.d_model),
        }
    return out


def encode_memory(params: dict, enc_inputs: jax.Array, cfg: ModelConfig):
    """Encoder pass over stub frontend embeddings (non-causal self-attn)."""
    # bidirectional: reuse attn_forward but disable the causal mask by
    # running with cross-style memory = itself?  Simpler: the encoder uses
    # causal=False via a one-off config flag in attn_forward -> we emulate
    # bidirectionality with mem=x (cross attention against itself).
    x = enc_inputs.astype(_dtype(cfg))
    def unit(x, layer_p):
        p = layer_p["b0_attn"]
        h = rmsnorm(p["ln1"], x, cfg.norm_eps)
        h = attn_forward(p["attn"], h, cfg, mem=h)   # non-causal self-attn
        x = x + h
        h = rmsnorm(p["ln2"], x, cfg.norm_eps)
        return x + mlp(p["ffn"], h), None
    body = jax.checkpoint(unit) if cfg.remat else unit
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x,
                        params["encoder"]["stack"])
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps)


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig,
            memory: jax.Array | None = None,
            enc_inputs: jax.Array | None = None):
    """tokens (B,S) -> hidden states (B,S,D) + aux loss."""
    if cfg.is_encdec and enc_inputs is not None:
        memory = encode_memory(params, enc_inputs, cfg)
    x = embed(params["tok"], tokens, _dtype(cfg))
    if cfg.act_pspec is not None:
        # pin the residual stream's batch sharding: the vocab/FSDP-sharded
        # embedding gather otherwise poisons propagation (activations would
        # replicate over data — observed 32 GB score tensors per chip).
        from jax.sharding import PartitionSpec as P
        x = jax.lax.with_sharding_constraint(x, P(*cfg.act_pspec))
    aux = jnp.float32(0.0)
    for i, (pat, reps) in enumerate(cfg.stages):
        x, a = stage_forward(params["stages"][f"s{i}"], x, pat, cfg, memory)
        aux = aux + a
    return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux


def loss_fn(params: dict, batch: dict, cfg: ModelConfig):
    """batch: tokens (B,S), labels (B,S), optional memory/enc_inputs."""
    x, aux = forward(params, batch["tokens"], cfg,
                     memory=batch.get("memory"),
                     enc_inputs=batch.get("enc_inputs"))
    if cfg.logits_chunk:
        ce = chunked_xent_loss(params["tok"], x, batch["labels"], cfg,
                               cfg.logits_chunk)
    else:
        lg = logits(params["tok"], x, cfg)
        ce = xent_loss(lg, batch["labels"], cfg.vocab_size)
    return ce + 0.01 * aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = _dtype(cfg)
    return {f"s{i}": init_stage_cache(pat, reps, cfg, batch, max_len, dt)
            for i, (pat, reps) in enumerate(cfg.stages)}


def decode_step(params: dict, cache: dict, token: jax.Array, pos: jax.Array,
                cfg: ModelConfig, memory: jax.Array | None = None):
    """One serving step: token (B,1) int32 -> (logits (B,V), cache').

    ``pos`` is a scalar int32 absolute position, or a ``(B,)`` vector of
    per-row positions (batched serving: each row of the shared ring cache
    advances independently — see ``serve.engine.BatchEngine``).  Only
    attention consumes positions; ssm/rglru decode steps ignore them.
    """
    x1 = embed(params["tok"], token, _dtype(cfg))
    new_cache = {}
    for i, (pat, reps) in enumerate(cfg.stages):
        x1, c = stage_decode(params["stages"][f"s{i}"], cache[f"s{i}"], x1,
                             pos, pat, cfg, memory)
        new_cache[f"s{i}"] = c
    x1 = rmsnorm(params["final_norm"], x1, cfg.norm_eps)
    lg = logits(params["tok"], x1, cfg)[:, 0]
    return lg, new_cache


def prefill_chunk(params: dict, cache: dict, tokens: jax.Array,
                  pos0: jax.Array, n_valid: jax.Array, cfg: ModelConfig):
    """Teacher-forced serving chunk: tokens (B,S) int32 inputs at per-row
    positions ``pos0 + [0, S)`` -> (logits (B,S,V), cache').

    Bit-identical to S sequential ``decode_step`` calls when the chunk
    stays inside the ring (``pos0 + S <= cache_len``) — the batched
    engine's fast path for compress rows, whose inputs are all known up
    front.  Gate on :func:`can_prefill`.  Rows with ``n_valid < S`` freeze
    after their live steps (queries discarded, no cache writes).
    """
    xs = embed(params["tok"], tokens, _dtype(cfg))
    new_cache = {}
    for i, (pat, reps) in enumerate(cfg.stages):
        xs, c = stage_prefill(params["stages"][f"s{i}"], cache[f"s{i}"], xs,
                              pos0, n_valid, pat, cfg)
        new_cache[f"s{i}"] = c
    xs = rmsnorm(params["final_norm"], xs, cfg.norm_eps)
    return logits(params["tok"], xs, cfg), new_cache


# convenience -----------------------------------------------------------------

def init_model(cfg: ModelConfig, key: jax.Array):
    return init_params(make_model_defs(cfg), key, _dtype(cfg))


def abstract_model(cfg: ModelConfig):
    return abstract_params(make_model_defs(cfg), _dtype(cfg))
