"""Bit-Swap hierarchical VAE over the lane stack (bits-back coding).

A small 2-level VAE whose *coding path* runs entirely through the
craystack-style stack of :mod:`repro.core.stack` — the latent-variable
workload family of the roadmap (DESIGN.md §12).  Each lane is one data
vector (an image patch of ``d_x`` pixels); the lane axis is the coder's
SIMD axis, so a whole batch of patches is coded in lockstep.

Generative model / inference model (all diagonal Gaussians, latents
discretized to the standard normal's equal-mass quantile bins for coding):

    p(z2) = N(0, I)                q2(z2 | z1) = N(mu2(z1), sig2(z1))
    p(z1 | z2) = N(mu, sig)(z2)    q1(z1 | x)  = N(mu1(x), sig1(x))
    p(x | z1)  = DiscretizedLogistic(mu(z1), s(z1)) per pixel

Bit-Swap coding order (encode; decode is the exact reverse with push and
pop swapped — the stack restores its initial state bit-for-bit, which is
the bits-back identity the tests pin):

    A. pop  k1 ~ q1(. | x)      (recovers bits — the bits-back credit)
    B. push x  ~ p(x | z1)
    C. pop  k2 ~ q2(. | z1)
    D. push k1 ~ p(z1 | z2)
    E. push k2 ~ p(z2)          (equal-mass bins -> exactly Uniform)

Training maximizes the continuous ELBO with reparameterized samples; the
networks are built from the repo's own layer substrate
(:mod:`repro.models.layers` gated-SiLU MLP blocks, :mod:`repro.models.param`
ParamDefs) and train with :mod:`repro.train.optimizer` AdamW.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import spc, stack
from repro.models import layers
from repro.models.param import ParamDef, init_params
from repro.train import optimizer


class VAEConfig(NamedTuple):
    d_x: int = 64        # pixels per lane (one 8x8 patch)
    d_z: int = 4         # latent dims per level
    d_h: int = 48        # hidden width
    z_bins: int = 16     # latent quantile bins (power of two: exact Uniform)
    x_bins: int = 256    # pixel levels
    prob_bits: int = C.PROB_BITS


# ---------------------------------------------------------------------------
# networks: in-proj -> gated-SiLU MLP residual core -> out-proj
# ---------------------------------------------------------------------------

def _net_defs(d_in: int, d_h: int, d_out: int) -> dict:
    return {
        "win": ParamDef((d_in, d_h), ("embed", "mlp"), scale=0.1),
        "core": layers.make_mlp(d_h, 2 * d_h),
        "wout": ParamDef((d_h, d_out), ("mlp", "embed"), scale=0.1),
        "bout": ParamDef((d_out,), ("embed",), init="zeros"),
    }


def _net(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["win"])
    h = h + layers.mlp(p["core"], h)
    return h @ p["wout"] + p["bout"]


def vae_defs(cfg: VAEConfig) -> dict:
    return {
        "enc1": _net_defs(cfg.d_x, cfg.d_h, 2 * cfg.d_z),  # x  -> q1
        "enc2": _net_defs(cfg.d_z, cfg.d_h, 2 * cfg.d_z),  # z1 -> q2
        "dec2": _net_defs(cfg.d_z, cfg.d_h, 2 * cfg.d_z),  # z2 -> p(z1|z2)
        "dec1": _net_defs(cfg.d_z, cfg.d_h, 2 * cfg.d_x),  # z1 -> p(x|z1)
    }


def init_vae(cfg: VAEConfig, key: jax.Array) -> dict:
    return init_params(vae_defs(cfg), key)


def _mu_sig(raw: jax.Array):
    """Split a ``(..., 2d)`` net output into (mu, sigma); log-sigma clamped
    for optimizer stability (coding re-clamps identically, so train and
    code see the same distributions)."""
    mu, logsig = jnp.split(raw, 2, axis=-1)
    return mu, jnp.exp(jnp.clip(logsig, -4.0, 2.0))


def _mu_logs(raw: jax.Array):
    """Pixel-likelihood head: (mu in [-1,1]-ish, log-scale clamped)."""
    mu, log_s = jnp.split(raw, 2, axis=-1)
    return mu, jnp.clip(log_s, -7.0, 1.0)


def normalize(x: jax.Array, x_bins: int) -> jax.Array:
    """Integer pixel levels -> bin centres in [-1, 1]."""
    return 2.0 * (x.astype(jnp.float32) + 0.5) / x_bins - 1.0


# ---------------------------------------------------------------------------
# continuous ELBO (training)
# ---------------------------------------------------------------------------

def _gauss_logpdf(z, mu, sig):
    zn = (z - mu) / sig
    return -0.5 * zn * zn - jnp.log(sig) - 0.5 * np.log(2 * np.pi)


def _dlogistic_loglik(x, mu, log_s, x_bins: int):
    """log p(x) of the discretized logistic over ``x_bins`` levels in
    [-1, 1] — the same binning the coding path quantizes
    (``stack.logistic_bin_probs``), endpoint bins take the open tails."""
    lower = 2.0 * x.astype(jnp.float32) / x_bins - 1.0
    upper = 2.0 * (x.astype(jnp.float32) + 1.0) / x_bins - 1.0
    inv_s = jnp.exp(-log_s)
    cdf_lo = jax.nn.sigmoid((lower - mu) * inv_s)
    cdf_hi = jax.nn.sigmoid((upper - mu) * inv_s)
    cdf_lo = jnp.where(x <= 0, 0.0, cdf_lo)
    cdf_hi = jnp.where(x >= x_bins - 1, 1.0, cdf_hi)
    return jnp.log(jnp.maximum(cdf_hi - cdf_lo, 1e-12))


def elbo_loss(params: dict, x: jax.Array, cfg: VAEConfig,
              key: jax.Array) -> jax.Array:
    """Negative ELBO in nats per lane (mean over the batch/lane axis)."""
    xn = normalize(x, cfg.x_bins)
    k1, k2 = jax.random.split(key)

    mu1, sig1 = _mu_sig(_net(params["enc1"], xn))
    z1 = mu1 + sig1 * jax.random.normal(k1, mu1.shape)
    mu2, sig2 = _mu_sig(_net(params["enc2"], z1))
    z2 = mu2 + sig2 * jax.random.normal(k2, mu2.shape)

    mu1p, sig1p = _mu_sig(_net(params["dec2"], z2))
    mux, log_sx = _mu_logs(_net(params["dec1"], z1))

    log_px = jnp.sum(_dlogistic_loglik(x, mux, log_sx, cfg.x_bins), -1)
    kl1 = jnp.sum(_gauss_logpdf(z1, mu1, sig1)
                  - _gauss_logpdf(z1, mu1p, sig1p), -1)
    kl2 = jnp.sum(_gauss_logpdf(z2, mu2, sig2)
                  - _gauss_logpdf(z2, jnp.zeros_like(mu2),
                                  jnp.ones_like(sig2)), -1)
    return jnp.mean(-log_px + kl1 + kl2)


def train_vae(cfg: VAEConfig, batches, *, steps: int = 300,
              lr: float = 3e-3, seed: int = 0) -> dict:
    """Train on ``batches`` (callable ``step -> (lanes, d_x)`` int array).
    Small and CPU-friendly by design — the example/CI budget."""
    key = jax.random.PRNGKey(seed)
    params = init_vae(cfg, key)
    opt = optimizer.adamw_init(params)

    @jax.jit
    def step_fn(params, opt, x, k):
        loss, grads = jax.value_and_grad(elbo_loss)(params, x, cfg, k)
        grads, _ = optimizer.clip_by_global_norm(grads, 1.0)
        params, opt = optimizer.adamw_update(grads, opt, params, lr,
                                             weight_decay=1e-4)
        return params, opt, loss

    loss = None
    for i in range(steps):
        x = jnp.asarray(batches(i), jnp.int32)
        params, opt, loss = step_fn(params, opt, x,
                                    jax.random.fold_in(key, i + 1))
    return params, float(loss)


# ---------------------------------------------------------------------------
# bits-back coding over the stack
# ---------------------------------------------------------------------------

def _latent_tables(mu: jax.Array, sig: jax.Array, edges: jax.Array,
                   prob_bits: int):
    """Per-dim Gaussian bin tables: (lanes, d) nets -> (d, lanes, B) freq/cdf
    (the ``(T, lanes, K)`` per-position layout of the stack array codecs)."""
    probs = stack.gaussian_bin_probs(mu.T, sig.T, edges)
    return spc.freq_cdf_from_probs(spc.store_bf16(probs), prob_bits)


def _pixel_tables(params: dict, z1c: jax.Array, cfg: VAEConfig):
    """p(x | z1) tables: (d_x, lanes, x_bins) discretized logistic."""
    mux, log_sx = _mu_logs(_net(params["dec1"], z1c))
    probs = stack.logistic_bin_probs(mux.T, log_sx.T, cfg.x_bins)
    return spc.freq_cdf_from_probs(spc.store_bf16(probs), cfg.prob_bits)


def _uniform_tables(k: int, prob_bits: int):
    """Exact uniform tables over ``k`` symbols (requires 2**prob_bits % k
    == 0 — the equal-mass standard-normal prior over its own quantile
    bins)."""
    total = 1 << prob_bits
    if total % k:
        raise ValueError(f"uniform prior needs 2**{prob_bits} % {k} == 0")
    f = total // k
    freq = jnp.full((k,), f, jnp.uint32)
    cdf = (jnp.arange(k + 1, dtype=jnp.uint32) * f).astype(jnp.uint32)
    return freq, cdf


@functools.lru_cache(maxsize=8)
def _bins(z_bins: int):
    edges, centres = stack.std_gaussian_bins(z_bins)
    return edges, centres


def bb_encode(st: stack.StackState, params: dict, x: jax.Array,
              cfg: VAEConfig, backend: str = "coder",
              interpret: bool = True) -> stack.StackState:
    """Bits-back encode one ``(lanes, d_x)`` batch onto the stack (the
    A-E Bit-Swap schedule in the module docstring).  The net message cost
    is ``stack.stack_bytes`` growth — the posterior pop's recovered bits
    are credited automatically by the stack discipline."""
    pb = cfg.prob_bits
    edges, centres = _bins(cfg.z_bins)
    xn = normalize(x, cfg.x_bins)

    # A: pop k1 ~ q1(. | x)
    mu1, sig1 = _mu_sig(_net(params["enc1"], xn))
    f1, c1 = _latent_tables(mu1, sig1, edges, pb)
    st, k1 = stack.pop_symbols(st, cfg.d_z, f1, c1, pb, backend=backend,
                               interpret=interpret)
    z1c = centres[k1]

    # B: push x ~ p(x | z1)
    fx, cx = _pixel_tables(params, z1c, cfg)
    st = stack.push_symbols(st, x, fx, cx, pb)

    # C: pop k2 ~ q2(. | z1)
    mu2, sig2 = _mu_sig(_net(params["enc2"], z1c))
    f2, c2 = _latent_tables(mu2, sig2, edges, pb)
    st, k2 = stack.pop_symbols(st, cfg.d_z, f2, c2, pb, backend=backend,
                               interpret=interpret)
    z2c = centres[k2]

    # D: push k1 ~ p(z1 | z2)
    mu1p, sig1p = _mu_sig(_net(params["dec2"], z2c))
    fp, cp = _latent_tables(mu1p, sig1p, edges, pb)
    st = stack.push_symbols(st, k1, fp, cp, pb)

    # E: push k2 ~ p(z2) (exactly uniform over equal-mass bins)
    fu, cu = _uniform_tables(cfg.z_bins, pb)
    return stack.push_symbols(st, k2, fu, cu, pb)


def bb_decode(st: stack.StackState, params: dict, cfg: VAEConfig,
              backend: str = "coder", interpret: bool = True):
    """Exact reverse of :func:`bb_encode` (push and pop swapped, E' -> A').
    Returns ``(state, x)``; the state equals the pre-encode stack
    bit-for-bit — the bits-back identity."""
    pb = cfg.prob_bits
    edges, centres = _bins(cfg.z_bins)

    # E': pop k2 ~ p(z2)
    fu, cu = _uniform_tables(cfg.z_bins, pb)
    st, k2 = stack.pop_symbols(st, cfg.d_z, fu, cu, pb, backend=backend,
                               interpret=interpret)
    z2c = centres[k2]

    # D': pop k1 ~ p(z1 | z2)
    mu1p, sig1p = _mu_sig(_net(params["dec2"], z2c))
    fp, cp = _latent_tables(mu1p, sig1p, edges, pb)
    st, k1 = stack.pop_symbols(st, cfg.d_z, fp, cp, pb, backend=backend,
                               interpret=interpret)
    z1c = centres[k1]

    # C': push k2 ~ q2(. | z1)
    mu2, sig2 = _mu_sig(_net(params["enc2"], z1c))
    f2, c2 = _latent_tables(mu2, sig2, edges, pb)
    st = stack.push_symbols(st, k2, f2, c2, pb)

    # B': pop x ~ p(x | z1)
    fx, cx = _pixel_tables(params, z1c, cfg)
    st, x = stack.pop_symbols(st, cfg.d_x, fx, cx, pb, backend=backend,
                              interpret=interpret)

    # A': push k1 ~ q1(. | x)
    xn = normalize(x, cfg.x_bins)
    mu1, sig1 = _mu_sig(_net(params["enc1"], xn))
    f1, c1 = _latent_tables(mu1, sig1, edges, pb)
    return stack.push_symbols(st, k1, f1, c1, pb), x
