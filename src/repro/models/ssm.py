"""Mamba-2 SSD (state-space duality) mixer — attention-free sequence layer.

Implements the chunked SSD algorithm (arXiv:2405.21060): the linear
recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t (x) B_t        (state (H,P,N))
    y_t = h_t . C_t + D * x_t

is evaluated as intra-chunk quadratic attention-like einsums plus an
inter-chunk state scan — O(S * Q) work, O(1)-state decode.  ``ssd_sequential``
is the step-by-step oracle used by tests; ``ssm_decode_step`` is the serving
path (this is what makes mamba2 run the long_500k shape).

TP note: the input projection is stored **per component** (z, x, B, C, dt)
rather than as one fused matrix so each output dim shards cleanly over the
model axis (the fused concat width is not divisible by tp=16); the tiny
per-head dt projection replicates.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.param import ParamDef


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_headdim
    groups = 1
    return d_in, heads, groups


def make_ssm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    d_in, heads, groups = ssm_dims(cfg)
    gn = groups * cfg.ssm_state
    return {
        "wz": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "wx": ParamDef((d, d_in), ("embed", "ssm_inner")),
        "wb": ParamDef((d, gn), ("embed", "ssm_state")),
        "wc": ParamDef((d, gn), ("embed", "ssm_state")),
        "wdt": ParamDef((d, heads), ("embed", None)),
        "conv_x_w": ParamDef((cfg.conv_width, d_in), (None, "ssm_inner")),
        "conv_x_b": ParamDef((d_in,), ("ssm_inner",), init="zeros"),
        "conv_b_w": ParamDef((cfg.conv_width, gn), (None, "ssm_state")),
        "conv_b_b": ParamDef((gn,), ("ssm_state",), init="zeros"),
        "conv_c_w": ParamDef((cfg.conv_width, gn), (None, "ssm_state")),
        "conv_c_b": ParamDef((gn,), ("ssm_state",), init="zeros"),
        "A_log": ParamDef((heads,), (None,), init="zeros"),
        "D": ParamDef((heads,), (None,), init="ones"),
        "dt_bias": ParamDef((heads,), (None,), init="zeros"),
        "norm_scale": ParamDef((d_in,), ("ssm_inner",), init="ones"),
        "out_proj": ParamDef((d_in, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B,S,C) with taps (W,C)."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :] * w[i]
    return out + b


def ssd_chunked(x, dt, a_head, bm, cm, chunk: int):
    """x:(B,S,H,P) dt:(B,S,H) a_head:(H,) bm/cm:(B,S,G,N) -> y:(B,S,H,P)."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    q = min(chunk, s)
    if s % q:
        # zero-pad to a chunk multiple: dt=0 -> decay=1 and zero input, so
        # padded steps are state-neutral; outputs are sliced back.
        pad = q - s % q
        zpad = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                                 [(0, 0)] * (t.ndim - 2))
        out = ssd_chunked(zpad(x), zpad(dt), a_head, zpad(bm), zpad(cm), q)
        return out[:, :s]
    nc = s // q
    rep = h // g
    bh = jnp.repeat(bm, rep, axis=2)            # (B,S,H,N)
    ch = jnp.repeat(cm, rep, axis=2)
    dtf = dt.astype(jnp.float32)
    da = dtf * a_head.astype(jnp.float32)       # (B,S,H) log-decay
    xdt = (x.astype(jnp.float32)
           * dtf[..., None])                    # dt-weighted input

    def r4(t):  # (B,S,...) -> (B,nc,Q,...)
        return t.reshape((b, nc, q) + t.shape[2:])

    da_c, xdt_c = r4(da), r4(xdt)
    bh_c, ch_c = r4(bh.astype(jnp.float32)), r4(ch.astype(jnp.float32))
    cs = jnp.cumsum(da_c, axis=2)               # (B,nc,Q,H) inclusive

    # --- intra-chunk: y_i += sum_{j<=i} exp(cs_i - cs_j) (C_i.B_j) xdt_j
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]     # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    el = jnp.exp(jnp.where(tri, diff, -jnp.inf))
    scores = jnp.einsum("bcihn,bcjhn->bcijh", ch_c, bh_c)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores * el, xdt_c)

    # --- chunk-final states: S_c = sum_j exp(cs_end - cs_j) xdt_j (x) B_j
    dec_end = jnp.exp(cs[:, :, -1:, :] - cs)               # (B,nc,Q,H)
    s_c = jnp.einsum("bcjh,bcjhn,bcjhp->bchpn", dec_end, bh_c, xdt_c)

    # --- inter-chunk recurrence over nc chunks
    chunk_decay = jnp.exp(cs[:, :, -1, :])                 # (B,nc,H)

    def scan_fn(hprev, xs):
        dec, s_new = xs                                    # (B,H), (B,H,P,N)
        h_out = hprev                                      # state BEFORE chunk
        return dec[..., None, None] * hprev + s_new, h_out

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    _, h_before = jax.lax.scan(
        scan_fn, h0, (chunk_decay.swapaxes(0, 1), s_c.swapaxes(0, 1)))
    h_before = h_before.swapaxes(0, 1)                     # (B,nc,H,P,N)

    y_inter = jnp.einsum("bcihn,bchpn->bcihp", ch_c, h_before) \
        * jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(x.dtype)


def ssd_sequential(x, dt, a_head, bm, cm):
    """Step-by-step oracle for tests (identical math, O(S) scan)."""
    b, s, h, p = x.shape
    g, n = bm.shape[2], bm.shape[3]
    rep = h // g
    bh = jnp.repeat(bm, rep, axis=2).astype(jnp.float32)
    ch = jnp.repeat(cm, rep, axis=2).astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(hprev, xs):
        xt, dtt, bt, ct = xs
        decay = jnp.exp(dtt * a_head)[..., None, None]     # (B,H,1,1)
        upd = jnp.einsum("bhp,bhn->bhpn", xt * dtt[..., None], bt)
        hnew = decay * hprev + upd
        yt = jnp.einsum("bhpn,bhn->bhp", hnew, ct)
        return hnew, yt

    h0 = jnp.zeros((b, h, p, n), jnp.float32)
    xs = (x.astype(jnp.float32).swapaxes(0, 1), dtf.swapaxes(0, 1),
          bh.swapaxes(0, 1), ch.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)


def _project(p: dict, x: jax.Array):
    z = jnp.einsum("bsd,dk->bsk", x, p["wz"])
    xs = jnp.einsum("bsd,dk->bsk", x, p["wx"])
    bm = jnp.einsum("bsd,dk->bsk", x, p["wb"])
    cm = jnp.einsum("bsd,dk->bsk", x, p["wc"])
    dt = jnp.einsum("bsd,dk->bsk", x, p["wdt"])
    return z, xs, bm, cm, dt


def ssm_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Full mamba2 mixer: proj -> conv -> SSD -> gated norm -> out_proj."""
    b, s, _ = x.shape
    d_in, heads, groups = ssm_dims(cfg)
    z, xs, bm, cm, dt = _project(p, x)
    xs = jax.nn.silu(_causal_conv(xs, p["conv_x_w"], p["conv_x_b"]))
    bm = jax.nn.silu(_causal_conv(bm, p["conv_b_w"], p["conv_b_b"]))
    cm = jax.nn.silu(_causal_conv(cm, p["conv_c_w"], p["conv_c_b"]))
    xh = xs.reshape(b, s, heads, cfg.ssm_headdim)
    bmh = bm.reshape(b, s, groups, cfg.ssm_state)
    cmh = cm.reshape(b, s, groups, cfg.ssm_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_head = -jnp.exp(p["A_log"].astype(jnp.float32))
    y = ssd_chunked(xh, dtv, a_head, bmh, cmh, cfg.ssm_chunk)
    y = y + xh * p["D"][:, None].astype(y.dtype)
    y = y.reshape(b, s, d_in)
    y = rmsnorm({"scale": p["norm_scale"]}, y * jax.nn.silu(z), cfg.norm_eps)
    return jnp.einsum("bsk,kd->bsd", y, p["out_proj"])


# ---------------------------------------------------------------------------
# decode: O(1) state update
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d_in, heads, groups = ssm_dims(cfg)
    conv_dim = d_in + 2 * groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), dtype),
        "h": jnp.zeros((batch, heads, cfg.ssm_headdim, cfg.ssm_state),
                       jnp.float32),
    }


def ssm_decode_step(p: dict, x1: jax.Array, cache: dict,
                    cfg: ModelConfig):
    """x1: (B,1,D) -> (y (B,1,D), cache')."""
    b = x1.shape[0]
    d_in, heads, groups = ssm_dims(cfg)
    gn = groups * cfg.ssm_state
    z, xs, bm, cm, dt = _project(p, x1)
    xbc = jnp.concatenate([xs, bm, cm], axis=-1)           # (B,1,conv_dim)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)   # (B,W,conv_dim)
    conv_w = jnp.concatenate([p["conv_x_w"], p["conv_b_w"],
                              p["conv_c_w"]], axis=1)
    conv_b = jnp.concatenate([p["conv_x_b"], p["conv_b_b"],
                              p["conv_c_b"]], axis=0)
    conv_out = jnp.einsum("bwc,wc->bc", hist, conv_w) + conv_b
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    xs, bm, cm = jnp.split(xbc1, [d_in, d_in + gn], -1)
    xh = xs.reshape(b, heads, cfg.ssm_headdim).astype(jnp.float32)
    bmh = jnp.repeat(bm.reshape(b, groups, cfg.ssm_state),
                     heads // groups, axis=1).astype(jnp.float32)
    cmh = jnp.repeat(cm.reshape(b, groups, cfg.ssm_state),
                     heads // groups, axis=1).astype(jnp.float32)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]
    a_head = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dtv * a_head)[..., None, None]
    h = decay * cache["h"] + jnp.einsum("bhp,bhn->bhpn",
                                        xh * dtv[..., None], bmh)
    y = jnp.einsum("bhpn,bhn->bhp", h, cmh)
    y = y + xh * p["D"][:, None]
    y = y.reshape(b, 1, d_in).astype(x1.dtype)
    y = rmsnorm({"scale": p["norm_scale"]}, y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsk,kd->bsd", y, p["out_proj"])
    return out, {"conv": hist[:, 1:], "h": h}
