"""ModelConfig: one dataclass describing every architecture in the pool.

Derived quantities (padded head counts, pattern stages, parameter counts)
are computed here so configs/, launch/ and analysis/ agree on them.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int | None = None      # default d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5

    # MoE
    n_experts: int = 0
    topk_experts: int = 2
    moe_impl: str = "capacity"       # capacity | dense
    capacity_factor: float = 1.25

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_chunk: int = 128
    conv_width: int = 4

    # hybrid (recurrentgemma): layer-kind pattern, tiled over depth
    block_pattern: tuple[str, ...] = ()   # e.g. ("rec", "rec", "attn")
    local_window: int = 0                 # local attention window (0 = full)
    sliding_window: int = 0               # SWA for dense archs (mixtral)
    rglru_c: float = 8.0

    # encoder-decoder (seamless) / cross-attn (vlm)
    encoder_layers: int = 0
    cross_attn_every: int = 0             # vlm: 1 cross layer per N
    memory_tokens: int = 0                # stub modality frontend length
    memory_dim: int = 0                   # frontend embedding dim (=d_model)

    # distribution / fitting knobs
    tp: int = 1                           # model-axis size heads are padded to
    attn_impl: str = "naive"              # naive | blockwise
    attn_block: int = 1024                # kv-chunk for blockwise attention
    remat: bool = True
    scan_layers: bool = True
    dtype: str = "float32"
    logits_chunk: int = 0                 # 0 = unchunked loss
    grad_accum: int = 1
    moment_dtype: str = "float32"         # bf16 halves optimizer HBM (405b)
    grad_dtype: str = "float32"           # bf16 grads: the 405b fit lever
    act_pspec: tuple | None = None        # activation sharding constraint
                                          # (e.g. sequence-parallel residuals)

    # ---- derived ----------------------------------------------------------
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // max(self.n_heads, 1)

    @property
    def n_heads_padded(self) -> int:
        """Q heads padded up to a multiple of tp (zero-init extras keep the
        function exact; see DESIGN.md §5)."""
        if self.n_heads == 0:
            return 0
        return math.ceil(self.n_heads / self.tp) * self.tp

    @property
    def kv_sharded(self) -> bool:
        """KV heads shard over the model axis only when divisible; otherwise
        they replicate over model (+ FSDP over data when enabled)."""
        return self.n_kv_heads > 0 and self.n_kv_heads % self.tp == 0

    @property
    def vocab_padded(self) -> int:
        return math.ceil(self.vocab_size / 256) * 256

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def pattern(self) -> tuple[str, ...]:
        """Layer-kind pattern unit (scanned); defaults per family."""
        if self.block_pattern:
            return self.block_pattern
        if self.family == "ssm":
            return ("ssm",)
        if self.family == "moe":
            return ("attn_moe",)
        if self.family == "vlm" and self.cross_attn_every:
            return ("attn",) * (self.cross_attn_every - 1) + ("cross",)
        return ("attn",)

    @property
    def stages(self) -> tuple[tuple[tuple[str, ...], int], ...]:
        """(pattern, repeats) stages covering n_layers; the tail partial
        pattern becomes its own stage so scan stacks stay homogeneous."""
        pat = self.pattern
        full, rem = divmod(self.n_layers, len(pat))
        out = []
        if full:
            out.append((pat, full))
        if rem:
            out.append((pat[:rem], 1))
        return tuple(out)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # rough parameter count for MODEL_FLOPS (6*N*D) reporting
    def param_count_estimate(self) -> int:
        d, dh = self.d_model, self.head_dim_
        h, kv = self.n_heads, self.n_kv_heads
        attn = d * dh * (h + 2 * kv) + h * dh * d
        if self.qkv_bias:
            attn += dh * (h + 2 * kv)
        mlp = 3 * d * self.d_ff
        moe = self.n_experts * 3 * d * self.d_ff + d * self.n_experts
        ssm_inner = self.ssm_expand * d
        ssm = (d * (2 * ssm_inner + 2 * self.ssm_state
                    + ssm_inner // max(self.ssm_headdim, 1))
               + ssm_inner * d) if self.family == "ssm" else 0
        per_kind = {
            "attn": attn + mlp,
            "attn_moe": attn + moe,
            "cross": 2 * attn + mlp,
            "ssm": ssm,
            "rec": (d * 3 * ssm_inner + ssm_inner * d) + mlp,
        }
        total = 0
        for pat, reps in self.stages:
            total += reps * sum(per_kind.get(k, attn + mlp) for k in pat)
        if self.is_encdec:
            total += self.encoder_layers * (attn + mlp)
        total += self.vocab_padded * d * (1 if self.tie_embeddings else 2)
        return total

    def active_param_count_estimate(self) -> int:
        """MoE: experts count only at topk/n_experts duty cycle."""
        if self.n_experts == 0:
            return self.param_count_estimate()
        full = self.param_count_estimate()
        moe_part = self.n_layers * self.n_experts * 3 * self.d_model * self.d_ff
        active_part = self.n_layers * self.topk_experts * 3 * self.d_model * self.d_ff
        return full - moe_part + active_part
