from repro.models.config import ModelConfig
from repro.models.transformer import (abstract_model, decode_step, forward,
                                      init_cache, init_model, loss_fn,
                                      make_model_defs)

__all__ = ["ModelConfig", "abstract_model", "decode_step", "forward",
           "init_cache", "init_model", "loss_fn", "make_model_defs"]
