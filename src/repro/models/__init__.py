"""The single import surface of the model zoo.

``serve/`` (and anything else driving models as probability generators)
imports from HERE, never from an architecture module: the protocol
entry points dispatch per family (``models.protocol``), so the serving
stack is generator-agnostic — the paper's pluggable-model contract.
"""

from repro.models.config import ModelConfig
from repro.models.protocol import (FAMILY_PROTOCOLS, ModelProtocol,
                                   PrefillUnsupportedError, StateSpec,
                                   can_prefill, decode_step, get_protocol,
                                   has_recurrent_state, init_state,
                                   prefill_chunk, recurrent_state_tree,
                                   ring_length, state_spec, wrap_length)
from repro.models.transformer import (abstract_model, forward, init_model,
                                      loss_fn, make_model_defs)
# back-compat alias: the protocol name is init_state (the state need not be
# a transformer "cache"); existing callers keep working
from repro.models.transformer import init_cache

__all__ = [
    "ModelConfig",
    # protocol surface
    "FAMILY_PROTOCOLS", "ModelProtocol", "PrefillUnsupportedError",
    "StateSpec", "can_prefill", "decode_step", "get_protocol",
    "has_recurrent_state", "init_state", "prefill_chunk",
    "recurrent_state_tree", "ring_length", "state_spec", "wrap_length",
    # training / construction surface
    "abstract_model", "forward", "init_cache", "init_model", "loss_fn",
    "make_model_defs",
]
