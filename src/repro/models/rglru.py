"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (Griffin "recurrent block"):

    x -> [W_x -> causal conv(4) -> RG-LRU]  (.)  [W_y -> GeLU]  -> W_out

RG-LRU (diagonal gates — TPU-adapted from Griffin's block-diagonal; noted in
DESIGN.md):

    r_t = sigmoid(w_a . u_t + b_a)          (recurrence gate)
    i_t = sigmoid(w_i . u_t + b_i)          (input gate)
    log a_t = -c * softplus(Lambda) * r_t
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The linear recurrence runs as a log-space ``associative_scan`` over the
sequence (parallel depth O(log S) — this is what makes recurrentgemma a
long_500k architecture), and as an O(1) state update in decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamDef
from repro.models.ssm import _causal_conv


def rglru_width(cfg: ModelConfig) -> int:
    return cfg.d_model  # RecurrentGemma: lru_width == d_model


def make_rglru_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = rglru_width(cfg)
    return {
        "w_x": ParamDef((d, w), ("embed", "ssm_inner")),
        "w_y": ParamDef((d, w), ("embed", "ssm_inner")),
        "conv_w": ParamDef((cfg.conv_width, w), (None, "ssm_inner")),
        "conv_b": ParamDef((w,), ("ssm_inner",), init="zeros"),
        "gate_a_w": ParamDef((w,), ("ssm_inner",), init="normal"),
        "gate_a_b": ParamDef((w,), ("ssm_inner",), init="zeros"),
        "gate_i_w": ParamDef((w,), ("ssm_inner",), init="normal"),
        "gate_i_b": ParamDef((w,), ("ssm_inner",), init="zeros"),
        "lam": ParamDef((w,), ("ssm_inner",), init="ones"),
        "w_out": ParamDef((w, d), ("ssm_inner", "embed")),
    }


def _rglru_gates(p: dict, u: jax.Array, cfg: ModelConfig):
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf * p["gate_a_w"] + p["gate_a_b"])
    i = jax.nn.sigmoid(uf * p["gate_i_w"] + p["gate_i_b"])
    log_a = -cfg.rglru_c * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * uf


def rglru_forward(p: dict, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    u = jnp.einsum("bsd,dw->bsw", x, p["w_x"])
    gy = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_y"]))
    u = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, b = _rglru_gates(p, u, cfg)

    # linear recurrence h_t = a_t h_{t-1} + b_t  via associative scan
    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = h.astype(x.dtype) * gy
    return jnp.einsum("bsw,wd->bsd", out, p["w_out"])


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = rglru_width(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def rglru_decode_step(p: dict, x1: jax.Array, cache: dict,
                      cfg: ModelConfig):
    u1 = jnp.einsum("bsd,dw->bsw", x1, p["w_x"])
    gy = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x1, p["w_y"]))
    hist = jnp.concatenate([cache["conv"], u1], axis=1)
    u = (jnp.einsum("bwc,wc->bc", hist, p["conv_w"])
         + p["conv_b"])[:, None, :]
    a, b = _rglru_gates(p, u, cfg)
    h = a[:, 0] * cache["h"] + b[:, 0]
    out = h[:, None, :].astype(x1.dtype) * gy
    y = jnp.einsum("bsw,wd->bsd", out, p["w_out"])
    return y, {"conv": hist[:, 1:], "h": h}
