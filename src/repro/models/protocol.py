"""The model-state protocol: one serve surface for the whole zoo.

The paper treats the probability generator as a pluggable component of the
rANS pipeline; this module is that plug.  Every architecture family in the
registry (dense / moe / ssm / hybrid / vlm / audio) exposes the same four
entry points behind :func:`get_protocol`:

    init_state(cfg, batch, max_len)   -> state pytree (all-zeros leaves)
    decode_step(params, state, token, pos, cfg, memory=None)
                                      -> (logits (B, Vpad), state')
    prefill_chunk(params, state, tokens, pos0, n_valid, cfg)
                                      -> (logits (B, S, Vpad), state')
                                         [optional — see can_prefill]
    state_spec(cfg)                   -> StateSpec

so ``serve.compress``, ``serve.engine`` and ``parallel.chunked`` never
import an architecture module — they carry an *arbitrary state pytree*
whose only contract is:

* every leaf is shaped ``(reps, rows, ...)`` — the row axis is axis 1 on
  every leaf (the engine's slots x lanes batch axis, the lane-mesh shard
  axis: ``parallel.chunked.state_row_specs``);
* a fresh stream's state is all-zeros (``init_state`` zero-initializes
  both KV rings and recurrent state, so the engine's per-slot reset mask
  — zeroing the retiring slot's rows — IS a fresh admit);
* :class:`StateSpec` classifies the leaves: **ring** state (KV caches —
  position-addressed, a bounded window of history, raggedness handled by
  per-row positions) versus **recurrent** state (Mamba2's ``(h, conv)``,
  rGLRU's — position-free, every step mutates it, so frozen rows need an
  explicit select; see ``serve.engine._chunk_body``).

Today every family shares one assembler (``models.transformer`` composes
attn/attn_moe/cross/dec/ssm/rec blocks from the layer-kind pattern), so
the per-family protocols all delegate to it — the protocol's value is the
explicit dispatch + capability surface, and the door it leaves open for a
family with a genuinely different assembler (the probabilistic-circuits
direction in PAPERS.md) to slot in without touching serve/.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax

from repro.models import transformer as _tf
from repro.models.config import ModelConfig


class PrefillUnsupportedError(RuntimeError):
    """The family's state is sequential — no block-parallel prefill.

    Raised (instead of the assembler's bare ``KeyError``) when a caller
    asks for ``prefill_chunk`` on a config whose pattern contains a
    recurrent / cross / enc-dec kind: those blocks carry state or memory
    the teacher-forced block pass does not model, so the only bit-exact
    program is the sequential ``decode_step`` scan.  The engine's
    ``prefill="auto"`` steps down silently; ``prefill="force"`` surfaces
    this error.
    """


# layer kinds whose per-block state is a position-addressed KV ring vs a
# position-free recurrence ("cross" caches nothing: it attends a
# precomputed memory)
_RING_KINDS = ("attn", "attn_moe", "dec")
_RECURRENT_KINDS = ("ssm", "rec")


class StateSpec(NamedTuple):
    """Static classification of a config's serving state.

    ``kinds``       — deduped layer kinds, stage order.
    ``ring``        — any position-addressed KV-ring leaves.
    ``recurrent``   — any position-free recurrent leaves (ssm/rec): these
                      mutate on EVERY step, so engine-frozen rows need an
                      explicit old/new select (ring leaves don't — their
                      writes land at a clamped position the next live step
                      overwrites before attending).
    ``ring_window`` — 0: no ring at all; > 0: the ring is bounded at this
                      window regardless of stream length (local/sliding
                      attention — ``init_state`` allocates
                      ``min(max_len, window)`` slots); -1: unbounded full
                      attention (the ring is ``max_len`` and wrapping it
                      changes the conditioning).
    """
    kinds: tuple[str, ...]
    ring: bool
    recurrent: bool
    ring_window: int


def state_spec(cfg: ModelConfig) -> StateSpec:
    kinds = tuple(dict.fromkeys(k for pat, _ in cfg.stages for k in pat))
    ring = any(k in _RING_KINDS for k in kinds)
    recurrent = any(k in _RECURRENT_KINDS for k in kinds)
    if not ring:
        window = 0
    else:
        window = (cfg.local_window or cfg.sliding_window) or -1
    return StateSpec(kinds=kinds, ring=ring, recurrent=recurrent,
                     ring_window=window)


def ring_length(cfg: ModelConfig, max_len: int) -> int:
    """Actual allocated ring slots of ``init_state(cfg, _, max_len)``.

    Windowed archs only ever allocate a window-sized ring
    (``models.attention.init_kv_cache`` via ``init_block_cache``), so the
    ring a serving loop must reason about is ``min(max_len, window)`` —
    NOT ``max_len``.  Pure-recurrent configs have no ring; their
    "ring length" is reported as ``max_len`` for convenience (nothing
    wraps — see :func:`wrap_length`).
    """
    spec = state_spec(cfg)
    if spec.ring_window > 0:
        return min(max_len, spec.ring_window)
    return max_len


def wrap_length(cfg: ModelConfig, max_len: int) -> int | None:
    """Stream length above which serving diverges from the single-request
    path (the ring wraps a shorter-than-native window), or ``None`` when
    no length does:

    * no ring (pure ssm/rglru): recurrent state is O(1) in stream length —
      nothing ever wraps;
    * bounded window with ``max_len >= window``: both the engine ring
      (``min(max_len, window) == window``) and the single-request ring
      (``min(T, window)``) saturate at the native window, and the attend
      core's reductions are ring-length-invariant — byte-identical at any
      stream length;
    * bounded window with ``max_len < window``: streams longer than
      ``max_len`` wrap an under-sized ring — windowed conditioning
      narrower than the arch's native window;
    * unbounded full attention: streams longer than ``max_len`` wrap and
      condition on a sliding window the full-context path never sees.
    """
    spec = state_spec(cfg)
    if not spec.ring:
        return None
    if spec.ring_window > 0:
        return None if max_len >= spec.ring_window else max_len
    return max_len


class ModelProtocol(NamedTuple):
    """One family's serving entry points (``prefill_chunk`` optional)."""
    family: str
    init_state: Callable
    decode_step: Callable
    prefill_chunk: Callable | None
    state_spec: Callable[[ModelConfig], StateSpec]


def _shared(family: str, prefillable: bool) -> ModelProtocol:
    return ModelProtocol(
        family=family,
        init_state=_tf.init_cache,
        decode_step=_tf.decode_step,
        prefill_chunk=_tf.prefill_chunk if prefillable else None,
        state_spec=state_spec,
    )


# every current family composes the shared assembler; prefill_chunk is
# advertised only by the families whose patterns CAN be all-attention
# (the per-config gate stays can_prefill — e.g. a vlm config with cross
# layers steps down even though the family advertises prefill)
FAMILY_PROTOCOLS: dict[str, ModelProtocol] = {
    "dense": _shared("dense", prefillable=True),
    "moe": _shared("moe", prefillable=True),
    "vlm": _shared("vlm", prefillable=True),
    "audio": _shared("audio", prefillable=False),   # enc-dec memory
    "ssm": _shared("ssm", prefillable=False),
    "hybrid": _shared("hybrid", prefillable=False),
}


def get_protocol(cfg: ModelConfig) -> ModelProtocol:
    try:
        return FAMILY_PROTOCOLS[cfg.family]
    except KeyError:
        raise KeyError(
            f"no model protocol registered for family {cfg.family!r} "
            f"(config {cfg.name!r}): known families are "
            f"{sorted(FAMILY_PROTOCOLS)}") from None


def can_prefill(cfg: ModelConfig) -> bool:
    """True when the teacher-forced block pass is bit-identical to the
    sequential step scan for this config (all-self-attention patterns)."""
    return (get_protocol(cfg).prefill_chunk is not None
            and _tf.can_prefill(cfg))


# ---------------------------------------------------------------------------
# the dispatching module-level surface (what serve/ imports)
# ---------------------------------------------------------------------------

def init_state(cfg: ModelConfig, batch: int, max_len: int):
    """Fresh all-zeros serving state: ``(reps, batch, ...)`` leaves."""
    return get_protocol(cfg).init_state(cfg, batch, max_len)


def decode_step(params, state, token, pos, cfg: ModelConfig, memory=None):
    """One serving step: token (B, 1) -> (logits (B, Vpad), state')."""
    return get_protocol(cfg).decode_step(params, state, token, pos, cfg,
                                         memory=memory)


def prefill_chunk(params, state, tokens, pos0, n_valid, cfg: ModelConfig):
    """Teacher-forced block chunk — named error when the family can't."""
    if not can_prefill(cfg):
        raise PrefillUnsupportedError(
            f"config {cfg.name!r} (family {cfg.family!r}, kinds "
            f"{state_spec(cfg).kinds}) carries sequential state: "
            "prefill_chunk would not be bit-identical to the decode_step "
            "scan — run the sequential step program instead")
    return get_protocol(cfg).prefill_chunk(params, state, tokens, pos0,
                                           n_valid, cfg)


def recurrent_state_tree(state):
    """Bool pytree over ``state``: True on recurrent leaves, False on ring.

    Classification is by state *pytree path*, not by config: the block
    caches key their recurrent leaves under ``"ssm"`` / ``"rec"`` dicts
    (``models.transformer.init_block_cache``), and KV rings under
    ``"kv"``.  The engine maps this tree against old/new state to freeze
    inactive rows' recurrent leaves (ring leaves keep the zero-cost
    clamped-position trick — see ``serve.engine._chunk_body``).
    """
    from jax.tree_util import DictKey, tree_map_with_path

    def classify(path, _leaf):
        return any(isinstance(k, DictKey) and k.key in _RECURRENT_KINDS
                   for k in path)

    return tree_map_with_path(classify, state)


def has_recurrent_state(state) -> bool:
    return any(jax.tree.leaves(recurrent_state_tree(state)))
