"""Parameter machinery: declarative defs with logical sharding axes.

Model code builds a pytree of :class:`ParamDef` (shape + logical axis names +
init rule).  From that single source of truth we derive:

  * ``init_params``     — materialized arrays (deterministic per path),
  * ``abstract_params`` — ShapeDtypeStruct tree for AOT lowering (the
                          multi-pod dry-run never allocates weights),
  * ``pspec_tree``      — PartitionSpec tree via logical->mesh axis rules
                          (parallel/sharding.py owns the rule sets).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis name per dim
    init: str = "normal"                  # normal | zeros | ones
    scale: float = 0.02
    # padding-to-TP support: true (unpadded) extent per dim, None = full.
    # Entries beyond the true size are zero-initialized so padded heads are
    # function-preserving (DESIGN.md §5).
    true_sizes: tuple[int | None, ...] | None = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)
        if self.true_sizes is not None:
            assert len(self.true_sizes) == len(self.shape)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _flatten(tree, prefix=()):
    if _is_def(tree):
        yield prefix, tree
        return
    for k in sorted(tree):
        yield from _flatten(tree[k], prefix + (k,))


def init_params(tree, key: jax.Array, dtype=jnp.float32):
    """Materialize every ParamDef; rng folded per path so order-independent."""

    def make(path, d: ParamDef):
        if d.init == "zeros":
            return jnp.zeros(d.shape, dtype)
        if d.init == "ones":
            return jnp.ones(d.shape, dtype)
        import zlib
        sub = key
        for p in path:
            # crc32: stable across processes (unlike str hash) -> checkpoints
            # re-initialize identically on restart
            sub = jax.random.fold_in(sub, zlib.crc32(str(p).encode()))
        w = jax.random.normal(sub, d.shape, jnp.float32) * d.scale
        if d.true_sizes is not None:
            for dim, ts in enumerate(d.true_sizes):
                if ts is not None and ts < d.shape[dim]:
                    mask = (jnp.arange(d.shape[dim]) < ts).reshape(
                        [-1 if i == dim else 1 for i in range(len(d.shape))])
                    w = w * mask
        return w.astype(dtype)

    return _map_tree(tree, make)


def abstract_params(tree, dtype=jnp.float32):
    """ShapeDtypeStruct tree — the dry-run's no-allocation weight stand-in."""
    return _map_tree(tree, lambda _, d: jax.ShapeDtypeStruct(d.shape, dtype))


def pspec_tree(tree, rules: dict[str, str | tuple | None]):
    """Logical axes -> PartitionSpec via ``rules`` (missing names replicate)."""

    def to_spec(_, d: ParamDef):
        return P(*[rules.get(a) if a is not None else None for a in d.axes])

    return _map_tree(tree, to_spec)


def _map_tree(tree, fn, path=()):
    if _is_def(tree):
        return fn(path, tree)
    return {k: _map_tree(v, fn, path + (k,)) for k, v in tree.items()}


def param_count(tree) -> int:
    return sum(int(np.prod(d.shape)) for _, d in _flatten(tree))
