"""Mixture-of-Experts FFN: top-k routing with two dispatch schedules.

``capacity`` (default, the at-scale path): GShard-style — tokens are ranked
within their expert by a cumulative one-hot, dropped beyond capacity
C = ceil(topk * N / E * capacity_factor), scattered into an (E, C, D) buffer,
run through batched expert matmuls, and combined back with router weights.
FLOPs scale with *active* parameters; with experts sharded over the model
axis the scatter/gather lower to the expert-parallel all-to-all.

``dense`` (reference): every expert computes every token; exact (no drops),
used by tests to validate the capacity path and by small smoke configs.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.param import ParamDef


def make_moe_defs(cfg: ModelConfig) -> dict:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamDef((d, e), ("embed", None)),
        "wi_gate": ParamDef((e, d, ff), ("experts", "embed", "mlp")),
        "wi_up": ParamDef((e, d, ff), ("experts", "embed", "mlp")),
        "wo": ParamDef((e, ff, d), ("experts", "mlp", "embed")),
    }


def _route(p: dict, x2: jax.Array, cfg: ModelConfig):
    """x2: (N, D) -> (weights (N,k), ids (N,k), aux load-balance loss)."""
    logits = jnp.einsum("nd,de->ne", x2, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.topk_experts)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    # Switch-style aux loss: E * sum_e f_e * p_e
    e = cfg.n_experts
    f_e = jnp.mean(jax.nn.one_hot(ids[:, 0], e, dtype=jnp.float32), axis=0)
    p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return w.astype(x2.dtype), ids, aux


def moe_dense(p: dict, x: jax.Array, cfg: ModelConfig):
    b, s, d = x.shape
    x2 = x.reshape(-1, d)
    w, ids, aux = _route(p, x2, cfg)
    gates = jnp.zeros((x2.shape[0], cfg.n_experts), x.dtype)
    for k in range(cfg.topk_experts):
        gates = gates + jax.nn.one_hot(ids[:, k], cfg.n_experts,
                                       dtype=x.dtype) * w[:, k:k + 1]
    g = jnp.einsum("nd,edf->nef", x2, p["wi_gate"])
    u = jnp.einsum("nd,edf->nef", x2, p["wi_up"])
    y = jnp.einsum("nef,efd->ned", jax.nn.silu(g) * u, p["wo"])
    out = jnp.einsum("ned,ne->nd", y, gates)
    return out.reshape(b, s, d), aux


def moe_capacity(p: dict, x: jax.Array, cfg: ModelConfig):
    """Group-limited capacity dispatch (GShard-style).

    Tokens are ranked within their *batch row* (the DP shard unit), so the
    dispatch buffer is (B, E, C, D) with B sharded over data — §Perf fix:
    global ranking produced an unsharded (E, topk*N_global*1.25/E, D)
    buffer (10 GB f32/chip on mixtral prefill_32k).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.topk_experts
    n = b * s
    w, ids, aux = _route(p, x.reshape(-1, d), cfg)
    w = w.reshape(b, s, k)
    ids = ids.reshape(b, s, k)
    cap = int(math.ceil(k * s / e * cfg.capacity_factor))
    cap = max(4, -(-cap // 4) * 4)  # round up to multiple of 4

    # flatten assignments token-major within each row: a = (s, slot_k)
    eid = ids.reshape(b, s * k)                            # (B, A)
    wgt = w.reshape(b, s * k)
    tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s), k)[None], (b, s * k))
    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)       # (B, A, E)
    rank = (jnp.cumsum(onehot, axis=1) - onehot)           # pos within expert
    rank = jnp.sum(rank * onehot, axis=-1)                 # (B, A)
    keep = rank < cap
    slot = jnp.where(keep, eid * cap + rank, e * cap)      # OOB -> dropped

    bidx = jnp.broadcast_to(jnp.arange(b)[:, None], slot.shape)
    buf = jnp.zeros((b, e * cap, d), x.dtype)
    buf = buf.at[bidx, slot].set(
        jnp.take_along_axis(x, tok[..., None], axis=1), mode="drop")
    buf = buf.reshape(b, e, cap, d)
    g = jnp.einsum("becd,edf->becf", buf, p["wi_gate"])
    u = jnp.einsum("becd,edf->becf", buf, p["wi_up"])
    yb = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u, p["wo"])

    flat = yb.reshape(b, e * cap, d)
    gathered = flat[bidx, jnp.minimum(slot, e * cap - 1)]
    gathered = gathered * keep[..., None] * wgt[..., None]
    out = gathered.reshape(b, s, k, d).sum(axis=2)         # token-major fold
    return out, aux


def moe(p: dict, x: jax.Array, cfg: ModelConfig):
    if cfg.moe_impl == "dense":
        return moe_dense(p, x, cfg)
    return moe_capacity(p, x, cfg)
