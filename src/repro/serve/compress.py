"""LM-driven lossless compression — the paper's full pipeline, end to end.

Fig. 1/2 of the RAS paper: a learned probability generator feeds calibrated
distributions through the SPC (BF16 -> mass-corrected fixed point) into the
multi-lane rANS fabric.  Here the generator is any model-zoo LM and the text
stream is the payload:

  compress    — teacher-forced scan of the *decode* path produces one
                distribution per (lane, position); the SPC quantizes them;
                the multi-lane coder encodes in reverse (rANS is LIFO).
  decompress  — the same scan, except each step's symbol comes out of the
                rANS decoder (prediction-guided: the model's own top-k are
                the trial symbols, verified with O(1) CDF probes and a safe
                binary-search fallback) and is fed back into the model.
                ``backend="kernel"`` adds a second pass: the scan collects
                the per-step tables and top-k candidate planes, then the
                Pallas decode kernel replays the whole bitstream in ONE
                launch with in-kernel candidate speculation (chunked
                streams ride the kernel's chunk grid axis).

Bit-exactness: both directions run the *identical* decode_step function on
the identical cache evolution, so the distributions (and therefore tables
and bitstream) match float-for-float on a given backend — the software
analogue of the paper's determinism contract.  Each batch row is one rANS
lane (the multi-lane fabric, T4).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coder, constants as C, spc
from repro.core.predictors import model_topk_candidates
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache

BOS = 0


class CompressStats(NamedTuple):
    enc: coder.EncodedLanes
    bits_per_symbol: jax.Array
    model_xent_bits: jax.Array     # model cross entropy (bits/symbol) = bound
    avg_probes: jax.Array | None = None


def _step_tables(logits: jax.Array, vocab: int, prob_bits: int):
    """Model logits (lanes, Vpad) -> TableSet (lanes, V) via the SPC."""
    lg = logits[:, :vocab].astype(jnp.float32)
    probs = jax.nn.softmax(lg, axis=-1)
    return spc.tables_from_probs(spc.store_bf16(probs), prob_bits)


@functools.partial(jax.jit, static_argnames=("cfg", "prob_bits"))
def collect_tables(params, cfg: ModelConfig, tokens: jax.Array,
                   prob_bits: int = C.PROB_BITS):
    """Teacher-forced pass: per-(position, lane) coding tables + xent."""
    lanes, t_len = tokens.shape
    cache = init_cache(cfg, lanes, t_len)
    inputs = jnp.concatenate(
        [jnp.full((lanes, 1), BOS, tokens.dtype), tokens[:, :-1]], axis=1)

    def body(carry, t):
        cache = carry
        lg, cache = decode_step(params, cache, inputs[:, t][:, None], t, cfg)
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        lp = jax.nn.log_softmax(lg[:, :cfg.vocab_size].astype(jnp.float32))
        gold = jnp.take_along_axis(lp, tokens[:, t][:, None], -1)[:, 0]
        return cache, (tbl, -jnp.mean(gold))

    _, (tables, nll) = jax.lax.scan(body, cache, jnp.arange(t_len))
    xent_bits = jnp.mean(nll) / jnp.log(2.0)
    return tables, xent_bits   # TableSet fields: (T, lanes, K)


def lm_compress(params, cfg: ModelConfig, tokens: jax.Array,
                prob_bits: int = C.PROB_BITS,
                backend: str = "coder",
                interpret: bool = True) -> CompressStats:
    """tokens (lanes, T) -> multi-lane rANS bitstream + stats.

    ``backend="kernel"`` feeds the teacher-forced ``(T, lanes, K)`` tables
    of :func:`collect_tables` straight into the fused-compaction Pallas
    encode kernel (the adaptive per-lane layout encodes in-kernel and the
    packed stream comes straight off the kernel — no host-side
    ``compact_records`` pass; interpret mode on CPU); ``backend="coder"``
    runs the pure-JAX lane scan.  Both consume ``core.update``, so the
    produced bitstream — including the per-lane ``overflow`` flags on the
    returned ``EncodedLanes`` — is byte-identical either way and
    round-trips through :func:`lm_decompress` bit-exactly.
    """
    lanes, t_len = tokens.shape
    tables, xent_bits = collect_tables(params, cfg, tokens, prob_bits)
    if backend == "kernel":
        from repro.kernels.ops import rans_encode
        enc = rans_encode(tokens.astype(jnp.int32), tables,
                          prob_bits=prob_bits, interpret=interpret)
    elif backend == "coder":
        enc = coder.encode(tokens.astype(jnp.int32), tables)
    else:
        raise ValueError(f"unknown encode backend {backend!r}")
    bits = jnp.mean(enc.length.astype(jnp.float32)) * 8.0 / t_len
    return CompressStats(enc=enc, bits_per_symbol=bits,
                         model_xent_bits=xent_bits)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_symbols", "prob_bits", "topk",
                                    "collect_planes"))
def _lm_decompress_scan(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                        n_symbols: int, prob_bits: int, topk: int,
                        collect_planes: bool = False):
    """Sequential model-driven decode scan (the pure-JAX reference pass).

    With ``collect_planes`` the scan also stacks each step's quantized
    TableSet and model-top-k candidate row — the ``(T, lanes, K)`` tables
    and ``(T, lanes, topk)`` candidate planes the Pallas decode kernel
    consumes (the serve two-pass kernel decode, see :func:`lm_decompress`).
    """
    lanes = enc.buf.shape[0]
    cache = init_cache(cfg, lanes, n_symbols)
    dec0 = coder.decoder_init(enc)
    tok0 = jnp.full((lanes, 1), BOS, jnp.int32)

    def body(carry, t):
        cache, dec, tok = carry
        lg, cache = decode_step(params, cache, tok, t, cfg)
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        cands = model_topk_candidates(lg[:, :cfg.vocab_size], topk)
        dec, sym, probes = coder.decode_get(dec, enc.buf, tbl, prob_bits,
                                            candidates=cands)
        ys = (sym, probes) + ((tbl, cands) if collect_planes else ())
        return (cache, dec, sym[:, None].astype(jnp.int32)), ys

    (_, _, _), ys = jax.lax.scan(
        body, (cache, dec0, tok0), jnp.arange(n_symbols))
    return ys     # (symbols (T, lanes), probes (T, lanes)[, tables, cands])


def lm_decompress(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                  n_symbols: int, prob_bits: int = C.PROB_BITS,
                  topk: int = 4, backend: str = "coder",
                  interpret: bool = True, lane_probes: bool = False):
    """Bitstream -> tokens, decoding with model-top-k speculation (T3).

    ``backend="coder"`` pops every symbol inside the sequential model scan
    (the pure-JAX path).  ``backend="kernel"`` is the two-pass serve decode:
    pass 1 runs the same scan (it must — the model is autoregressive over
    its own decoded tokens) but *collects* the per-step ``(T, lanes, K)``
    tables and ``(T, lanes, topk)`` model-top-k candidate planes; pass 2
    re-decodes the untouched bitstream in ONE Pallas launch with in-kernel
    candidate speculation.  Both passes consume ``core.search``, so pass 2's
    symbols and per-lane probe counters are integer-identical to pass 1's —
    the returned values come from the kernel, making the round-trip against
    ``lm_compress(backend="kernel")`` a true kernel-datapath round-trip.

    Returns ``(tokens (lanes, T), avg_probes[, per-lane probes])``.
    """
    if backend == "coder":
        symbols, probes = _lm_decompress_scan(params, cfg, enc, n_symbols,
                                              prob_bits, topk)
        out = (symbols.T, jnp.mean(probes.astype(jnp.float32)))
        if lane_probes:
            out = out + (jnp.sum(probes, axis=0),)
        return out
    if backend != "kernel":
        raise ValueError(f"unknown decode backend {backend!r}")
    from repro.kernels.ops import rans_decode
    _, _, tables, cands = _lm_decompress_scan(params, cfg, enc, n_symbols,
                                              prob_bits, topk,
                                              collect_planes=True)
    sym, avg, per_lane = rans_decode(enc, n_symbols, tables,
                                     prob_bits=prob_bits, candidates=cands,
                                     interpret=interpret, lane_probes=True)
    if lane_probes:
        return sym, avg, per_lane
    return sym, avg


# ---------------------------------------------------------------------------
# chunked streaming path: payloads longer than one coder buffer.  Encode
# flushes every ``chunk_size`` symbols (chunks stay independently decodable
# and shard across devices — repro.parallel.chunked); decompression walks the
# chunks sequentially with the model cache carried across chunk boundaries,
# so peak coder-buffer memory is O(chunk_size), not O(T).
# ---------------------------------------------------------------------------

class ChunkedCompressStats(NamedTuple):
    chunks: coder.ChunkedLanes
    chunk_size: int
    n_symbols: int
    bits_per_symbol: jax.Array
    model_xent_bits: jax.Array


def lm_compress_chunked(params, cfg: ModelConfig, tokens: jax.Array,
                        chunk_size: int, prob_bits: int = C.PROB_BITS,
                        mesh=None, backend: str = "coder",
                        cap: int | None = None,
                        interpret: bool = True) -> ChunkedCompressStats:
    """tokens (lanes, T) -> chunked multi-lane bitstream + stats.

    Tables still come from one teacher-forced pass (the model cache spans
    chunk boundaries — chunking changes the *coder* framing, never the
    distributions), then the chunk x lane grid is encoded on ``mesh`` via
    ``repro.parallel.chunked`` (vmap fallback on one device).
    ``backend="kernel"`` routes the encode through the fused Pallas
    kernel's chunk grid axis — one ``pallas_call`` per device emitting
    packed streams.  ``cap`` optionally bounds the per-(chunk, lane) byte
    budget; under-provisioned cells come back truncated-but-flagged on
    ``chunks.overflow`` (identically on either backend) and refuse to pack.
    """
    from repro.parallel.chunked import encode_chunked
    lanes, t_len = tokens.shape
    tables, xent_bits = collect_tables(params, cfg, tokens, prob_bits)
    chunks = encode_chunked(tokens.astype(jnp.int32), tables, chunk_size,
                            mesh=mesh, backend=backend, cap=cap,
                            interpret=interpret)
    bits = (jnp.sum(chunks.length.astype(jnp.float32)) * 8.0
            / (lanes * t_len))
    return ChunkedCompressStats(chunks=chunks, chunk_size=chunk_size,
                                n_symbols=t_len, bits_per_symbol=bits,
                                model_xent_bits=xent_bits)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n", "prob_bits", "topk",
                                    "collect_planes"))
def _lm_decompress_chunk(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                         cache, tok, t0, n: int, prob_bits: int, topk: int,
                         collect_planes: bool = False):
    """Decode one chunk (positions [t0, t0+n)) with carried model cache.

    ``collect_planes`` also stacks the chunk's ``(n, lanes, K)`` TableSet
    and ``(n, lanes, topk)`` candidate rows for the kernel's second pass.
    """
    dec0 = coder.decoder_init(enc)

    def body(carry, t):
        cache, dec, tok = carry
        lg, cache = decode_step(params, cache, tok, t, cfg)
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        cands = model_topk_candidates(lg[:, :cfg.vocab_size], topk)
        dec, sym, probes = coder.decode_get(dec, enc.buf, tbl, prob_bits,
                                            candidates=cands)
        ys = (sym, probes) + ((tbl, cands) if collect_planes else ())
        return (cache, dec, sym[:, None].astype(jnp.int32)), ys

    (cache, _, tok), ys = jax.lax.scan(
        body, (cache, dec0, tok), t0 + jnp.arange(n))
    symbols, probes = ys[0], ys[1]
    out = (cache, tok, symbols.T, jnp.sum(probes, axis=0))
    if collect_planes:
        out = out + (ys[2], ys[3])
    return out


def lm_decompress_chunked(params, cfg: ModelConfig,
                          chunks: coder.ChunkedLanes, n_symbols: int,
                          chunk_size: int, prob_bits: int = C.PROB_BITS,
                          topk: int = 4, backend: str = "coder",
                          mesh=None,
                          interpret: bool = True,
                          lane_probes: bool = False):
    """Chunked bitstream -> tokens (bit-exact inverse of lm_compress_chunked).

    The rANS decoder re-initializes per chunk (each chunk is a standalone
    stream); the model cache and fed-back token carry across chunks, so the
    distribution sequence is float-identical to the monolithic path.  With
    ``backend="coder"`` only one chunk's byte buffer is live at a time —
    the streaming-decode shape.

    ``backend="kernel"`` is the chunked two-pass serve decode: pass 1 walks
    the chunks sequentially as above (the model must see its own decoded
    tokens) while collecting every chunk's tables and model-top-k candidate
    planes; pass 2 re-decodes the *entire* chunked stream in ONE Pallas
    launch — the kernel's chunk grid axis replays every (chunk, lane) cell
    with in-kernel state reset and candidate speculation.  Returned symbols
    and probe counters come from the kernel and are integer-identical to
    pass 1's (both consume ``core.search``).

    ``mesh`` (kernel backend only): place pass 2 on a ``("chunks",)``
    device mesh via ``repro.parallel.chunked.decode_chunked`` — the
    collected candidate planes are cut chunk-major and sharded with the
    chunk slab, one kernel launch per device.  Per-lane probe counters are
    not aggregated across devices, so ``lane_probes`` requires
    ``mesh=None``.

    Returns ``(tokens (lanes, T), avg_probes[, per-lane probes])``.
    """
    if backend not in ("coder", "kernel"):
        raise ValueError(f"unknown decode backend {backend!r}")
    if mesh is not None and backend != "kernel":
        raise ValueError(
            "mesh= requires backend='kernel': the coder backend decodes "
            "inside the sequential model scan (pass 1 IS the decode), so "
            "there is no pass 2 to place on a device mesh")
    lanes = chunks.buf.shape[1]
    n_total = coder.num_chunks(n_symbols, chunk_size)
    if chunks.buf.shape[0] != n_total:
        raise ValueError(
            f"stream has {chunks.buf.shape[0]} chunks but n_symbols="
            f"{n_symbols} at chunk_size={chunk_size} implies {n_total}")
    collect = backend == "kernel"
    cache = init_cache(cfg, lanes, n_symbols)
    tok = jnp.full((lanes, 1), BOS, jnp.int32)
    outs, lane_sum, planes = [], jnp.zeros((lanes,), jnp.int32), []
    for c, n in enumerate(coder.chunk_lengths(n_symbols, chunk_size)):
        enc = coder.chunk_encoded(chunks, c)
        res = _lm_decompress_chunk(
            params, cfg, enc, cache, tok, jnp.int32(c * chunk_size), n=n,
            prob_bits=prob_bits, topk=topk, collect_planes=collect)
        cache, tok, sym, probes = res[:4]
        outs.append(sym)
        lane_sum = lane_sum + probes
        if collect:
            planes.append(res[4:])
    if collect:
        tables = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *[p[0] for p in planes])
        cands = jnp.concatenate([p[1] for p in planes], axis=0)
        if mesh is not None:
            if lane_probes:
                raise ValueError(
                    "lane_probes requires mesh=None: the sharded decode "
                    "does not aggregate per-lane counters across devices")
            from repro.parallel.chunked import decode_chunked as pdecode
            return pdecode(chunks, n_symbols, tables, chunk_size, mesh=mesh,
                           prob_bits=prob_bits, backend="kernel",
                           candidates=cands, interpret=interpret)
        from repro.kernels.ops import rans_decode_chunked
        sym, avg, per_lane = rans_decode_chunked(
            chunks, n_symbols, tables, chunk_size, prob_bits=prob_bits,
            candidates=cands, interpret=interpret, lane_probes=True)
        if lane_probes:
            return sym, avg, per_lane
        return sym, avg
    out = (jnp.concatenate(outs, axis=1),
           jnp.sum(lane_sum.astype(jnp.float32)) / (lanes * n_symbols))
    if lane_probes:
        out = out + (lane_sum,)
    return out


# ---------------------------------------------------------------------------
# static-table path (classic rANS with an empirical histogram) — the
# "software rANS" rung of Fig. 1's algorithmic ladder, used by benchmarks.
# ---------------------------------------------------------------------------

def histogram_compress(symbols: np.ndarray, k: int,
                       prob_bits: int = C.PROB_BITS):
    counts = np.bincount(symbols.ravel(), minlength=k)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(
        counts, prob_bits))
    enc = coder.encode(jnp.asarray(symbols, jnp.int32), tbl)
    return enc, tbl


def histogram_decompress(enc: coder.EncodedLanes, n_symbols: int, tbl,
                         prob_bits: int = C.PROB_BITS, predictor=None,
                         backend: str = "kernel", interpret: bool = True):
    """Static-table decode — through the Pallas kernel by default.

    The serving counterpart of :func:`histogram_compress`: both backends
    consume ``core.search``, so symbols and probe telemetry are identical
    whether the decode ran in-kernel (``backend="kernel"``, interpret mode
    on CPU) or in the pure-JAX lane coder (``backend="coder"``).
    ``predictor`` enables prediction-guided search (e.g. the paper's
    ``NeighborAverage`` for image rows).  Returns (symbols, avg_probes).
    """
    if backend == "kernel":
        from repro.kernels.ops import rans_decode
        return rans_decode(enc, n_symbols, tbl, prob_bits=prob_bits,
                           predictor=predictor, interpret=interpret)
    if backend == "coder":
        return coder.decode(enc, n_symbols, tbl, prob_bits,
                            predictor=predictor)
    raise ValueError(f"unknown decode backend {backend!r}")
