"""LM-driven lossless compression — the paper's full pipeline, end to end.

Fig. 1/2 of the RAS paper: a learned probability generator feeds calibrated
distributions through the SPC (BF16 -> mass-corrected fixed point) into the
multi-lane rANS fabric.  Here the generator is any model-zoo LM and the text
stream is the payload:

  compress    — teacher-forced scan of the *decode* path produces one
                distribution per (lane, position); the SPC quantizes them;
                the multi-lane coder encodes in reverse (rANS is LIFO).
  decompress  — the same scan, except each step's symbol comes out of the
                rANS decoder (prediction-guided: the model's own top-k are
                the trial symbols, verified with O(1) CDF probes and a safe
                binary-search fallback) and is fed back into the model.

Bit-exactness: both directions run the *identical* decode_step function on
the identical cache evolution, so the distributions (and therefore tables
and bitstream) match float-for-float on a given backend — the software
analogue of the paper's determinism contract.  Each batch row is one rANS
lane (the multi-lane fabric, T4).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coder, constants as C, spc
from repro.core.predictors import model_topk_candidates
from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache

BOS = 0


class CompressStats(NamedTuple):
    enc: coder.EncodedLanes
    bits_per_symbol: jax.Array
    model_xent_bits: jax.Array     # model cross entropy (bits/symbol) = bound
    avg_probes: jax.Array | None = None


def _step_tables(logits: jax.Array, vocab: int, prob_bits: int):
    """Model logits (lanes, Vpad) -> TableSet (lanes, V) via the SPC."""
    lg = logits[:, :vocab].astype(jnp.float32)
    probs = jax.nn.softmax(lg, axis=-1)
    return spc.tables_from_probs(spc.store_bf16(probs), prob_bits)


@functools.partial(jax.jit, static_argnames=("cfg", "prob_bits"))
def collect_tables(params, cfg: ModelConfig, tokens: jax.Array,
                   prob_bits: int = C.PROB_BITS):
    """Teacher-forced pass: per-(position, lane) coding tables + xent."""
    lanes, t_len = tokens.shape
    cache = init_cache(cfg, lanes, t_len)
    inputs = jnp.concatenate(
        [jnp.full((lanes, 1), BOS, tokens.dtype), tokens[:, :-1]], axis=1)

    def body(carry, t):
        cache = carry
        lg, cache = decode_step(params, cache, inputs[:, t][:, None], t, cfg)
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        lp = jax.nn.log_softmax(lg[:, :cfg.vocab_size].astype(jnp.float32))
        gold = jnp.take_along_axis(lp, tokens[:, t][:, None], -1)[:, 0]
        return cache, (tbl, -jnp.mean(gold))

    _, (tables, nll) = jax.lax.scan(body, cache, jnp.arange(t_len))
    xent_bits = jnp.mean(nll) / jnp.log(2.0)
    return tables, xent_bits   # TableSet fields: (T, lanes, K)


def lm_compress(params, cfg: ModelConfig, tokens: jax.Array,
                prob_bits: int = C.PROB_BITS) -> CompressStats:
    """tokens (lanes, T) -> multi-lane rANS bitstream + stats."""
    lanes, t_len = tokens.shape
    tables, xent_bits = collect_tables(params, cfg, tokens, prob_bits)
    enc = coder.encode(tokens.astype(jnp.int32), tables)
    bits = jnp.mean(enc.length.astype(jnp.float32)) * 8.0 / t_len
    return CompressStats(enc=enc, bits_per_symbol=bits,
                         model_xent_bits=xent_bits)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_symbols", "prob_bits", "topk"))
def lm_decompress(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                  n_symbols: int, prob_bits: int = C.PROB_BITS,
                  topk: int = 4):
    """Bitstream -> tokens, decoding with model-top-k speculation (T3)."""
    lanes = enc.buf.shape[0]
    cache = init_cache(cfg, lanes, n_symbols)
    dec0 = coder.decoder_init(enc)
    tok0 = jnp.full((lanes, 1), BOS, jnp.int32)

    def body(carry, t):
        cache, dec, tok = carry
        lg, cache = decode_step(params, cache, tok, t, cfg)
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        cands = model_topk_candidates(lg[:, :cfg.vocab_size], topk)
        dec, sym, probes = coder.decode_get(dec, enc.buf, tbl, prob_bits,
                                            candidates=cands)
        return (cache, dec, sym[:, None].astype(jnp.int32)), (sym, probes)

    (_, _, _), (symbols, probes) = jax.lax.scan(
        body, (cache, dec0, tok0), jnp.arange(n_symbols))
    return symbols.T, jnp.mean(probes.astype(jnp.float32))


# ---------------------------------------------------------------------------
# static-table path (classic rANS with an empirical histogram) — the
# "software rANS" rung of Fig. 1's algorithmic ladder, used by benchmarks.
# ---------------------------------------------------------------------------

def histogram_compress(symbols: np.ndarray, k: int,
                       prob_bits: int = C.PROB_BITS):
    counts = np.bincount(symbols.ravel(), minlength=k)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(
        counts, prob_bits))
    enc = coder.encode(jnp.asarray(symbols, jnp.int32), tbl)
    return enc, tbl
