"""LM-driven lossless compression — the paper's full pipeline, end to end.

Fig. 1/2 of the RAS paper: a learned probability generator feeds calibrated
distributions through the SPC (BF16 -> mass-corrected fixed point) into the
multi-lane rANS fabric.  Here the generator is any model-zoo LM and the text
stream is the payload:

  compress    — teacher-forced scan of the *decode* path produces one
                distribution per (lane, position); the SPC quantizes them;
                the multi-lane coder encodes in reverse (rANS is LIFO).
  decompress  — the same scan, except each step's symbol comes out of the
                rANS decoder (prediction-guided: the model's own top-k are
                the trial symbols, verified with O(1) CDF probes and a safe
                binary-search fallback) and is fed back into the model.
                Three backends (DESIGN.md §9):
                  * ``backend="kernel"`` — the FUSED serve path: ONE traced
                    program (a ``lax.scan`` carrying model cache + rANS
                    state) where each step runs the model, quantizes its
                    distribution through the SPC decode fast path, and pops
                    one symbol per lane with the per-step Pallas kernel.
                    No pure-JAX reference decode runs on this path;
                  * ``backend="two_pass"`` — the differential reference:
                    pass 1 runs the pure-JAX model scan collecting the
                    per-step tables and top-k candidate planes, pass 2
                    replays the whole bitstream in ONE Pallas launch with
                    in-kernel candidate speculation (chunked streams ride
                    the kernel's chunk grid axis);
                  * ``backend="coder"`` — pure-JAX end to end.
                All three are bit-exact on symbols AND integer-identical on
                the Fig. 4(b) probe counters (single-source search core).

Bit-exactness: both directions run the *identical* decode_step function on
the identical cache evolution, so the distributions (and therefore tables
and bitstream) match float-for-float on a given backend — the software
analogue of the paper's determinism contract.  Each batch row is one rANS
lane (the multi-lane fabric, T4).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitstream, coder, constants as C, spc
from repro.core.predictors import model_topk_candidates
from repro.models import ModelConfig, decode_step, init_state

BOS = 0


class CompressStats(NamedTuple):
    enc: coder.EncodedLanes
    bits_per_symbol: jax.Array
    model_xent_bits: jax.Array     # model cross entropy (bits/symbol) = bound
    avg_probes: jax.Array | None = None


def step_tables(logits: jax.Array, vocab: int, prob_bits: int):
    """Model logits (rows, Vpad) -> TableSet (rows, V) via the SPC.

    THE single-source per-step quantizer of the serve layer: one f32
    softmax, BF16 storage, mass correction, CDF construction.  Every path
    that prices or decodes a stream — ``collect_tables``, the sequential
    and fused decompress scans here, and the batched engine's chunk
    program (``serve.engine._chunk_body``) — calls this function, so the
    tables (and therefore the bytes) cannot drift between the
    single-request and batched services.  Rows are whatever the caller
    batches: lanes, or the engine's flattened slots x lanes.
    """
    lg = logits[:, :vocab].astype(jnp.float32)
    probs = jax.nn.softmax(lg, axis=-1)
    return spc.tables_from_probs(spc.store_bf16(probs), prob_bits)


_step_tables = step_tables      # historical internal alias


def _step_freq_cdf(logits: jax.Array, vocab: int, prob_bits: int):
    """Model logits (lanes, Vpad) -> ``(freq, cdf)`` — decode-side SPC.

    The identical quantization to :func:`_step_tables` (same f32 softmax,
    same BF16 storage, same mass correction, same CDF construction) minus
    the encoder-only Barrett planes — the fused decode's just-in-time table
    path (``spc.freq_cdf_from_probs`` is pinned bit-equal in tests).
    """
    lg = logits[:, :vocab].astype(jnp.float32)
    probs = jax.nn.softmax(lg, axis=-1)
    return spc.freq_cdf_from_probs(spc.store_bf16(probs), prob_bits)


@functools.partial(jax.jit, static_argnames=("cfg", "prob_bits"))
def collect_tables(params, cfg: ModelConfig, tokens: jax.Array,
                   prob_bits: int = C.PROB_BITS):
    """Teacher-forced pass: per-(position, lane) coding tables + xent.

    Runs on ``serve.engine.teacher_forced_scan`` — the same shared scan that
    backs ``prefill``/``generate`` — so the cache evolution pricing the
    bitstream is structurally the serving cache evolution (not a drifting
    private copy of the loop).
    """
    from repro.serve.engine import teacher_forced_scan
    lanes, t_len = tokens.shape
    inputs = jnp.concatenate(
        [jnp.full((lanes, 1), BOS, tokens.dtype), tokens[:, :-1]], axis=1)

    def per_step(lg, t):
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        lp = jax.nn.log_softmax(lg[:, :cfg.vocab_size].astype(jnp.float32))
        gold = jnp.take_along_axis(lp, tokens[:, t][:, None], -1)[:, 0]
        return tbl, -jnp.mean(gold)

    _, (tables, nll) = teacher_forced_scan(params, cfg, inputs, t_len,
                                           step_fn=per_step)
    xent_bits = jnp.mean(nll) / jnp.log(2.0)
    return tables, xent_bits   # TableSet fields: (T, lanes, K)


def lm_compress(params, cfg: ModelConfig, tokens: jax.Array,
                prob_bits: int = C.PROB_BITS,
                backend: str = "coder",
                interpret: bool = True) -> CompressStats:
    """tokens (lanes, T) -> multi-lane rANS bitstream + stats.

    ``backend="kernel"`` feeds the teacher-forced ``(T, lanes, K)`` tables
    of :func:`collect_tables` straight into the fused-compaction Pallas
    encode kernel (the adaptive per-lane layout encodes in-kernel and the
    packed stream comes straight off the kernel — no host-side
    ``compact_records`` pass; interpret mode on CPU); ``backend="coder"``
    runs the pure-JAX lane scan.  Both consume ``core.update``, so the
    produced bitstream — including the per-lane ``overflow`` flags on the
    returned ``EncodedLanes`` — is byte-identical either way and
    round-trips through :func:`lm_decompress` bit-exactly.
    """
    lanes, t_len = tokens.shape
    tables, xent_bits = collect_tables(params, cfg, tokens, prob_bits)
    if backend == "kernel":
        from repro.kernels.ops import rans_encode
        enc = rans_encode(tokens.astype(jnp.int32), tables,
                          prob_bits=prob_bits, interpret=interpret)
    elif backend == "coder":
        enc = coder.encode(tokens.astype(jnp.int32), tables)
    else:
        raise ValueError(f"unknown encode backend {backend!r}")
    bits = jnp.mean(enc.length.astype(jnp.float32)) * 8.0 / t_len
    return CompressStats(enc=enc, bits_per_symbol=bits,
                         model_xent_bits=xent_bits)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_symbols", "prob_bits", "topk",
                                    "collect_planes"))
def _lm_decompress_scan(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                        n_symbols: int, prob_bits: int, topk: int,
                        collect_planes: bool = False):
    """Sequential model-driven decode scan (the pure-JAX reference pass).

    With ``collect_planes`` the scan also stacks each step's quantized
    TableSet and model-top-k candidate row — the ``(T, lanes, K)`` tables
    and ``(T, lanes, topk)`` candidate planes the Pallas decode kernel
    consumes (the serve two-pass kernel decode, see :func:`lm_decompress`).
    """
    lanes = enc.buf.shape[0]
    cache = init_state(cfg, lanes, n_symbols)
    dec0 = coder.decoder_init(enc)
    tok0 = jnp.full((lanes, 1), BOS, jnp.int32)

    def body(carry, t):
        cache, dec, tok = carry
        lg, cache = decode_step(params, cache, tok, t, cfg)
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        cands = model_topk_candidates(lg[:, :cfg.vocab_size], topk)
        dec, sym, probes = coder.decode_get(dec, enc.buf, tbl, prob_bits,
                                            candidates=cands)
        ys = (sym, probes) + ((tbl, cands) if collect_planes else ())
        return (cache, dec, sym[:, None].astype(jnp.int32)), ys

    (_, dec_f, _), ys = jax.lax.scan(
        body, (cache, dec0, tok0), jnp.arange(n_symbols))
    # (symbols (T, lanes), probes (T, lanes)[, tables, cands], underflow)
    return ys + (dec_f.underflow,)


def _fused_scan(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                cache, tok, t0, n: int, prob_bits: int, topk: int,
                interpret: bool):
    """The fused serve decode core (DESIGN.md §9): ONE traced program.

    A ``lax.scan`` over positions ``[t0, t0+n)`` carrying BOTH the model
    cache and the rANS coder state ``(s, ptr)``.  Each step runs the model
    ``decode_step``, quantizes its distribution through the SPC decode fast
    path (:func:`_step_freq_cdf` — no Barrett planes, no ``(T, lanes, K)``
    plane stacking), ranks its top-k trial symbols, and pops one symbol per
    lane with the per-step Pallas kernel
    (``kernels.rans_decode.rans_decode_step``; interpret mode inlines the
    kernel into this very program).  The decoded symbol feeds straight back
    into the model — no pure-JAX reference decode runs anywhere on this
    path, and no table plane ever round-trips through HBM.
    """
    from repro.kernels.rans_decode import rans_decode_step
    dec0 = coder.decoder_init(enc)
    buf_t = enc.buf.T      # (cap, lanes): transposed ONCE, outside the scan

    def body(carry, t):
        cache, s, ptr, under, tok = carry
        lg, cache = decode_step(params, cache, tok, t, cfg)
        freq, cdf = _step_freq_cdf(lg, cfg.vocab_size, prob_bits)
        cands = model_topk_candidates(lg[:, :cfg.vocab_size], topk)
        s, ptr, sym, probes, u = rans_decode_step(
            buf_t, s, ptr, freq, cdf, prob_bits=prob_bits,
            candidates=cands, interpret=interpret)
        carry = (cache, s, ptr, under | (u > 0),
                 sym[:, None].astype(jnp.int32))
        return carry, (sym, probes)

    (cache, _, _, under, tok), (sym, probes) = jax.lax.scan(
        body, (cache, dec0.s, dec0.ptr, dec0.underflow, tok),
        t0 + jnp.arange(n))
    # sym (lanes, n), probes (n, lanes), under (lanes,)
    return cache, tok, sym.T, probes, under


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n_symbols", "prob_bits", "topk",
                                    "interpret"))
def _lm_decompress_fused(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                         n_symbols: int, prob_bits: int, topk: int,
                         interpret: bool = True):
    """Monolithic fused decode: whole stream in one traced program."""
    lanes = enc.buf.shape[0]
    cache = init_state(cfg, lanes, n_symbols)
    tok = jnp.full((lanes, 1), BOS, jnp.int32)
    _, _, sym, probes, under = _fused_scan(params, cfg, enc, cache, tok,
                                           jnp.int32(0), n_symbols,
                                           prob_bits, topk, interpret)
    return sym, probes, under


def _lane_mesh_check(mesh, lanes: int) -> bool:
    """Validate/route a mesh for the fused path (lanes are its parallel
    axis — decode is sequential over positions).  Delegates to the shared
    routing contract ``parallel.chunked.lane_mesh_usable`` (also consumed
    by the batched engine for its slots x lanes row axis): True = place on
    mesh; False = degrade to the single-device program (divisibility
    fallback); wrong-axis meshes raise."""
    from repro.parallel.chunked import lane_mesh_usable
    return lane_mesh_usable(mesh, lanes, what="fused decode "
                            "(backend='kernel')")


def _fused_on_lane_mesh(params, enc, mesh, local_fn):
    """Shard the fused program over a ``("lanes",)`` mesh.

    Lanes are independent end to end (the model treats lanes as batch, the
    coder state and byte streams are per-lane), so each device runs the
    whole fused scan over its local lane slab with zero collectives;
    ``local_fn(params, enc_local) -> (sym (lanes_loc, T), probes)`` is the
    single-device program.  Bit-exact vs the unsharded path.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    lane_axis = 0 if enc.buf.ndim == 2 else 1   # EncodedLanes|ChunkedLanes
    espec = jax.tree.map(lambda _: P(*([None] * lane_axis + ["lanes"])), enc)
    pspec = jax.tree.map(lambda _: P(), params)
    probes_spec = P("lanes") if enc.buf.ndim == 3 else P(None, "lanes")
    # third output: the per-lane stream-exhaustion flag (lanes,)
    return shard_map(local_fn, mesh=mesh, in_specs=(pspec, espec),
                     out_specs=(P("lanes"), probes_spec, P("lanes")),
                     check_rep=False)(params, enc)


def lm_decompress(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                  n_symbols: int, prob_bits: int = C.PROB_BITS,
                  topk: int = 4, backend: str = "coder",
                  mesh=None,
                  interpret: bool = True, lane_probes: bool = False):
    """Bitstream -> tokens, decoding with model-top-k speculation (T3).

    ``backend="coder"`` pops every symbol inside the sequential model scan
    (the pure-JAX path).  ``backend="kernel"`` is the FUSED serve decode:
    one traced program (``lax.scan`` carrying model cache + rANS state)
    whose every step runs the model, the SPC decode fast path, and the
    per-step Pallas decode kernel — the pure-JAX per-symbol reference scan
    never executes on this path.  ``backend="two_pass"`` is the retained
    differential reference: pass 1 runs the pure-JAX scan collecting the
    per-step ``(T, lanes, K)`` tables and ``(T, lanes, topk)`` candidate
    planes; pass 2 re-decodes the untouched bitstream in ONE Pallas launch
    (its reported counters come from the kernel pass ONLY).  All three
    consume ``core.search``, so symbols and per-lane probe counters are
    integer-identical across backends.

    ``mesh``: optional ``("lanes",)`` mesh (``parallel.chunked.lane_mesh``)
    placing the fused program's independent lane axis across devices
    (``backend="kernel"`` only).

    Returns ``(tokens (lanes, T), avg_probes[, per-lane probes])``.
    """
    if mesh is not None and backend != "kernel":
        raise ValueError(
            "mesh= requires backend='kernel': only the fused program has "
            "an independent (lane) axis to place — the coder and two-pass "
            "reference paths are single-device")
    if backend == "coder":
        symbols, probes, under = _lm_decompress_scan(
            params, cfg, enc, n_symbols, prob_bits, topk)
        coder._check_exhausted(under, "lm_decompress")
        out = (symbols.T, jnp.mean(probes.astype(jnp.float32)))
        if lane_probes:
            out = out + (jnp.sum(probes, axis=0),)
        return out
    if backend == "kernel":
        if _lane_mesh_check(mesh, enc.buf.shape[0]):
            def local(params_l, enc_l):
                return _lm_decompress_fused(params_l, cfg, enc_l, n_symbols,
                                            prob_bits, topk, interpret)
            sym, probes, under = _fused_on_lane_mesh(params, enc, mesh,
                                                     local)
        else:
            sym, probes, under = _lm_decompress_fused(
                params, cfg, enc, n_symbols, prob_bits, topk, interpret)
        coder._check_exhausted(under, "lm_decompress")
        out = (sym, jnp.mean(probes.astype(jnp.float32)))
        if lane_probes:
            out = out + (jnp.sum(probes, axis=0),)
        return out
    if backend != "two_pass":
        raise ValueError(f"unknown decode backend {backend!r}")
    from repro.kernels.ops import rans_decode
    # pass-1 flags are discarded: pass 2 (the kernel replay) re-detects
    # exhaustion on the authoritative stream walk and raises host-side
    _, _, tables, cands, _ = _lm_decompress_scan(params, cfg, enc,
                                                 n_symbols, prob_bits, topk,
                                                 collect_planes=True)
    sym, avg, per_lane = rans_decode(enc, n_symbols, tables,
                                     prob_bits=prob_bits, candidates=cands,
                                     interpret=interpret, lane_probes=True)
    if lane_probes:
        return sym, avg, per_lane
    return sym, avg


# ---------------------------------------------------------------------------
# chunked streaming path: payloads longer than one coder buffer.  Encode
# flushes every ``chunk_size`` symbols (chunks stay independently decodable
# and shard across devices — repro.parallel.chunked); decompression walks the
# chunks sequentially with the model cache carried across chunk boundaries,
# so peak coder-buffer memory is O(chunk_size), not O(T).
# ---------------------------------------------------------------------------

class ChunkedCompressStats(NamedTuple):
    chunks: coder.ChunkedLanes
    chunk_size: int
    n_symbols: int
    bits_per_symbol: jax.Array
    model_xent_bits: jax.Array


def lm_compress_chunked(params, cfg: ModelConfig, tokens: jax.Array,
                        chunk_size: int, prob_bits: int = C.PROB_BITS,
                        mesh=None, backend: str = "coder",
                        cap: int | None = None,
                        interpret: bool = True) -> ChunkedCompressStats:
    """tokens (lanes, T) -> chunked multi-lane bitstream + stats.

    Tables still come from one teacher-forced pass (the model cache spans
    chunk boundaries — chunking changes the *coder* framing, never the
    distributions), then the chunk x lane grid is encoded on ``mesh`` via
    ``repro.parallel.chunked`` (vmap fallback on one device).
    ``backend="kernel"`` routes the encode through the fused Pallas
    kernel's chunk grid axis — one ``pallas_call`` per device emitting
    packed streams.  ``cap`` optionally bounds the per-(chunk, lane) byte
    budget; under-provisioned cells come back truncated-but-flagged on
    ``chunks.overflow`` (identically on either backend) and refuse to pack.
    """
    from repro.parallel.chunked import encode_chunked
    lanes, t_len = tokens.shape
    tables, xent_bits = collect_tables(params, cfg, tokens, prob_bits)
    chunks = encode_chunked(tokens.astype(jnp.int32), tables, chunk_size,
                            mesh=mesh, backend=backend, cap=cap,
                            interpret=interpret)
    bits = (jnp.sum(chunks.length.astype(jnp.float32)) * 8.0
            / (lanes * t_len))
    return ChunkedCompressStats(chunks=chunks, chunk_size=chunk_size,
                                n_symbols=t_len, bits_per_symbol=bits,
                                model_xent_bits=xent_bits)


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n", "prob_bits", "topk",
                                    "collect_planes"))
def _lm_decompress_chunk(params, cfg: ModelConfig, enc: coder.EncodedLanes,
                         cache, tok, t0, n: int, prob_bits: int, topk: int,
                         collect_planes: bool = False):
    """Decode one chunk (positions [t0, t0+n)) with carried model cache.

    ``collect_planes`` also stacks the chunk's ``(n, lanes, K)`` TableSet
    and ``(n, lanes, topk)`` candidate rows for the kernel's second pass.
    """
    dec0 = coder.decoder_init(enc)

    def body(carry, t):
        cache, dec, tok = carry
        lg, cache = decode_step(params, cache, tok, t, cfg)
        tbl = _step_tables(lg, cfg.vocab_size, prob_bits)
        cands = model_topk_candidates(lg[:, :cfg.vocab_size], topk)
        dec, sym, probes = coder.decode_get(dec, enc.buf, tbl, prob_bits,
                                            candidates=cands)
        ys = (sym, probes) + ((tbl, cands) if collect_planes else ())
        return (cache, dec, sym[:, None].astype(jnp.int32)), ys

    (cache, dec_f, tok), ys = jax.lax.scan(
        body, (cache, dec0, tok), t0 + jnp.arange(n))
    symbols, probes = ys[0], ys[1]
    out = (cache, tok, symbols.T, jnp.sum(probes, axis=0), dec_f.underflow)
    if collect_planes:
        out = out + (ys[2], ys[3])
    return out


@functools.partial(jax.jit,
                   static_argnames=("cfg", "n", "prob_bits", "topk",
                                    "interpret"))
def _lm_decompress_fused_chunk(params, cfg: ModelConfig,
                               enc: coder.EncodedLanes, cache, tok, t0,
                               n: int, prob_bits: int, topk: int,
                               interpret: bool = True):
    """Fused decode of one chunk (positions [t0, t0+n)), carried cache."""
    return _fused_scan(params, cfg, enc, cache, tok, t0, n, prob_bits,
                       topk, interpret)


def _fused_chunked_local(params, cfg: ModelConfig,
                         chunks: "coder.ChunkedLanes | bitstream.ContainerSlab",
                         n_symbols: int, chunk_size: int, prob_bits: int,
                         topk: int, interpret: bool):
    """Fused chunked decode over (this device's slab of) the lane axis.

    The rANS state re-initializes per chunk (standalone streams); the model
    cache and fed-back token carry across chunk boundaries, exactly like the
    coder path — one fused program per chunk, only that chunk's byte buffer
    live at a time.  Returns ``(symbols (lanes, T), lane probe sums)``.

    ``chunks`` may be a :class:`~repro.core.bitstream.ContainerSlab`: each
    chunk's window then comes straight off the packed payload with one
    device-side gather per chunk (``bitstream.chunk_encoded_from_slab``) —
    the host right-align copy never runs and only one chunk's bytes are
    ever materialized at a time (the streaming-decode shape, kept).
    """
    slab_in = isinstance(chunks, bitstream.ContainerSlab)
    lanes = chunks.offset.shape[1] if slab_in else chunks.buf.shape[1]
    cache = init_state(cfg, lanes, n_symbols)
    tok = jnp.full((lanes, 1), BOS, jnp.int32)
    outs, lane_sum = [], jnp.zeros((lanes,), jnp.int32)
    under = jnp.zeros((lanes,), bool)
    for c, n in enumerate(coder.chunk_lengths(n_symbols, chunk_size)):
        enc = (bitstream.chunk_encoded_from_slab(chunks, c) if slab_in
               else coder.chunk_encoded(chunks, c))
        cache, tok, sym, probes, und = _lm_decompress_fused_chunk(
            params, cfg, enc, cache, tok, jnp.int32(c * chunk_size), n=n,
            prob_bits=prob_bits, topk=topk, interpret=interpret)
        outs.append(sym)
        lane_sum = lane_sum + jnp.sum(probes, axis=0)
        under = under | und
    return jnp.concatenate(outs, axis=1), lane_sum, under


def lm_decompress_chunked(params, cfg: ModelConfig,
                          chunks: "coder.ChunkedLanes | bitstream.ContainerSlab",
                          n_symbols: int,
                          chunk_size: int, prob_bits: int = C.PROB_BITS,
                          topk: int = 4, backend: str = "coder",
                          mesh=None,
                          interpret: bool = True,
                          lane_probes: bool = False):
    """Chunked bitstream -> tokens (bit-exact inverse of lm_compress_chunked).

    The rANS decoder re-initializes per chunk (each chunk is a standalone
    stream); the model cache and fed-back token carry across chunks, so the
    distribution sequence is float-identical to the monolithic path.  With
    ``backend="coder"`` only one chunk's byte buffer is live at a time —
    the streaming-decode shape.

    ``backend="kernel"`` is the FUSED chunked serve decode: one fused
    program per chunk (model step + SPC decode fast path + per-step Pallas
    kernel, the ``lax.scan`` carrying model cache AND rANS state), cache
    and token carried across chunk boundaries — the pure-JAX per-symbol
    reference scan never executes, and it keeps the streaming shape (one
    chunk's byte buffer live at a time).

    ``backend="two_pass"`` is the retained differential reference: pass 1
    walks the chunks sequentially through the pure-JAX scan (the model must
    see its own decoded tokens) while collecting every chunk's tables and
    model-top-k candidate planes; pass 2 re-decodes the *entire* chunked
    stream in ONE Pallas launch — the kernel's chunk grid axis replays
    every (chunk, lane) cell with in-kernel state reset and candidate
    speculation.  Returned symbols and probe counters come from the kernel
    pass ONLY (pass 1's counters are never accumulated), integer-identical
    to the other backends (all consume ``core.search``).

    ``mesh``: for ``backend="kernel"`` a ``("lanes",)`` mesh
    (``parallel.chunked.lane_mesh``) shards the fused program's independent
    lane axis — decode is sequential over chunks, so the chunk axis cannot
    shard the fused path.  For ``backend="two_pass"`` a ``("chunks",)``
    mesh places pass 2 via ``repro.parallel.chunked.decode_chunked`` (the
    collected candidate planes shard chunk-major with the stream slab);
    per-lane counters are not aggregated across chunk shards, so
    ``lane_probes`` there requires ``mesh=None``.

    ``chunks`` may also be a :class:`~repro.core.bitstream.ContainerSlab`
    (``bitstream.parse_chunked`` of a serialized container) on every
    backend: the two_pass kernel replay then decodes ZERO-COPY straight
    from the packed payload slab (the in-kernel DMA window path), while
    the sequential fused/coder scans pull each chunk's window with one
    device-side gather per chunk (``bitstream.chunk_encoded_from_slab``)
    — the host right-align copy never runs on any serve path.  Symbols
    and probe counters are bit-identical to passing the equivalent
    ``ChunkedLanes``.

    Returns ``(tokens (lanes, T), avg_probes[, per-lane probes])``.
    """
    if backend not in ("coder", "kernel", "two_pass"):
        raise ValueError(f"unknown decode backend {backend!r}")
    if mesh is not None and backend == "coder":
        raise ValueError(
            "mesh= requires backend='kernel' or 'two_pass': the coder "
            "backend decodes inside the sequential model scan, so there is "
            "neither a fused program nor a pass 2 to place on a mesh")
    slab_in = isinstance(chunks, bitstream.ContainerSlab)
    n_have = chunks.offset.shape[0] if slab_in else chunks.buf.shape[0]
    lanes = chunks.offset.shape[1] if slab_in else chunks.buf.shape[1]
    n_total = coder.num_chunks(n_symbols, chunk_size)
    if n_have != n_total:
        raise ValueError(
            f"stream has {n_have} chunks but n_symbols="
            f"{n_symbols} at chunk_size={chunk_size} implies {n_total}")
    if backend == "kernel":
        if _lane_mesh_check(mesh, lanes):
            if slab_in:
                # the lane mesh shards dense (…, lanes, cap) arrays; one
                # device-side gather rebuilds them (host copy still never
                # runs) — the unsharded fused path stays per-chunk windows
                chunks = bitstream.slab_to_chunked(chunks)

            def local(params_l, chunks_l):
                return _fused_chunked_local(params_l, cfg, chunks_l,
                                            n_symbols, chunk_size,
                                            prob_bits, topk, interpret)
            sym, lane_sum, under = _fused_on_lane_mesh(params, chunks, mesh,
                                                       local)
        else:
            sym, lane_sum, under = _fused_chunked_local(
                params, cfg, chunks, n_symbols, chunk_size, prob_bits,
                topk, interpret)
        coder._check_exhausted(under, "lm_decompress_chunked")
        out = (sym, jnp.sum(lane_sum.astype(jnp.float32))
               / (lanes * n_symbols))
        if lane_probes:
            out = out + (lane_sum,)
        return out
    collect = backend == "two_pass"
    cache = init_state(cfg, lanes, n_symbols)
    tok = jnp.full((lanes, 1), BOS, jnp.int32)
    outs, lane_sum, planes = [], jnp.zeros((lanes,), jnp.int32), []
    under = jnp.zeros((lanes,), bool)
    for c, n in enumerate(coder.chunk_lengths(n_symbols, chunk_size)):
        enc = (bitstream.chunk_encoded_from_slab(chunks, c) if slab_in
               else coder.chunk_encoded(chunks, c))
        res = _lm_decompress_chunk(
            params, cfg, enc, cache, tok, jnp.int32(c * chunk_size), n=n,
            prob_bits=prob_bits, topk=topk, collect_planes=collect)
        cache, tok, sym, probes, und = res[:5]
        if collect:
            # two-pass probe purity: pass-1 counters are NEVER accumulated —
            # the reported Fig. 4(b) accounting comes from the kernel pass
            # only (and pass-1 symbols, exhaustion flags are likewise
            # discarded — pass 2 re-detects and raises)
            planes.append(res[5:])
        else:
            outs.append(sym)
            lane_sum = lane_sum + probes
            under = under | und
    if collect:
        tables = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                              *[p[0] for p in planes])
        cands = jnp.concatenate([p[1] for p in planes], axis=0)
        if mesh is not None:
            if lane_probes:
                raise ValueError(
                    "lane_probes requires mesh=None: the sharded decode "
                    "does not aggregate per-lane counters across devices")
            from repro.parallel.chunked import decode_chunked as pdecode
            return pdecode(chunks, n_symbols, tables, chunk_size, mesh=mesh,
                           prob_bits=prob_bits, backend="kernel",
                           candidates=cands, interpret=interpret)
        from repro.kernels.ops import rans_decode_chunked
        if slab_in:
            # pass 2 zero-copy: the kernel DMAs each (chunk, lane) window
            # out of the packed slab — no dense stream rebuild at all
            sym, avg, per_lane = rans_decode_chunked(
                n_symbols=n_symbols, tbl=tables, chunk_size=chunk_size,
                prob_bits=prob_bits, candidates=cands, interpret=interpret,
                lane_probes=True, from_container=chunks)
        else:
            sym, avg, per_lane = rans_decode_chunked(
                chunks, n_symbols, tables, chunk_size, prob_bits=prob_bits,
                candidates=cands, interpret=interpret, lane_probes=True)
        if lane_probes:
            return sym, avg, per_lane
        return sym, avg
    coder._check_exhausted(under, "lm_decompress_chunked")
    out = (jnp.concatenate(outs, axis=1),
           jnp.sum(lane_sum.astype(jnp.float32)) / (lanes * n_symbols))
    if lane_probes:
        out = out + (lane_sum,)
    return out


# ---------------------------------------------------------------------------
# static-table path (classic rANS with an empirical histogram) — the
# "software rANS" rung of Fig. 1's algorithmic ladder, used by benchmarks.
# ---------------------------------------------------------------------------

def histogram_compress(symbols: np.ndarray, k: int,
                       prob_bits: int = C.PROB_BITS):
    counts = np.bincount(symbols.ravel(), minlength=k)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(
        counts, prob_bits))
    enc = coder.encode(jnp.asarray(symbols, jnp.int32), tbl)
    return enc, tbl


def histogram_decompress(enc: coder.EncodedLanes, n_symbols: int, tbl,
                         prob_bits: int = C.PROB_BITS, predictor=None,
                         backend: str = "kernel", interpret: bool = True):
    """Static-table decode — through the Pallas kernel by default.

    The serving counterpart of :func:`histogram_compress`: both backends
    consume ``core.search``, so symbols and probe telemetry are identical
    whether the decode ran in-kernel (``backend="kernel"``, interpret mode
    on CPU) or in the pure-JAX lane coder (``backend="coder"``).
    ``predictor`` enables prediction-guided search (e.g. the paper's
    ``NeighborAverage`` for image rows).  Returns (symbols, avg_probes).
    """
    if backend == "kernel":
        from repro.kernels.ops import rans_decode
        return rans_decode(enc, n_symbols, tbl, prob_bits=prob_bits,
                           predictor=predictor, interpret=interpret)
    if backend == "coder":
        return coder.decode(enc, n_symbols, tbl, prob_bits,
                            predictor=predictor)
    raise ValueError(f"unknown decode backend {backend!r}")
