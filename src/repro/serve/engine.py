"""Serving engine: prefill + autoregressive generation over the model zoo.

``make_serve_step`` is the function the decode-shape dry-runs lower: one new
token against a (possibly ring-buffered) cache of seq_len.  ``prefill`` and
``generate`` drive the same step function for the runnable examples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, cache, token, pos, memory=None)."""

    def serve_step(params, cache, token, pos, memory=None):
        return decode_step(params, cache, token, pos, cfg, memory=memory)

    return serve_step


def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            max_len: int, memory: jax.Array | None = None):
    """Teacher-forced scan of decode_step over the prompt.

    Returns (cache, last_logits).  Using the decode path for prefill keeps
    serving numerics identical to stepwise decode — the property LM-driven
    lossless compression depends on (serve/compress.py).
    """
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)

    def body(carry, t):
        cache = carry
        lg, cache = decode_step(params, cache, tokens[:, t][:, None],
                                t, cfg, memory=memory)
        return cache, lg

    cache, all_logits = jax.lax.scan(body, cache, jnp.arange(s))
    return cache, all_logits[-1]


def generate(params, cfg: ModelConfig, prompt: jax.Array, n_new: int,
             max_len: int, memory: jax.Array | None = None,
             temperature: float = 0.0, key: jax.Array | None = None):
    """Greedy (or sampled) generation; returns (B, n_new) new tokens."""
    b, s = prompt.shape
    cache, last = prefill(params, cfg, prompt, max_len, memory)

    def pick(lg, k):
        lg = lg[:, :cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    def body(carry, i):
        cache, tok, k = carry
        k, sub = jax.random.split(k)
        lg, cache = decode_step(params, cache, tok[:, None], s + i, cfg,
                                memory=memory)
        nxt = pick(lg, sub)
        return (cache, nxt, k), nxt

    k0 = key if key is not None else jax.random.PRNGKey(0)
    first = pick(last, k0)
    (_, _, _), rest = jax.lax.scan(
        body, (cache, first, k0), jnp.arange(1, n_new))
    return jnp.concatenate([first[:, None], rest.T], axis=1)
