"""Serving engine: prefill/generate plus the batched multi-stream service.

Two layers live here:

* the seed-era single-stream primitives — ``make_serve_step`` is the
  function the decode-shape dry-runs lower (one new token against a
  ring-buffered cache of ``max_len``: a cache shorter than the sequence
  wraps, slot = pos % max_len, older entries age out — the wrap is pinned
  logit-level in tests/test_serve_engine.py), and ``prefill`` /
  ``generate`` drive the same step function for the runnable examples;

* :class:`BatchEngine` — the request-level continuous-batching engine
  (DESIGN.md §11).  Concurrent compress/decompress requests are admitted
  into ``slots`` of ONE traced chunk program: the batch axis is
  ``slots * lanes`` rows (shardable over a ``("lanes",)`` mesh), every
  slot owns ``lanes`` rows of one shared ring-buffered model cache with
  its own per-row positions, and the per-row rANS ``DecState`` rides the
  same ``lax.scan`` carry.  Requests join and retire at chunk boundaries
  without retracing (row masks, not new programs); the host double-buffers
  container parse/pack and chunk encode against the in-flight launch.
  Every per-request output is byte-identical to the single-request
  ``serve.compress`` paths — the engine is a scheduler, not a new coder.
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitstream, coder, constants as C
from repro.core.predictors import model_topk_candidates
from repro.models import (ModelConfig, PrefillUnsupportedError, can_prefill,
                          decode_step, init_state, prefill_chunk,
                          recurrent_state_tree, ring_length, state_spec,
                          wrap_length)


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, cache, token, pos, memory=None)."""

    def serve_step(params, cache, token, pos, memory=None):
        return decode_step(params, cache, token, pos, cfg, memory=memory)

    return serve_step


def teacher_forced_scan(params, cfg: ModelConfig, tokens: jax.Array,
                        max_len: int, memory: jax.Array | None = None,
                        step_fn=None):
    """Scan ``decode_step`` over ``tokens`` (B, S), teacher-forced.

    The single shared teacher-forced core of the serve layer: ``prefill``
    consumes it for generation, and ``serve.compress.collect_tables``
    consumes it to drive the SPC (so the cache evolution that prices the
    bitstream is the *same code* that serves the model — the determinism
    contract of LM-driven lossless compression).  ``step_fn(logits, t)``
    optionally maps each step's logits before stacking; default stacks the
    raw logits.  Returns ``(cache, stacked outputs)``.

    ``max_len`` < S is a real ring: positions wrap (slot = pos % max_len)
    and entries older than ``max_len`` age out of attention, so the scan
    conditions on a sliding window of the last ``max_len`` tokens —
    logit-identical to ``forward`` with ``sliding_window=max_len`` (the
    regression test in tests/test_serve_engine.py pins this).
    """
    b, s = tokens.shape
    cache = init_state(cfg, b, max_len)

    def body(carry, t):
        cache = carry
        lg, cache = decode_step(params, cache, tokens[:, t][:, None],
                                t, cfg, memory=memory)
        return cache, (lg if step_fn is None else step_fn(lg, t))

    return jax.lax.scan(body, cache, jnp.arange(s))


def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            max_len: int, memory: jax.Array | None = None):
    """Teacher-forced scan of decode_step over the prompt.

    Returns (cache, last_logits).  Using the decode path for prefill keeps
    serving numerics identical to stepwise decode — the property LM-driven
    lossless compression depends on (serve/compress.py).
    """
    cache, all_logits = teacher_forced_scan(params, cfg, tokens, max_len,
                                            memory)
    return cache, all_logits[-1]


def generate(params, cfg: ModelConfig, prompt: jax.Array, n_new: int,
             max_len: int, memory: jax.Array | None = None,
             temperature: float = 0.0, key: jax.Array | None = None,
             return_logits: bool = False):
    """Greedy (or sampled) generation; returns (B, n_new) new tokens.

    ``return_logits``: also return the per-step logits ``(B, n_new, Vpad)``
    that produced each token — the testable position contract (a cache
    off-by-one perturbs logits long before it flips an argmax).
    """
    b, s = prompt.shape
    cache, last = prefill(params, cfg, prompt, max_len, memory)

    def pick(lg, k):
        lg = lg[:, :cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    def body(carry, i):
        cache, tok, k = carry
        k, sub = jax.random.split(k)
        lg, cache = decode_step(params, cache, tok[:, None], s + i, cfg,
                                memory=memory)
        nxt = pick(lg, sub)
        return (cache, nxt, k), (nxt, lg)

    k0 = key if key is not None else jax.random.PRNGKey(0)
    first = pick(last, k0)
    # prefill consumed positions [0, s), so the first generated token is
    # consumed at position s: scan i = 0..n_new-2 (NOT 1..n_new-1, which
    # would skip cache slot s and attend over a never-written row)
    (_, _, _), (rest, lgs) = jax.lax.scan(
        body, (cache, first, k0), jnp.arange(n_new - 1))
    out = jnp.concatenate([first[:, None], rest.T], axis=1)
    if return_logits:
        logits = jnp.concatenate([last[:, None], lgs.swapaxes(0, 1)], axis=1)
        return out, logits
    return out


# ---------------------------------------------------------------------------
# batched multi-stream engine (continuous batching over slots x lanes rows)
# ---------------------------------------------------------------------------

BOS = 0
MODE_IDLE, MODE_COMPRESS, MODE_DECOMPRESS = 0, 1, 2


class EngineQueueFullError(RuntimeError):
    """Admission queue at capacity — the graceful-degradation backstop."""


class RequestOverflowError(RuntimeError):
    """A request's per-request byte budget (cap) overflowed mid-stream."""


@dataclasses.dataclass
class RequestResult:
    """Terminal state of one engine request.

    ``ok`` requests carry ``blob`` (compress) or ``tokens`` (decompress);
    failed requests carry the named ``error`` instead — a failure retires
    its slot and NEVER perturbs co-batched streams (their rows are
    independent end to end; the isolation test pins byte-exactness of the
    neighbours).  ``probes`` is the request's total CDF-probe count
    (decompress; the Fig. 4(b) accounting, summed over its rows).
    """
    rid: int
    kind: str
    ok: bool
    blob: bytes | None = None
    tokens: np.ndarray | None = None
    error: Exception | None = None
    n_symbols: int = 0
    probes: int = 0
    slot: int = -1
    arrival: float = 0.0
    admitted_at: float = 0.0
    completed_at: float = 0.0


@dataclasses.dataclass
class _Req:
    rid: int
    kind: str                       # "compress" | "decompress"
    arrival: float
    n_symbols: int
    cap: int                        # per-request byte budget (compress)
    tokens: np.ndarray | None = None            # (lanes, T) compress input
    slab: bitstream.ContainerSlab | None = None  # decompress input
    # live-slot state
    slot: int = -1
    admitted_at: float = 0.0
    pos: int = 0                    # symbols dispatched so far
    enc_chunks: list = dataclasses.field(default_factory=list)
    out_syms: list = dataclasses.field(default_factory=list)
    probes: int = 0


def _row_reset(mask, a):
    """Zero the masked rows of a (reps, rows, ...) cache leaf."""
    return jnp.where(mask.reshape((1, -1) + (1,) * (a.ndim - 2)),
                     jnp.zeros_like(a), a)


def _chunk_body(params, cache, tok, fresh, pos0, mode, n_valid, tf, buf,
                start, *, cfg, chunk_size, prob_bits, topk, backend,
                interpret):
    """One continuous-batching cycle: ``chunk_size`` steps over all rows.

    Rows are the flattened ``slots * lanes`` batch axis; all per-slot
    quantities arrive rows-form (``(B,)``/``(B, ...)``) so the body is
    row-local — shardable over a ``("lanes",)`` mesh with no collectives.

      fresh   (B,) bool  — admit boundary: zero the row's cache, tok=BOS
                           (matching ``init_state`` zeros, so the row's
                           evolution equals a fresh single-request scan)
      pos0    (B,) int32 — the row's absolute position at cycle start
      mode    (B,) int32 — MODE_COMPRESS / MODE_DECOMPRESS / MODE_IDLE
      n_valid (B,) int32 — active steps this cycle (ragged join/retire:
                           rows past their request freeze via masks)
      tf      (B, chunk_size) — teacher-forced next-tokens (compress rows)
      buf     (B, cap) uint8 / start (B,) — this cycle's standalone rANS
                           chunk streams (decompress rows; the per-row
                           ``DecState`` re-initializes here each cycle,
                           exactly like the single-request chunk decode)

    Each step runs the shared ``decode_step`` (per-row ring positions),
    quantizes through the single-source ``serve.compress.step_tables``,
    ranks model-top-k candidates and pops one symbol per row
    (``kernels.ops.rans_decode_step_rows``).  Freezing a row past its
    request is per state class (``repro.models.recurrent_state_tree``):
    *ring* leaves need no select — the frozen row clamps its position to
    ``pos0 + n_valid``, so the write lands in the slot the next cycle's
    first step overwrites before attending; *recurrent* leaves (ssm/rec
    ``(h, conv)``) mutate on EVERY step, so frozen rows explicitly keep
    their old leaves (``jnp.where`` on the active mask — for ring-only
    configs the select tree is empty and the traced program is unchanged).
    Returns ``(cache', tok', tables, syms, probes)`` with scan-stacked
    ``(chunk_size, B, ...)`` outputs.
    """
    from repro.kernels.ops import rans_decode_step_rows
    from repro.serve.compress import step_tables
    cache = jax.tree.map(functools.partial(_row_reset, fresh), cache)
    tok = jnp.where(fresh[:, None], jnp.int32(BOS), tok)
    rec_tree = recurrent_state_tree(cache)      # static (trace-time) bools
    dec0 = coder.decoder_init(coder.EncodedLanes(
        buf=buf, start=start, length=jnp.zeros_like(start), overflow=None))
    buf_t = buf.T                   # (cap, B): transposed once, not per step

    def _freeze(active, new_cache, old_cache):
        def sel(rec, new, old):
            if not rec:
                return new
            m = active.reshape((1, -1) + (1,) * (new.ndim - 2))
            return jnp.where(m, new, old)
        return jax.tree.map(sel, rec_tree, new_cache, old_cache)

    def body(carry, t):
        cache, s, ptr, tok = carry
        active = t < n_valid
        pos = pos0 + jnp.minimum(t, n_valid)
        lg, new_cache = decode_step(params, cache, tok, pos, cfg)
        cache = _freeze(active, new_cache, cache)
        tbl = step_tables(lg, cfg.vocab_size, prob_bits)
        cands = model_topk_candidates(lg[:, :cfg.vocab_size], topk)
        s2, p2, sym, probes, u = rans_decode_step_rows(
            buf_t, s, ptr, tbl, prob_bits=prob_bits, candidates=cands,
            backend=backend, interpret=interpret)
        s = jnp.where(active, s2, s)
        ptr = jnp.where(active, p2, ptr)
        und = (active & (u > 0)).astype(jnp.int32)
        nxt = jnp.where(mode == MODE_COMPRESS, tf[:, t],
                        sym.astype(jnp.int32))
        tok = jnp.where(active[:, None], nxt[:, None], tok)
        return (cache, s, ptr, tok), (tbl, sym, probes, und)

    (cache, _, _, tok), (tables, syms, probes, unders) = jax.lax.scan(
        body, (cache, dec0.s, dec0.ptr, tok), jnp.arange(chunk_size))
    return cache, tok, tables, syms, probes, unders


def _prefill_body(params, cache, tok, fresh, pos0, mode, n_valid, tf, buf,
                  start, *, cfg, chunk_size, prob_bits, topk, backend,
                  interpret):
    """All-compress fast cycle: same signature and outputs as
    :func:`_chunk_body`, but ONE teacher-forced block pass over the chunk
    instead of ``chunk_size`` sequential decode steps (the serving-engine
    prefill/decode phase split — compress rows are fully known up front,
    so there is nothing sequential about them).

    Dispatched per cycle by :class:`BatchEngine` only when every live slot
    is an unwrapped compress request and :func:`can_prefill` holds; any
    decompress (symbols feed back step to step) or wrapped row falls back
    to the step program.  Bit-identity with the step cycle is structural:
    ``prefill_chunk`` is pinned bit-identical to the ``decode_step`` scan,
    and the per-position ``step_tables`` runs at the step path's exact
    (B, V) shape under ``lax.map``.  ``syms``/``probes`` are placeholder
    zeros — finalize never reads them for compress rows.
    """
    from repro.serve.compress import step_tables
    del mode, buf, start, topk, backend, interpret  # step-path-only inputs
    cache = jax.tree.map(functools.partial(_row_reset, fresh), cache)
    tok = jnp.where(fresh[:, None], jnp.int32(BOS), tok)
    # the step body feeds the PREVIOUS token at each step: inputs are the
    # carried token (BOS if fresh) followed by all but the last tf token
    inputs = jnp.concatenate([tok, tf[:, :chunk_size - 1]], axis=1)
    lgs, cache = prefill_chunk(params, cache, inputs, pos0, n_valid, cfg)
    tables = jax.lax.map(
        lambda lg: step_tables(lg, cfg.vocab_size, prob_bits),
        jnp.moveaxis(lgs, 1, 0))
    idx = jnp.clip(n_valid - 1, 0, chunk_size - 1)
    last = jnp.take_along_axis(tf, idx[:, None], axis=1)
    tok = jnp.where((n_valid > 0)[:, None], last, tok)
    zeros = jnp.zeros((chunk_size, tok.shape[0]), jnp.int32)
    return cache, tok, tables, zeros, zeros, zeros


@functools.partial(jax.jit, static_argnames=("cap",))
def _encode_rows(symbols, tbl, cap: int):
    """Encode one slot's chunk against its per-(position, lane) tables."""
    return coder.encode(symbols, tbl, cap=cap)


@functools.lru_cache(maxsize=None)
def _compiled_program(body, cfg, chunk_size, prob_bits, topk, backend,
                      interpret):
    """Process-wide program cache: engines with the same traced geometry
    share ONE jitted executable per cycle body (a fresh ``jax.jit``
    wrapper per engine would recompile per instance — the retrace the
    engine exists to avoid)."""
    return jax.jit(functools.partial(
        body, cfg=cfg, chunk_size=chunk_size, prob_bits=prob_bits,
        topk=topk, backend=backend, interpret=interpret))


class BatchEngine:
    """Continuous-batching compress/decompress service over one step program.

    ``slots`` concurrent requests of ``lanes`` rANS lanes each share ONE
    jitted chunk program (:func:`_chunk_body`): a single model cache of
    ``slots * lanes`` rows ring-buffered at ``max_len``, per-row positions,
    per-row coder state.  Requests join (``fresh`` row reset) and retire at
    chunk boundaries via masks — no retracing, any slot occupancy runs the
    same executable.  The run loop keeps ONE cycle in flight: cycle ``k+1``
    is dispatched before cycle ``k``'s outputs are fetched, so the host
    half (container parse / right-align windows, chunk encode, ``pack``)
    overlaps the device half (async dispatch) — the double-buffer pipeline.

    Byte-identity contract: a request of T <= ``max_len`` symbols produces
    output byte-identical to ``lm_compress_chunked`` /
    ``lm_decompress_chunked`` at the same ``chunk_size``/``prob_bits``/
    ``topk`` regardless of co-batched traffic.  (Rows are independent in
    every model op; a ring of length >= T never wraps and its unwritten
    slots contribute exactly-zero attention mass; recurrent state is
    position-free and frozen rows keep their leaves by explicit select;
    the per-chunk coder math is the identical ``core`` single source.)
    The length guard is state-spec-driven (``repro.models.wrap_length``):
    pure-recurrent configs (mamba2) accept ANY length — their O(1) state
    never wraps; windowed configs (recurrentgemma, mixtral) accept any
    length once ``max_len >=`` the native window — both the engine ring
    (``min(max_len, window)``) and the single-request ring saturate at
    the window, byte-identically; only a ring that would wrap *shorter
    than the single-request path's* is rejected with a named error unless
    ``allow_wrap=True`` (wrapped requests condition on a sliding window
    of ``max_len`` tokens and round-trip through this engine).

    Admission: FIFO by ``(arrival, rid)``, at most ``max_queue`` waiting
    requests (``submit_*`` raises :class:`EngineQueueFullError` beyond —
    reject-at-the-door, never corrupt in-flight streams).  Failures
    (e.g. per-request cap overflow) retire their slot with a named error
    in the result; co-batched rows are untouched.

    ``mesh``: optional ``("lanes",)`` mesh (``parallel.chunked.lane_mesh``)
    sharding the program's row axis; indivisible row counts degrade to the
    single-device program bit-exactly (shared routing contract —
    ``parallel.chunked.lane_mesh_usable``).

    ``prefill``: ``"auto"`` (default) dispatches cycles whose live slots
    are all unwrapped compress requests to the block-parallel prefill
    program (:func:`_prefill_body` — the engine's throughput lever: one
    teacher-forced pass replaces ``chunk_size`` sequential steps, bit
    -identically), stepping down cleanly to the step program for families
    without ``prefill_chunk`` (recurrent/hybrid state is sequential —
    ``repro.models.can_prefill``); ``"off"`` forces every cycle onto the
    step program (the byte-identity oracle the tests compare against);
    ``"force"`` raises :class:`repro.models.PrefillUnsupportedError` at
    construction when the family cannot prefill — the named-error guard
    against silently assuming attention state for recurrent families.
    ``prefill_cycles`` counts fast-path dispatches.
    """

    def __init__(self, params, cfg: ModelConfig, *, slots: int = 4,
                 lanes: int = 8, chunk_size: int = 64,
                 max_len: int | None = None, cap: int | None = None,
                 prob_bits: int = C.PROB_BITS, topk: int = 4,
                 max_queue: int = 64, step_backend: str = "coder",
                 mesh=None, interpret: bool = True,
                 prefill: str = "auto"):
        if step_backend not in ("coder", "kernel"):
            raise ValueError(f"unknown step backend {step_backend!r}")
        if prefill not in ("auto", "off", "force"):
            raise ValueError(f"unknown prefill policy {prefill!r} "
                             "(expected 'auto', 'off' or 'force')")
        if prefill == "force" and not can_prefill(cfg):
            raise PrefillUnsupportedError(
                f"prefill='force' on config {cfg.name!r} (family "
                f"{cfg.family!r}, kinds {state_spec(cfg).kinds}): this "
                "family carries sequential state and has no block-parallel "
                "prefill — use prefill='auto' (steps down to the step "
                "program) or 'off'")
        self.params = params
        self.cfg = cfg
        self.slots = slots
        self.lanes = lanes
        self.rows = slots * lanes
        self.chunk_size = chunk_size
        self.max_len = 4 * chunk_size if max_len is None else max_len
        # stream window of the traced program: every decompress chunk cell
        # must fit (validated at submit) — compress caps are per-request
        # and unconstrained (encode runs outside the program)
        self.cap = coder.default_cap(chunk_size) if cap is None else cap
        self.prob_bits = prob_bits
        self.topk = topk
        self.max_queue = max_queue
        self.step_backend = step_backend
        self.interpret = interpret
        # state-spec-driven geometry: what the shared state actually is
        # (ring vs recurrent), how many ring slots init_state allocated,
        # and past which length a request's conditioning would diverge
        # from the single-request path (None = never — see wrap_length)
        self.state_spec = state_spec(cfg)
        self.ring_len = ring_length(cfg, self.max_len)
        self._wrap_len = wrap_length(cfg, self.max_len)
        self._cache = init_state(cfg, self.rows, self.max_len)
        self._tok = jnp.full((self.rows, 1), BOS, jnp.int32)
        self._slots: list[_Req | None] = [None] * slots
        self._queue: list[_Req] = []
        self._next_rid = 0
        self.admission_log: list[tuple[int, int, int]] = []  # (rid, slot, cycle)
        self.prefill_cycles = 0      # cycles served by the prefill program
        self._prog = self._build_program(mesh)
        self._prog_prefill = (self._build_program(mesh, body=_prefill_body)
                              if prefill in ("auto", "force")
                              and can_prefill(cfg) else None)

    # -- program ----------------------------------------------------------

    def _build_program(self, mesh, body=_chunk_body):
        from repro.parallel.chunked import lane_mesh_usable, state_row_specs
        if not lane_mesh_usable(mesh, self.rows,
                                what="batched engine (its slots x lanes rows)"):
            return _compiled_program(
                body, self.cfg, self.chunk_size, self.prob_bits, self.topk,
                self.step_backend, self.interpret)
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        core = functools.partial(
            body, cfg=self.cfg, chunk_size=self.chunk_size,
            prob_bits=self.prob_bits, topk=self.topk,
            backend=self.step_backend, interpret=self.interpret)
        rows, rows2 = P("lanes"), P("lanes", None)
        # arbitrary state pytrees shard by the protocol's row-axis pin
        # (axis 1 on every leaf — ring or recurrent alike)
        carry = state_row_specs(self._cache)
        pspec = jax.tree.map(lambda _: P(), self.params)
        core = shard_map(
            core, mesh=mesh,
            in_specs=(pspec, carry, rows2, rows, rows, rows, rows,
                      rows2, rows2, rows),
            out_specs=(carry, rows2, P(None, "lanes"), P(None, "lanes"),
                       P(None, "lanes"), P(None, "lanes")),
            check_rep=False)
        return jax.jit(core)

    # -- admission --------------------------------------------------------

    def _submit(self, req: _Req) -> int:
        if len(self._queue) >= self.max_queue:
            raise EngineQueueFullError(
                f"engine admission queue is full ({self.max_queue} waiting "
                "requests): drain with run() or raise max_queue — rejecting "
                "at the door keeps in-flight streams untouched")
        self._queue.append(req)
        return req.rid

    def _check_len(self, t_len: int, allow_wrap: bool, what: str):
        if t_len < 1:
            raise ValueError(f"{what} must cover at least 1 symbol")
        # state-spec-driven: pure-recurrent state never wraps (any length
        # is byte-identical to the single-request path), a window-bounded
        # ring with max_len >= window saturates identically at any length;
        # only a ring the single-request path would have sized LARGER can
        # diverge (repro.models.wrap_length)
        if self._wrap_len is not None and t_len > self._wrap_len \
                and not allow_wrap:
            raise ValueError(
                f"request of {t_len} symbols exceeds the engine ring "
                f"({self.ring_len} slots at max_len={self.max_len}): the "
                "shared cache would wrap and condition on a sliding window "
                "narrower than the single-request path's — pass "
                "allow_wrap=True to accept windowed conditioning "
                "(round-trips through this engine, but is no longer "
                "byte-identical to the single-request path), or build the "
                "engine with a larger max_len")

    def submit_compress(self, tokens, arrival: float = 0.0,
                        cap: int | None = None,
                        allow_wrap: bool = False) -> int:
        """Queue a compress request: tokens (lanes, T) -> container blob.

        ``cap`` is the per-request per-(chunk, lane) byte budget (default
        ``coder.default_cap`` of the chunk length — the single-request
        default, so default-cap blobs are byte-identical to
        ``lm_compress_chunked``).  An undersized cap fails ONLY this
        request (:class:`RequestOverflowError` in its result).
        """
        tokens = np.asarray(tokens, np.int32)
        if tokens.ndim != 2 or tokens.shape[0] != self.lanes:
            raise ValueError(
                f"compress tokens must be (lanes={self.lanes}, T), got "
                f"{tokens.shape}: the engine's traced program is shaped "
                "for slots x lanes rows")
        t_len = int(tokens.shape[1])
        self._check_len(t_len, allow_wrap, "a compress request")
        cap = (coder.default_cap(min(self.chunk_size, t_len))
               if cap is None else int(cap))
        rid = self._next_rid
        self._next_rid += 1
        return self._submit(_Req(rid=rid, kind="compress", arrival=arrival,
                                 n_symbols=t_len, cap=cap, tokens=tokens))

    def submit_decompress(self, blob: bytes, arrival: float = 0.0,
                          allow_wrap: bool = False) -> int:
        """Queue a decompress request: container v2 blob -> tokens.

        The blob is parsed and validated here (``bitstream.parse_chunked``
        named errors surface at submit, not mid-batch) and must match the
        engine's traced geometry: same ``lanes``, ``chunk_size`` and
        ``prob_bits``, every cell within the engine's stream window.
        """
        slab = bitstream.parse_chunked(blob)
        meta = slab.meta
        if meta.lanes != self.lanes:
            raise ValueError(
                f"container has {meta.lanes} lanes but the engine is "
                f"shaped for lanes={self.lanes}: the slots x lanes row "
                "grid is traced into the step program")
        if meta.chunk_size != self.chunk_size:
            raise ValueError(
                f"container chunk_size {meta.chunk_size} != engine "
                f"chunk_size {self.chunk_size}: the engine decodes at its "
                "traced chunk granularity — build a matching engine")
        if meta.prob_bits != self.prob_bits:
            raise ValueError(
                f"container prob_bits {meta.prob_bits} != engine "
                f"prob_bits {self.prob_bits}")
        max_cell = int(np.max(slab.length)) if slab.length.size else 0
        if max_cell > self.cap:
            raise ValueError(
                f"container cell of {max_cell} bytes exceeds the engine's "
                f"stream window (cap={self.cap}): build the engine with "
                f"cap >= {max_cell}")
        self._check_len(int(meta.n_symbols), allow_wrap,
                        "a decompress request")
        rid = self._next_rid
        self._next_rid += 1
        return self._submit(_Req(rid=rid, kind="decompress", arrival=arrival,
                                 n_symbols=int(meta.n_symbols), cap=self.cap,
                                 slab=slab))

    # -- one scheduling cycle --------------------------------------------

    def _admit(self, now: float, cycle: int):
        self._queue.sort(key=lambda r: (r.arrival, r.rid))
        for s in range(self.slots):
            if self._slots[s] is not None:
                continue
            pick = next((r for r in self._queue if r.arrival <= now), None)
            if pick is None:
                break
            self._queue.remove(pick)
            pick.slot, pick.admitted_at = s, now
            self._slots[s] = pick
            self.admission_log.append((pick.rid, s, cycle))

    def _build_cycle(self):
        """Host half of a cycle: rows-form inputs for every live slot.

        For decompress slots this is the zero-copy container side — the
        chunk's per-lane spans are right-aligned straight out of the
        parsed payload slab into the program's stream window (the only
        byte copy on the path).  Returns (spec, arrays) or None when no
        slot has steps to run.
        """
        B, S, cap = self.rows, self.chunk_size, self.cap
        fresh = np.zeros(B, bool)
        pos0 = np.zeros(B, np.int32)
        mode = np.zeros(B, np.int32)
        n_valid = np.zeros(B, np.int32)
        tf = np.zeros((B, S), np.int32)
        buf = np.zeros((B, cap), np.uint8)
        start = np.zeros(B, np.int32)
        spec = []
        prefillable = self._prog_prefill is not None
        for s, req in enumerate(self._slots):
            if req is None or req.pos >= req.n_symbols:
                continue
            # decompress rows feed decoded symbols back step to step, and
            # rows wrapping the ALLOCATED ring (ring_len = min(max_len,
            # window), not max_len) overwrite slots still visible to
            # in-chunk queries — both force the cycle onto the step
            # program (attn_prefill requires pos0 + S <= ring slots)
            if req.kind != "compress" or req.n_symbols > self.ring_len:
                prefillable = False
            r0, r1 = s * self.lanes, (s + 1) * self.lanes
            n_c = min(S, req.n_symbols - req.pos)
            c = req.pos // S
            fresh[r0:r1] = req.pos == 0
            pos0[r0:r1] = req.pos
            n_valid[r0:r1] = n_c
            if req.kind == "compress":
                mode[r0:r1] = MODE_COMPRESS
                tf[r0:r1, :n_c] = req.tokens[:, req.pos:req.pos + n_c]
            else:
                mode[r0:r1] = MODE_DECOMPRESS
                slab = req.slab
                payload = np.asarray(slab.slab, np.uint8)
                off = np.asarray(slab.offset)
                ln = np.asarray(slab.length)
                for l in range(self.lanes):
                    o, n = int(off[c, l]), int(ln[c, l])
                    buf[r0 + l, cap - n:] = payload[o:o + n]
                    start[r0 + l] = cap - n
            spec.append((req.rid, s, c, n_c,
                         req.pos + n_c >= req.n_symbols))
            req.pos += n_c
        if not spec:
            return None
        return spec, prefillable, (jnp.asarray(fresh), jnp.asarray(pos0),
                                   jnp.asarray(mode), jnp.asarray(n_valid),
                                   jnp.asarray(tf), jnp.asarray(buf),
                                   jnp.asarray(start))

    def _launch(self, built):
        """Device half: dispatch the cycle program asynchronously — the
        prefill fast path when every live slot is an unwrapped compress
        request, the step program otherwise."""
        spec, prefillable, (fresh, pos0, mode, n_valid, tf, buf,
                           start) = built
        prog = self._prog
        if prefillable:
            prog = self._prog_prefill
            self.prefill_cycles += 1
        self._cache, self._tok, tables, syms, probes, unders = prog(
            self.params, self._cache, self._tok, fresh, pos0, mode,
            n_valid, tf, buf, start)
        return spec, tables, syms, probes, unders

    def _finalize(self, inflight, now: float, results: dict):
        """Harvest a finished cycle: encode/pack/collect per-slot outputs.

        Runs while the NEXT cycle is already in flight — ``np.asarray``
        here blocks on this cycle's program only.  A cap overflow retires
        its request with :class:`RequestOverflowError`; the slot frees, the
        (at most one) already-dispatched follow-up chunk of the failed
        request is discarded at its own finalize, and no other row is
        touched.
        """
        spec, tables, syms, probes, unders = inflight
        for rid, s, c, n_c, last in spec:
            req = self._slots[s]
            if req is None or req.rid != rid or rid in results:
                continue        # retired mid-flight (failed upstream chunk)
            r0, r1 = s * self.lanes, (s + 1) * self.lanes
            if req.kind == "compress":
                tbl_c = jax.tree.map(lambda a: a[:n_c, r0:r1], tables)
                sym_c = jnp.asarray(
                    req.tokens[:, c * self.chunk_size:
                               c * self.chunk_size + n_c])
                enc = _encode_rows(sym_c, tbl_c, cap=req.cap)
                enc = coder.EncodedLanes(*map(np.asarray, enc))
                if enc.overflow.any():
                    cells = np.nonzero(enc.overflow)[0].tolist()
                    self._retire(req, now, results, error=RequestOverflowError(
                        f"request {rid}: encode overflow in chunk {c} "
                        f"(lanes {cells}): the per-request byte budget "
                        f"(cap={req.cap}) truncated the stream — resubmit "
                        "with a larger cap"))
                    continue
                req.enc_chunks.append(enc)
            else:
                und = np.asarray(unders[:n_c, r0:r1])
                if und.any():
                    cells = np.nonzero(und.any(axis=0))[0].tolist()
                    self._retire(req, now, results,
                                 error=coder.StreamExhaustedError(
                        f"request {rid}: decode over-read in chunk {c} "
                        f"(lanes {cells}): a lane's stream ran out of "
                        "bytes mid-decode — the container is truncated "
                        "or was produced with a different geometry"))
                    continue
                req.out_syms.append(
                    np.asarray(syms[:n_c, r0:r1]).T.astype(np.int32))
                req.probes += int(np.asarray(probes[:n_c, r0:r1]).sum())
            if last:
                self._retire(req, now, results)

    def _retire(self, req: _Req, now: float, results: dict,
                error: Exception | None = None):
        res = RequestResult(rid=req.rid, kind=req.kind, ok=error is None,
                            error=error, n_symbols=req.n_symbols,
                            probes=req.probes, slot=req.slot,
                            arrival=req.arrival, admitted_at=req.admitted_at,
                            completed_at=now)
        if error is None:
            if req.kind == "compress":
                ch = jax.tree.map(lambda *xs: np.stack(xs), *req.enc_chunks)
                res.blob = bitstream.pack_chunked(
                    ch.buf, ch.start, ch.length, ch.overflow,
                    chunk_size=self.chunk_size, n_symbols=req.n_symbols,
                    prob_bits=self.prob_bits)
            else:
                res.tokens = np.concatenate(req.out_syms, axis=1)
        results[req.rid] = res
        self._slots[req.slot] = None

    # -- run loop ---------------------------------------------------------

    def run(self, *, clock: str = "virtual") -> dict[int, RequestResult]:
        """Drain the queue; returns {rid: RequestResult} for every request.

        ``clock="virtual"``: time is the cycle counter — fully
        deterministic (the seeded-admission test contract): arrivals are
        in cycle units and the loop jumps idle gaps.  ``clock="wall"``:
        arrivals are seconds relative to run start; the loop sleeps
        through idle gaps and stamps real latencies (the bench contract).

        One cycle: admit -> build inputs (host) -> dispatch (async device)
        -> finalize the PREVIOUS cycle (host pack/encode, blocking only on
        the already-retired launch).  Keeping exactly one cycle in flight
        double-buffers host container work against the device program.
        """
        if clock not in ("virtual", "wall"):
            raise ValueError(f"unknown clock {clock!r}")
        results: dict[int, RequestResult] = {}
        t0 = time.monotonic()
        wall = clock == "wall"
        vnow, cycle = 0.0, 0
        inflight = None
        while self._queue or any(self._slots) or inflight is not None:
            now = time.monotonic() - t0 if wall else vnow
            if inflight is not None and self._queue \
                    and any(last for _, _, _, _, last in inflight[0]):
                # a retire is pending and successors are waiting: sync the
                # in-flight cycle NOW so the freed slot refills this cycle
                # instead of idling one (steady-state chunks keep the
                # launch-before-finalize double-buffer).
                self._finalize(inflight, now, results)
                inflight = None
            self._admit(now, cycle)
            built = self._build_cycle()
            nxt = self._launch(built) if built is not None else None
            if inflight is not None:
                now = time.monotonic() - t0 if wall else vnow
                self._finalize(inflight, now, results)
            inflight = nxt
            if nxt is None and inflight is None and self._queue:
                gap = min(r.arrival for r in self._queue)
                if wall:
                    time.sleep(max(0.0, gap - (time.monotonic() - t0)))
                else:
                    vnow = max(vnow + 1.0, gap)
            else:
                vnow += 1.0
            cycle += 1
        return results
