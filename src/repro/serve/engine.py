"""Serving engine: prefill + autoregressive generation over the model zoo.

``make_serve_step`` is the function the decode-shape dry-runs lower: one new
token against a (possibly ring-buffered) cache of seq_len.  ``prefill`` and
``generate`` drive the same step function for the runnable examples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import decode_step, init_cache


def make_serve_step(cfg: ModelConfig):
    """Returns serve_step(params, cache, token, pos, memory=None)."""

    def serve_step(params, cache, token, pos, memory=None):
        return decode_step(params, cache, token, pos, cfg, memory=memory)

    return serve_step


def teacher_forced_scan(params, cfg: ModelConfig, tokens: jax.Array,
                        max_len: int, memory: jax.Array | None = None,
                        step_fn=None):
    """Scan ``decode_step`` over ``tokens`` (B, S), teacher-forced.

    The single shared teacher-forced core of the serve layer: ``prefill``
    consumes it for generation, and ``serve.compress.collect_tables``
    consumes it to drive the SPC (so the cache evolution that prices the
    bitstream is the *same code* that serves the model — the determinism
    contract of LM-driven lossless compression).  ``step_fn(logits, t)``
    optionally maps each step's logits before stacking; default stacks the
    raw logits.  Returns ``(cache, stacked outputs)``.
    """
    b, s = tokens.shape
    cache = init_cache(cfg, b, max_len)

    def body(carry, t):
        cache = carry
        lg, cache = decode_step(params, cache, tokens[:, t][:, None],
                                t, cfg, memory=memory)
        return cache, (lg if step_fn is None else step_fn(lg, t))

    return jax.lax.scan(body, cache, jnp.arange(s))


def prefill(params, cfg: ModelConfig, tokens: jax.Array,
            max_len: int, memory: jax.Array | None = None):
    """Teacher-forced scan of decode_step over the prompt.

    Returns (cache, last_logits).  Using the decode path for prefill keeps
    serving numerics identical to stepwise decode — the property LM-driven
    lossless compression depends on (serve/compress.py).
    """
    cache, all_logits = teacher_forced_scan(params, cfg, tokens, max_len,
                                            memory)
    return cache, all_logits[-1]


def generate(params, cfg: ModelConfig, prompt: jax.Array, n_new: int,
             max_len: int, memory: jax.Array | None = None,
             temperature: float = 0.0, key: jax.Array | None = None,
             return_logits: bool = False):
    """Greedy (or sampled) generation; returns (B, n_new) new tokens.

    ``return_logits``: also return the per-step logits ``(B, n_new, Vpad)``
    that produced each token — the testable position contract (a cache
    off-by-one perturbs logits long before it flips an argmax).
    """
    b, s = prompt.shape
    cache, last = prefill(params, cfg, prompt, max_len, memory)

    def pick(lg, k):
        lg = lg[:, :cfg.vocab_size]
        if temperature <= 0.0:
            return jnp.argmax(lg, -1).astype(jnp.int32)
        return jax.random.categorical(k, lg / temperature).astype(jnp.int32)

    def body(carry, i):
        cache, tok, k = carry
        k, sub = jax.random.split(k)
        lg, cache = decode_step(params, cache, tok[:, None], s + i, cfg,
                                memory=memory)
        nxt = pick(lg, sub)
        return (cache, nxt, k), (nxt, lg)

    k0 = key if key is not None else jax.random.PRNGKey(0)
    first = pick(last, k0)
    # prefill consumed positions [0, s), so the first generated token is
    # consumed at position s: scan i = 0..n_new-2 (NOT 1..n_new-1, which
    # would skip cache slot s and attend over a never-written row)
    (_, _, _), (rest, lgs) = jax.lax.scan(
        body, (cache, first, k0), jnp.arange(n_new - 1))
    out = jnp.concatenate([first[:, None], rest.T], axis=1)
    if return_logits:
        logits = jnp.concatenate([last[:, None], lgs.swapaxes(0, 1)], axis=1)
        return out, logits
    return out
