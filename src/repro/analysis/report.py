"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the
recorded dry-run JSONs.

    PYTHONPATH=src python -m repro.analysis.report experiments/dryrun
"""

from __future__ import annotations

import json
import os
import sys


def load(out_dir: str):
    recs = []
    for name in sorted(os.listdir(out_dir)):
        if name.endswith(".json"):
            recs.append(json.load(open(os.path.join(out_dir, name))))
    return recs


def dryrun_table(recs) -> str:
    lines = ["| mesh | arch | shape | status | GB/chip | lower s | compile s |",
             "|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] == "OK":
            lines.append(
                f"| {r['mesh']} | {r['arch']} | {r['shape']} | OK | "
                f"{r['memory_analysis']['total_per_chip_gb']:.2f} | "
                f"{r['lower_s']} | {r['compile_s']} |")
        elif r["status"] == "SKIP":
            lines.append(f"| {r['mesh']} | {r['arch']} | {r['shape']} | "
                         f"SKIP | — | — | — |")
        else:
            lines.append(f"| {r['mesh']} | {r['arch']} | {r['shape']} | "
                         f"**FAIL** | — | — | — |")
    return "\n".join(lines)


def roofline_table(recs, mesh="pod16x16") -> str:
    lines = ["| arch | shape | compute s | memory s | coll s | dominant | "
             "useful/HLO | peak frac | GB/chip | mult |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "OK" or r["mesh"] != mesh:
            continue
        rf = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.2f} | "
            f"{rf['memory_s']:.2f} | {rf['collective_s']:.3f} | "
            f"**{rf['dominant']}** | {rf['useful_flops_ratio']:.2f} | "
            f"{rf['peak_fraction']:.2%} | "
            f"{r['memory_analysis']['total_per_chip_gb']:.2f} | "
            f"{rf['scan_multiplier']:.0f} |")
    return "\n".join(lines)


def main():
    out_dir = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(out_dir)
    ok = sum(r["status"] == "OK" for r in recs)
    skip = sum(r["status"] == "SKIP" for r in recs)
    fail = sum(r["status"] == "FAIL" for r in recs)
    print(f"## cells: {ok} OK, {skip} SKIP, {fail} FAIL\n")
    print("### Dry-run\n")
    print(dryrun_table(recs))
    print("\n### Roofline (single pod, 16x16)\n")
    print(roofline_table(recs, "pod16x16"))
    print("\n### Roofline (multi-pod, 2x16x16)\n")
    print(roofline_table(recs, "pod2x16x16"))


if __name__ == "__main__":
    main()
