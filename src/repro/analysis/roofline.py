"""Roofline analysis from compiled dry-run artifacts (§Roofline).

TPU v5e hardware constants (the TARGET; this container only compiles):

    peak 197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI.

cost_analysis() numbers are **per partition** (verified: a 512-way sharded
matmul reports total/512 flops), so the three terms are directly:

    compute_s    = hlo_flops / PEAK_FLOPS
    memory_s     = hlo_bytes / HBM_BW
    collective_s = collective_bytes / LINK_BW

MODEL_FLOPS uses 6*N*D (train), 2*N*D (prefill), 2*N*B (decode, one token),
with N = active params for MoE; the ratio MODEL/HLO catches remat and
dispatch waste.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass

from repro.analysis.hlo import collective_stats
from repro.configs.registry import ShapeSpec
from repro.models.config import ModelConfig

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s
LINK_BW = 50e9           # bytes/s per ICI link
# per-core VMEM: single-sourced from the kernel autotuner so the roofline
# machine model and the kernels' block-size selection can never disagree
# (tests pin the re-export; kernels/autotune.py owns the number)
from repro.kernels.autotune import VMEM_BYTES  # noqa: E402,F401


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    collective_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    model_flops_per_chip: float
    useful_flops_ratio: float     # MODEL / HLO per chip
    roofline_s: float             # max of the three terms
    bound_fraction: float         # dominant / sum  (how bound we are)
    peak_fraction: float          # model-useful compute / roofline time
    collectives: dict | None = None
    memory_per_chip_bytes: float | None = None
    scan_multiplier: float = 1.0   # loop-trip correction (see scan_multiplier)

    def to_json(self) -> str:
        return json.dumps(asdict(self), indent=1)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n = cfg.active_param_count_estimate()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one new token per sequence
    return 2.0 * n * shape.global_batch


def scan_multiplier(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Loop-trip correction for XLA:CPU cost_analysis.

    The CPU backend's cost analysis counts each ``while`` body ONCE (verified
    empirically: llama3-405b train reports ~1/1000 of the analytic FLOPs —
    exactly its 126-layer scan x 8 grad-accum microbatches).  All our big
    compute lives inside the layer scan (x accumulation scan for training),
    so the corrected terms are raw x multiplier.  Ops outside the scans
    (embedding, loss) get slightly over-scaled and encoder stacks of enc-dec
    archs slightly under-scaled — documented estimate, applied identically
    to all three terms so term *dominance* is unaffected.
    """
    reps = sum(r for _, r in cfg.stages)
    mult = float(max(reps, 1))
    if shape.kind == "train":
        mult *= max(cfg.grad_accum, 1)
    return mult


def analyze(compiled, cfg: ModelConfig, shape: ShapeSpec, arch: str,
            mesh_name: str, chips: int) -> RooflineReport:
    cost = compiled.cost_analysis()
    mult = scan_multiplier(cfg, shape)
    flops = float(cost.get("flops", 0.0)) * mult
    bytes_acc = float(cost.get("bytes accessed", 0.0)) * mult
    coll = collective_stats(compiled.as_text())
    # loop-body collectives run once per trip; entry-level ones once per step
    cbytes = (float(coll["body_bytes"]) * mult
              + float(coll["entry_bytes"]))

    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_acc / HBM_BW
    collective_s = cbytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_chip = mf / chips
    roof = max(terms.values())
    total = sum(terms.values()) or 1.0

    mem = None
    try:
        ma = compiled.memory_analysis()
        mem = float(ma.argument_size_in_bytes + ma.output_size_in_bytes
                    + ma.temp_size_in_bytes)
    except Exception:
        pass

    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, chips=chips,
        hlo_flops_per_chip=flops, hlo_bytes_per_chip=bytes_acc,
        collective_bytes_per_chip=cbytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        model_flops_total=mf, model_flops_per_chip=mf_chip,
        useful_flops_ratio=(mf_chip / flops) if flops else 0.0,
        roofline_s=roof,
        bound_fraction=roof / total,
        peak_fraction=(mf_chip / PEAK_FLOPS) / roof if roof else 0.0,
        collectives={k: v for k, v in coll.items() if k != "total_bytes"},
        memory_per_chip_bytes=mem,
        scan_multiplier=mult,
    )


def markdown_row(r: RooflineReport) -> str:
    mem_gb = (r.memory_per_chip_bytes or 0) / 2**30
    return (f"| {r.arch} | {r.shape} | {r.mesh} | "
            f"{r.compute_s*1e3:.2f} | {r.memory_s*1e3:.2f} | "
            f"{r.collective_s*1e3:.2f} | **{r.dominant}** | "
            f"{r.useful_flops_ratio:.2f} | {r.peak_fraction:.2%} | "
            f"{mem_gb:.2f} |")


MD_HEADER = ("| arch | shape | mesh | compute ms | memory ms | coll ms | "
             "dominant | useful/HLO | peak frac | GB/chip |\n"
             "|---|---|---|---|---|---|---|---|---|---|")
