"""HLO-text analysis: per-device collective bytes from a compiled module.

cost_analysis() has no collective accounting, so §Roofline's third term is
derived here: parse the (post-SPMD, per-partition) HLO and sum the operand
bytes of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute.  Operand shapes are resolved through a name->shape map
built from the instruction stream.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# `%name = dtype[d0,d1]{layout} opcode(...)` (also tuple results)
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^)=]*?\)?)\s+"
    r"([\w\-]+)\(([^)]*)\)")
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMPUTATION = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->")


def collective_stats(hlo_text: str) -> dict:
    """Returns {op: {"count","bytes"}, "total_bytes", "body_bytes",
    "entry_bytes"}.

    ``body_bytes`` are collectives inside while-loop body computations —
    the cost analysis counts those once per *body*, so the roofline layer
    multiplies them by the loop-trip correction; ``entry_bytes`` execute
    once per step.
    """
    shapes: dict[str, int] = {}
    stats: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0})
    body_bytes = 0
    entry_bytes = 0
    in_loop_body = False
    for line in hlo_text.splitlines():
        cm = _COMPUTATION.match(line)
        if cm and "{" in line:
            cname = cm.group(2)
            in_loop_body = (cm.group(1) is None
                            and ("while" in cname or "body" in cname
                                 or "scan" in cname or "cond" in cname))
        m = _INSTR.match(line)
        if not m:
            continue
        name, type_str, opcode, operands = m.groups()
        nbytes = _shape_bytes(type_str)
        shapes[name] = nbytes
        for coll in COLLECTIVES:
            if opcode.startswith(coll):
                # operand bytes (the data a chip must move); fall back to
                # the result size when operand shapes are unknown.
                ops = 0
                for ref in operands.split(","):
                    ref = ref.strip().lstrip("%")
                    ref = ref.split(" ")[0]
                    ops += shapes.get(ref, 0)
                nb = ops if ops else nbytes
                stats[coll]["count"] += 1
                stats[coll]["bytes"] += nb
                if in_loop_body:
                    body_bytes += nb
                else:
                    entry_bytes += nb
                break
    out = {k: dict(v) for k, v in stats.items()}
    out["total_bytes"] = sum(v["bytes"] for v in stats.values())
    out["body_bytes"] = body_bytes
    out["entry_bytes"] = entry_bytes
    return out


def op_histogram(hlo_text: str, top: int = 15) -> list[tuple[str, int]]:
    """Opcode frequency — remat/redundancy smell test (duplicate fusions)."""
    counts: dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = _INSTR.match(line)
        if m:
            counts[m.group(3)] += 1
    return sorted(counts.items(), key=lambda kv: -kv[1])[:top]
