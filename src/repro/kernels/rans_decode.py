"""Pallas TPU kernel: prediction-guided multi-lane rANS decode (Sec. IV-C, T3).

The decoder inner loop is the paper's focus: its latency is dominated by CDF
probes (state-to-symbol search) and stream reads.  Kernel design:

  * lane-blocked grid as in rans_encode; per-lane state/pointer vectors live
    in the ``fori_loop`` carry (VREGs);
  * every CDF probe and every stream-byte read is a **one-hot contraction**
    (VPU/MXU dense math — the TPU replacement for the RTL's table SRAM
    port);  probes are therefore *the* unit of cost, and the kernel counts
    them per lane exactly like Fig. 4(b);
  * the neighbour-average predictor (paper Fig. 3) runs inside the kernel:
    anchor mu = mean of the last ``window`` decoded symbols, bracket
    [mu-delta, mu+delta], verified against the CDF with a masked fallback to
    the full binary search — bit-exactness is structural (the bracket only
    narrows the search start, the search itself is unchanged);
  * fixed 2-step masked byte refill mirrors the encoder's renorm bound.

VMEM per grid step: stream (cap x Lb) + CDF (K+1) + symbols out (T x Lb);
for T=4096, Lb=128, K=256: ~3.7 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import constants as C
from repro.kernels.common import onehot_gather, onehot_gather_rows

_U32 = jnp.uint32
_I32 = jnp.int32


def _ceil_log2(k: int) -> int:
    return max(1, (k - 1).bit_length())


def _decode_kernel(buf_ref, start_ref, freq_ref, cdf_ref,
                   sym_ref, probes_ref,
                   *, t_len: int, prob_bits: int, k: int,
                   use_pred: bool, window: int, delta: int):
    lanes = buf_ref.shape[1]
    mask = _U32((1 << prob_bits) - 1)
    freq = freq_ref[0]
    cdf = cdf_ref[0]          # (K+1,)
    buf = buf_ref[...]        # (cap, lanes) resident in VMEM

    # --- read the 4-byte big-endian state header
    ptr = start_ref[0].astype(_I32)
    s = jnp.zeros((lanes,), _U32)
    for _ in range(4):
        byte = onehot_gather_rows(buf, ptr).astype(_U32)
        s = (s << 8) | byte
        ptr = ptr + 1

    ctx0 = jnp.full((window, lanes), -1, _I32)
    probes0 = jnp.zeros((lanes,), _I32)

    def body(t, carry):
        s, ptr, probes, ctx = carry
        slot = s & mask
        lo = jnp.zeros((lanes,), _I32)
        hi = jnp.full((lanes,), k, _I32)
        if use_pred:
            valid = ctx >= 0
            n_valid = jnp.sum(valid.astype(_I32), axis=0)
            ssum = jnp.sum(jnp.where(valid, ctx, 0), axis=0)
            mu = jnp.where(n_valid > 0, ssum // jnp.maximum(n_valid, 1), 0)
            lo_w = jnp.clip(mu - delta, 0, k - 1)
            hi_w = jnp.clip(mu + delta + 1, 1, k)
            hit = ((onehot_gather(cdf, lo_w) <= slot)
                   & (slot < onehot_gather(cdf, hi_w)))
            probes = probes + 1  # the window verify probe
            lo = jnp.where(hit, lo_w, lo)
            hi = jnp.where(hit, hi_w, hi)
        # masked fixed-depth binary search with equality early-commit
        for _ in range(_ceil_log2(k)):
            active = (hi - lo) > 1
            mid = (lo + hi) >> 1
            c_mid = onehot_gather(cdf, mid)
            eq = active & (c_mid == slot)
            go_right = c_mid <= slot
            lo = jnp.where(active & go_right, mid, lo)
            hi = jnp.where(eq, mid + 1,
                           jnp.where(active & ~go_right, mid, hi))
            probes = probes + active.astype(_I32)
        x = lo
        sym_ref[pl.dslice(t, 1), :] = x.reshape(1, lanes)
        f = onehot_gather(freq, x)
        start = onehot_gather(cdf[:k], x)
        s = f * (s >> prob_bits) + slot - start
        for _ in range(C.MAX_RENORM_STEPS):
            cond = s < _U32(C.RANS_L)
            byte = onehot_gather_rows(buf, ptr).astype(_U32)
            s = jnp.where(cond, (s << C.RENORM_SHIFT) | byte, s)
            ptr = ptr + cond.astype(_I32)
        if use_pred:
            ctx = jnp.concatenate([ctx[1:], x.reshape(1, lanes)], axis=0)
        return s, ptr, probes, ctx

    _, _, probes, _ = jax.lax.fori_loop(
        0, t_len, body, (s, ptr, probes0, ctx0))
    probes_ref[0, :] = probes


@functools.partial(jax.jit,
                   static_argnames=("t_len", "prob_bits", "use_pred",
                                    "window", "delta", "lane_block",
                                    "interpret"))
def rans_decode_lanes(buf: jax.Array,      # (lanes, cap) uint8 forward stream
                      start: jax.Array,    # (lanes,) int32
                      freq: jax.Array, cdf: jax.Array,
                      t_len: int,
                      prob_bits: int = C.PROB_BITS,
                      use_pred: bool = False,
                      window: int = 4,
                      delta: int = 8,
                      lane_block: int = 128,
                      interpret: bool = True):
    """Decode t_len symbols/lane.  Returns (symbols (lanes,T), probes (lanes,))."""
    lanes, cap = buf.shape
    if lanes % lane_block:
        raise ValueError(f"lanes={lanes} not a multiple of {lane_block}")
    k = freq.shape[-1]
    grid = (lanes // lane_block,)

    sym, probes = pl.pallas_call(
        functools.partial(_decode_kernel, t_len=t_len, prob_bits=prob_bits,
                          k=k, use_pred=use_pred, window=window, delta=delta),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cap, lane_block), lambda i: (0, i)),
            pl.BlockSpec((1, lane_block), lambda i: (0, i)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k + 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((t_len, lane_block), lambda i: (0, i)),
            pl.BlockSpec((1, lane_block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, lanes), _I32),
            jax.ShapeDtypeStruct((1, lanes), _I32),
        ],
        interpret=interpret,
    )(buf.T, start.reshape(1, lanes).astype(_I32),
      freq.reshape(1, k), cdf.reshape(1, k + 1))
    return sym.T, probes[0]
