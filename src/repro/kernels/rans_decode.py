"""Pallas TPU kernel: prediction-guided multi-lane rANS decode (Sec. IV-C, T3).

The decoder inner loop is the paper's focus: its latency is dominated by CDF
probes (state-to-symbol search) and stream reads.  Kernel design:

  * lane-blocked grid as in rans_encode; per-lane state/pointer vectors are
    carried across a ``fori_loop`` (VREGs) and — when the symbol axis is
    blocked — across grid steps in VMEM scratch;
  * the CDF search is **not** reimplemented here: the kernel imports the
    shared search core (:mod:`repro.core.search`) and substitutes its gather
    primitive with a one-hot contraction (VPU/MXU dense math — the TPU
    replacement for the RTL's table SRAM port).  Symbols *and* the canonical
    Fig. 4(b) probe counters are therefore structurally identical to
    ``core.coder.decode``;
  * prediction-guided decoding uses the ``core.predictors`` protocol
    directly (``predictor.init/predict/update`` run inside the kernel), so
    ``NeighborAverage``/``LastValue``/``ZeroPredictor`` behave identically
    in kernel and reference paths — bit-exactness is structural (the
    bracket only narrows the search start, the search itself is unchanged);
  * **candidate planes** (model-top-k speculation, Fig. 2 trial symbols):
    the kernel accepts a ``(T, lanes, topk)`` plane of trial symbols — the
    serve pipeline's model-top-k ids — blocked through VMEM alongside the
    tables.  Each row feeds ``core.search.find_symbol``'s candidate path
    (one O(1) one-hot CDF probe per trial), so in-kernel speculation pays
    exactly the canonical probe accounting of the pure-JAX decoder;
  * **adaptive tables**: besides a static ``(K,)`` TableSet the kernel
    accepts per-position ``(T, K)`` and per-position-per-lane
    ``(T, lanes, K)`` tables — the neural-prior layouts of
    ``serve.compress``.  The T axis is blocked through VMEM
    (``t_block`` rows of freq/cdf per grid step); decoder state persists in
    scratch between T blocks, so arbitrarily long adaptive streams decode
    without holding all T tables on chip;
  * **chunk grid axis**: chunked streams (independent per-chunk flush — the
    interleaved-ANS construction) decode in ONE ``pallas_call``: the chunk
    axis is a grid dimension; at each chunk's first grid step the kernel
    re-reads that chunk's 4-byte state header and resets the read cursors,
    probe counters and predictor context (chunks are standalone streams).
    Ragged chunks are padded to whole T blocks; padding rows decode nothing
    and their output rows are dropped host-side;
  * fixed 2-step masked byte refill mirrors the encoder's renorm bound.

Grid: ``(lanes // lane_block, n_chunks, ceil(chunk_size / t_block))`` — the
T axis iterates fastest (innermost), then chunks, so each (lane block,
chunk) streams its table blocks sequentially while that chunk's byte
stream (cap x Lb) stays resident.

VMEM per grid step: stream (cap x Lb) + tables (t_block x [Lb x] (2K+1)
u32) + candidates (t_block x Lb x topk) + symbols out (t_block x Lb).  For
T=4096, Lb=128, K=256 static: ~3.7 MB; for the (T, lanes, K) adaptive
layout, t_block=8 keeps the table slab at ~2.1 MB.

Context layout note: the predictor protocol's ``(lanes, window)`` context is
kept as-is inside the kernel (sublane-major for the tiny ``window`` axis);
on a real TPU a lane-minor layout would map better onto VREGs, but the
shared-protocol contract wins here and ``window`` is small.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants as C
from repro.core import search
from repro.kernels.common import (masked_refill, onehot_gather,
                                  onehot_gather_lanes, pad_chunk_rows,
                                  read_state_header, unpad_chunk_rows)

_U32 = jnp.uint32
_I32 = jnp.int32
_U8 = jnp.uint8


def _decode_kernel(*refs,
                   t_len: int, chunk_size: int, t_block: int, n_tb: int,
                   prob_bits: int, k: int, layout: str, predictor,
                   ctx_w: int, has_cands: bool, slab: bool = False,
                   cap: int = 0):
    if slab:
        # zero-copy source (DESIGN.md §10): the packed container payload
        # stays one (S,) slab in HBM (memory_space=ANY); per-(chunk, lane)
        # DMA starts ride the grid as a scalar-prefetch plane and each
        # chunk's byte windows are DMA'd into a lane-major VMEM scratch.
        base_ref, slab_ref, wstart_ref, wlen_ref, freq_ref, cdf_ref, \
            *rest = refs
    else:
        buf_ref, start_ref, freq_ref, cdf_ref, *rest = refs
    if has_cands:
        cand_ref, *rest = rest
    if slab:
        sym_ref, probes_ref, under_ref, s_scr, ptr_scr, ctx_scr, win_scr, \
            sem = rest
    else:
        sym_ref, probes_ref, under_ref, s_scr, ptr_scr, ctx_scr = rest
    lanes = sym_ref.shape[1]
    mask = _U32((1 << prob_bits) - 1)
    i = pl.program_id(0)      # lane-block index
    c = pl.program_id(1)      # chunk index
    j = pl.program_id(2)      # T-block index (innermost grid axis)
    # per-lane byte access: dense layout is (cap, lanes) row gathers, the
    # slab window is lane-major (lanes, cap) — same OOB-reads-0 contract
    byte_gather = onehot_gather_lanes if slab else None

    @pl.when(j == 0)
    def _init():
        # per-chunk re-init: every chunk is a standalone stream — read its
        # 4-byte big-endian state header and reset cursors/probes/context
        if slab:
            def dma(lane, _):
                b = base_ref[c, i * lanes + lane]
                cp = pltpu.make_async_copy(slab_ref.at[pl.ds(b, cap)],
                                           win_scr.at[lane], sem)
                cp.start()
                cp.wait()
                return 0
            jax.lax.fori_loop(0, lanes, dma, 0)
            ws = wstart_ref[0].astype(_I32)
            wl = wlen_ref[0].astype(_I32)
            # in-kernel span-bounds clamp: bytes outside this cell's
            # validated [wstart, wstart+length) span read as 0 — identical
            # to the dense path's out-of-stream reads, and a hostile index
            # can never surface another cell's bytes (the DMA base is
            # host-clipped to [0, S-cap], so the copy itself is in-block)
            col = jax.lax.broadcasted_iota(_I32, (lanes, cap), 1)
            win = win_scr[...]
            live = (col >= ws[:, None]) & (col < (ws + wl)[:, None])
            win_scr[...] = jnp.where(live, win, _U8(0))
            s, ptr, und = read_state_header(win_scr[...], ws,
                                            gather=byte_gather,
                                            limit=ws + wl)
        else:
            s, ptr, und = read_state_header(buf_ref[0],
                                            start_ref[0].astype(_I32),
                                            limit=buf_ref.shape[1])
        s_scr[0, :] = s
        ptr_scr[0, :] = ptr
        probes_ref[0, :] = jnp.zeros((lanes,), _I32)
        under_ref[0, :] = und
        if predictor is not None and ctx_w:
            ctx_scr[...] = predictor.init(lanes)

    # this chunk's byte source, resident in VMEM across its T blocks
    buf = win_scr[...] if slab else buf_ref[0]
    # one-past-the-end read bound per lane: the window span end for the
    # slab layout, the (right-aligned) buffer cap for the dense layout
    read_limit = (wstart_ref[0].astype(_I32) + wlen_ref[0].astype(_I32)
                  if slab else buf.shape[0])

    if layout == "static":
        freq_all = freq_ref[0]        # (K,)
        cdf_all = cdf_ref[0]          # (K+1,)

    if predictor is not None:
        ctx0 = (ctx_scr[...] if ctx_w
                else jnp.zeros((lanes, 0), _I32))
    else:
        ctx0 = jnp.zeros((lanes, 0), _I32)

    # valid rows in this T block: the final chunk may be ragged, and padding
    # rows (up to a whole T block) decode nothing
    chunk_len = jnp.minimum(chunk_size, t_len - c * chunk_size)
    n_t = jnp.clip(chunk_len - j * t_block, 0, t_block)

    # zero the symbol block first: rows >= n_t are padding (dropped by the
    # host-side unpad), and valid rows overwrite below
    sym_ref[...] = jnp.zeros(sym_ref.shape, _I32)

    def body(t, carry):
        s, ptr, probes, under, ctx = carry
        slot = s & mask
        if layout == "static":
            freq_t, cdf_t, g = freq_all, cdf_all, onehot_gather
        elif layout == "perpos":
            freq_t = freq_ref[pl.dslice(t, 1), :][0]       # (K,)
            cdf_t = cdf_ref[pl.dslice(t, 1), :][0]         # (K+1,)
            g = onehot_gather
        else:  # "lane": per-position per-lane rows
            freq_t = freq_ref[pl.dslice(t, 1), :, :][0]    # (lanes, K)
            cdf_t = cdf_ref[pl.dslice(t, 1), :, :][0]      # (lanes, K+1)
            g = onehot_gather_lanes
        cand_t = (cand_ref[pl.dslice(t, 1), :, :][0]       # (lanes, topk)
                  if has_cands else None)
        if predictor is not None:
            pred = predictor.predict(ctx)
            cands = cand_t if has_cands else pred.candidates
            x, p = search.find_symbol(cdf_t, k, slot, mu=pred.mu,
                                      delta=pred.delta,
                                      candidates=cands, gather=g)
            ctx = predictor.update(ctx, x)
        else:
            x, p = search.find_symbol(cdf_t, k, slot, candidates=cand_t,
                                      gather=g)
        sym_ref[pl.dslice(t, 1), :] = x.reshape(1, lanes)
        f = g(freq_t, x)
        start = g(cdf_t[..., :k], x)
        s = f * (s >> prob_bits) + slot - start
        if slab:
            s, ptr, u = masked_refill(buf, s, ptr, gather=byte_gather,
                                      limit=read_limit)
        else:
            s, ptr, u = masked_refill(buf, s, ptr, limit=read_limit)
        return s, ptr, probes + p, under + u, ctx

    s, ptr, probes, under, ctx = jax.lax.fori_loop(
        0, n_t, body, (s_scr[0, :], ptr_scr[0, :], probes_ref[0, :],
                       under_ref[0, :], ctx0))
    s_scr[0, :] = s
    ptr_scr[0, :] = ptr
    probes_ref[0, :] = probes
    under_ref[0, :] = under
    if predictor is not None and ctx_w:
        ctx_scr[...] = ctx


@functools.partial(jax.jit,
                   static_argnames=("t_len", "chunk_size", "prob_bits",
                                    "predictor", "lane_block", "t_block",
                                    "interpret"))
def rans_decode_lanes(buf: jax.Array,      # (lanes, cap) uint8 forward stream
                      start: jax.Array,    # (lanes,) int32
                      freq: jax.Array, cdf: jax.Array,
                      t_len: int,
                      chunk_size: int | None = None,
                      prob_bits: int = C.PROB_BITS,
                      predictor=None,
                      candidates: jax.Array | None = None,
                      lane_block: int = 128,
                      t_block: int | None = None,
                      interpret: bool = True):
    """Decode t_len symbols/lane — ONE ``pallas_call`` for the whole stream.

    Returns ``(symbols (lanes, T), probes (n_chunks, lanes))``: the probe
    plane carries the canonical per-(chunk, lane) Fig. 4(b) counters of
    ``core.search`` — integer-identical to ``core.coder.decode[_chunked]``.

    Stream layouts (detected from ``buf.ndim``):
      * ``(lanes, cap)``            — one monolithic stream per lane
                                      (``chunk_size`` must be None);
      * ``(n_chunks, lanes, cap)``  — chunked streams (``ChunkedLanes``
                                      device form): every (chunk, lane) cell
                                      is standalone; the chunk axis is a
                                      *grid* dimension with in-kernel
                                      state/pointer/context reset, not a
                                      host-side loop of launches.  ``start``
                                      must carry the matching leading axis
                                      and ``chunk_size`` is required.

    Table layouts (detected from ``freq.ndim``):
      * ``(K,)``            — static shared table (classic rANS);
      * ``(T, K)``          — per-position shared rows (neural prior, all
                              lanes share each step's distribution);
      * ``(T, lanes, K)``   — per-position per-lane rows (the
                              ``serve.compress`` TableSet layout).
    ``cdf`` must carry the matching shape with a trailing ``K+1``.

    ``predictor`` is a ``core.predictors`` config (hashable NamedTuple) or
    None; ``candidates`` an optional ``(T, lanes, topk)`` plane of
    model-top-k trial symbols (topk == 0 disables speculation), verified
    in-kernel with O(1) probes each; ``t_block`` blocks the T axis through
    VMEM (None = whole chunk in one block).
    """
    if buf.ndim == 2:
        if chunk_size is not None:
            raise ValueError("monolithic (lanes, cap) stream cannot take a "
                             "chunk_size; pass a (n_chunks, lanes, cap) buf")
        buf3 = buf[None]
        start2 = start.reshape(1, -1)
        chunk = t_len
    elif buf.ndim == 3:
        if chunk_size is None:
            raise ValueError("chunked (n_chunks, lanes, cap) stream needs "
                             "chunk_size")
        buf3, start2, chunk = buf, start, min(chunk_size, t_len)
    else:
        raise ValueError(f"unsupported stream rank {buf.ndim}")
    n_chunks, lanes, cap = buf3.shape
    if n_chunks != -(-t_len // chunk):
        raise ValueError(
            f"stream has {n_chunks} chunks but t_len={t_len} at chunk_size="
            f"{chunk} implies {-(-t_len // chunk)}")
    if lanes % lane_block:
        raise ValueError(f"lanes={lanes} not a multiple of {lane_block}")
    k = freq.shape[-1]
    tb = chunk if t_block is None else max(1, min(t_block, chunk))
    n_tb = -(-chunk // tb)
    padded_chunk = n_tb * tb
    total_rows = n_chunks * padded_chunk

    if freq.ndim == 1:
        layout = "static"
        freq_in, cdf_in = freq.reshape(1, k), cdf.reshape(1, k + 1)
        freq_spec = pl.BlockSpec((1, k), lambda i, c, j: (0, 0))
        cdf_spec = pl.BlockSpec((1, k + 1), lambda i, c, j: (0, 0))
    elif freq.ndim == 2:
        if freq.shape[0] != t_len:
            raise ValueError(
                f"per-position tables carry T={freq.shape[0]} rows but "
                f"t_len={t_len}")
        layout = "perpos"
        freq_in = pad_chunk_rows(freq, t_len, chunk, n_chunks, padded_chunk)
        cdf_in = pad_chunk_rows(cdf, t_len, chunk, n_chunks, padded_chunk)
        freq_spec = pl.BlockSpec((tb, k),
                                 lambda i, c, j: (c * n_tb + j, 0))
        cdf_spec = pl.BlockSpec((tb, k + 1),
                                lambda i, c, j: (c * n_tb + j, 0))
    elif freq.ndim == 3:
        if freq.shape[0] != t_len or freq.shape[1] != lanes:
            raise ValueError(
                f"per-lane tables must be (T, lanes, K)=({t_len}, {lanes}, "
                f"{k}); got {freq.shape}")
        layout = "lane"
        freq_in = pad_chunk_rows(freq, t_len, chunk, n_chunks, padded_chunk)
        cdf_in = pad_chunk_rows(cdf, t_len, chunk, n_chunks, padded_chunk)
        freq_spec = pl.BlockSpec((tb, lane_block, k),
                                 lambda i, c, j: (c * n_tb + j, i, 0))
        cdf_spec = pl.BlockSpec((tb, lane_block, k + 1),
                                lambda i, c, j: (c * n_tb + j, i, 0))
    else:
        raise ValueError(f"unsupported table rank {freq.ndim}")

    has_cands = candidates is not None and candidates.shape[-1] > 0
    extra_in, extra_specs = [], []
    if has_cands:
        if candidates.shape[:2] != (t_len, lanes):
            raise ValueError(
                f"candidate planes must be (T, lanes, topk)=({t_len}, "
                f"{lanes}, *); got {candidates.shape}")
        topk = candidates.shape[-1]
        extra_in.append(pad_chunk_rows(candidates.astype(_I32), t_len,
                                       chunk, n_chunks, padded_chunk))
        extra_specs.append(pl.BlockSpec(
            (tb, lane_block, topk), lambda i, c, j: (c * n_tb + j, i, 0)))

    ctx_w = (int(predictor.init(lane_block).shape[-1])
             if predictor is not None else 0)
    grid = (lanes // lane_block, n_chunks, n_tb)

    sym, probes, under = pl.pallas_call(
        functools.partial(_decode_kernel, t_len=t_len, chunk_size=chunk,
                          t_block=tb, n_tb=n_tb, prob_bits=prob_bits, k=k,
                          layout=layout, predictor=predictor, ctx_w=ctx_w,
                          has_cands=has_cands),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, cap, lane_block), lambda i, c, j: (c, 0, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
            freq_spec,
            cdf_spec,
        ] + extra_specs,
        out_specs=[
            pl.BlockSpec((tb, lane_block), lambda i, c, j: (c * n_tb + j, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total_rows, lanes), _I32),
            jax.ShapeDtypeStruct((n_chunks, lanes), _I32),
            jax.ShapeDtypeStruct((n_chunks, lanes), _I32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, lane_block), _U32),              # rANS states
            pltpu.VMEM((1, lane_block), _I32),              # read cursors
            pltpu.VMEM((lane_block, max(1, ctx_w)), _I32),  # predictor ctx
        ],
        interpret=interpret,
    )(buf3.swapaxes(1, 2), start2.astype(_I32), freq_in, cdf_in, *extra_in)
    sym = unpad_chunk_rows(sym, t_len, chunk, n_chunks, padded_chunk)
    return sym.T, probes, under


@functools.partial(jax.jit,
                   static_argnames=("cap", "t_len", "chunk_size",
                                    "prob_bits", "predictor", "lane_block",
                                    "t_block", "interpret"))
def rans_decode_slab(slab: jax.Array,      # (S,) uint8 packed payload slab
                     base: jax.Array,      # (n_chunks, lanes) int32 DMA start
                     wstart: jax.Array,    # (n_chunks, lanes) int32 in-window
                     wlen: jax.Array,      # (n_chunks, lanes) int32 span len
                     freq: jax.Array, cdf: jax.Array, *,
                     cap: int,
                     t_len: int,
                     chunk_size: int,
                     prob_bits: int = C.PROB_BITS,
                     predictor=None,
                     candidates: jax.Array | None = None,
                     lane_block: int = 128,
                     t_block: int | None = None,
                     interpret: bool = True):
    """Zero-copy chunked decode: ONE ``pallas_call`` straight off the
    packed container slab (DESIGN.md §10).

    The per-(chunk, lane) index walk that ``bitstream.unpack_chunked`` used
    to run host-side moves into the kernel: ``base`` rides the grid as a
    scalar-prefetch plane (SMEM), the slab stays unblocked in HBM
    (``memory_space=ANY``), and at each chunk's first grid step the kernel
    DMAs every lane's ``cap``-byte window ``slab[base : base + cap]`` into
    a lane-major VMEM scratch, then clamps bytes outside the cell's
    validated ``[wstart, wstart + wlen)`` span to 0 before reading the
    state header.  ``base`` must be host-clipped to ``[0, S - cap]`` (so
    the DMA can never leave the slab) with ``wstart = offset - base`` —
    :func:`repro.kernels.ops.rans_decode_chunked` derives all three planes
    from a validated :class:`~repro.core.bitstream.ContainerSlab`.

    Symbols and probe counters are bit-identical to
    :func:`rans_decode_lanes` over the dense right-aligned form: the byte
    sequence each lane reads is identical (span bytes then zeros), and the
    table/candidate/search plumbing is shared.
    """
    n_chunks, lanes = base.shape
    chunk = min(chunk_size, t_len)
    if n_chunks != -(-t_len // chunk):
        raise ValueError(
            f"stream has {n_chunks} chunks but t_len={t_len} at chunk_size="
            f"{chunk} implies {-(-t_len // chunk)}")
    if lanes % lane_block:
        raise ValueError(f"lanes={lanes} not a multiple of {lane_block}")
    k = freq.shape[-1]
    tb = chunk if t_block is None else max(1, min(t_block, chunk))
    n_tb = -(-chunk // tb)
    padded_chunk = n_tb * tb
    total_rows = n_chunks * padded_chunk

    # index maps take the scalar-prefetch refs as trailing args (*_)
    if freq.ndim == 1:
        layout = "static"
        freq_in, cdf_in = freq.reshape(1, k), cdf.reshape(1, k + 1)
        freq_spec = pl.BlockSpec((1, k), lambda i, c, j, *_: (0, 0))
        cdf_spec = pl.BlockSpec((1, k + 1), lambda i, c, j, *_: (0, 0))
    elif freq.ndim == 2:
        if freq.shape[0] != t_len:
            raise ValueError(
                f"per-position tables carry T={freq.shape[0]} rows but "
                f"t_len={t_len}")
        layout = "perpos"
        freq_in = pad_chunk_rows(freq, t_len, chunk, n_chunks, padded_chunk)
        cdf_in = pad_chunk_rows(cdf, t_len, chunk, n_chunks, padded_chunk)
        freq_spec = pl.BlockSpec((tb, k),
                                 lambda i, c, j, *_: (c * n_tb + j, 0))
        cdf_spec = pl.BlockSpec((tb, k + 1),
                                lambda i, c, j, *_: (c * n_tb + j, 0))
    elif freq.ndim == 3:
        if freq.shape[0] != t_len or freq.shape[1] != lanes:
            raise ValueError(
                f"per-lane tables must be (T, lanes, K)=({t_len}, {lanes}, "
                f"{k}); got {freq.shape}")
        layout = "lane"
        freq_in = pad_chunk_rows(freq, t_len, chunk, n_chunks, padded_chunk)
        cdf_in = pad_chunk_rows(cdf, t_len, chunk, n_chunks, padded_chunk)
        freq_spec = pl.BlockSpec((tb, lane_block, k),
                                 lambda i, c, j, *_: (c * n_tb + j, i, 0))
        cdf_spec = pl.BlockSpec((tb, lane_block, k + 1),
                                lambda i, c, j, *_: (c * n_tb + j, i, 0))
    else:
        raise ValueError(f"unsupported table rank {freq.ndim}")

    has_cands = candidates is not None and candidates.shape[-1] > 0
    extra_in, extra_specs = [], []
    if has_cands:
        if candidates.shape[:2] != (t_len, lanes):
            raise ValueError(
                f"candidate planes must be (T, lanes, topk)=({t_len}, "
                f"{lanes}, *); got {candidates.shape}")
        topk = candidates.shape[-1]
        extra_in.append(pad_chunk_rows(candidates.astype(_I32), t_len,
                                       chunk, n_chunks, padded_chunk))
        extra_specs.append(pl.BlockSpec(
            (tb, lane_block, topk),
            lambda i, c, j, *_: (c * n_tb + j, i, 0)))

    ctx_w = (int(predictor.init(lane_block).shape[-1])
             if predictor is not None else 0)
    grid = (lanes // lane_block, n_chunks, n_tb)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),           # the raw slab
            pl.BlockSpec((1, lane_block), lambda i, c, j, *_: (c, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j, *_: (c, i)),
            freq_spec,
            cdf_spec,
        ] + extra_specs,
        out_specs=[
            pl.BlockSpec((tb, lane_block),
                         lambda i, c, j, *_: (c * n_tb + j, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j, *_: (c, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j, *_: (c, i)),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, lane_block), _U32),              # rANS states
            pltpu.VMEM((1, lane_block), _I32),              # read cursors
            pltpu.VMEM((lane_block, max(1, ctx_w)), _I32),  # predictor ctx
            pltpu.VMEM((lane_block, cap), _U8),             # byte windows
            pltpu.SemaphoreType.DMA,                        # window copies
        ],
    )
    sym, probes, under = pl.pallas_call(
        functools.partial(_decode_kernel, t_len=t_len, chunk_size=chunk,
                          t_block=tb, n_tb=n_tb, prob_bits=prob_bits, k=k,
                          layout=layout, predictor=predictor, ctx_w=ctx_w,
                          has_cands=has_cands, slab=True, cap=cap),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((total_rows, lanes), _I32),
            jax.ShapeDtypeStruct((n_chunks, lanes), _I32),
            jax.ShapeDtypeStruct((n_chunks, lanes), _I32),
        ],
        interpret=interpret,
    )(base.astype(_I32), slab, wstart.astype(_I32), wlen.astype(_I32),
      freq_in, cdf_in, *extra_in)
    sym = unpad_chunk_rows(sym, t_len, chunk, n_chunks, padded_chunk)
    return sym.T, probes, under


# ---------------------------------------------------------------------------
# per-step kernel: ONE symbol pop per lane, coder state threaded through the
# caller.  This is the fused serve decode's building block (DESIGN.md §9):
# the model is autoregressive over its own decoded tokens, so the serve scan
# carries (model cache, rANS state, read cursors) and calls this kernel once
# per position with that step's just-quantized tables and candidate row.  The
# CDF search, probe accounting, state update and masked refill are the same
# shared cores the full-stream kernel consumes — bit-exactness vs both the
# pure coder and the two-pass kernel replay is structural.
# ---------------------------------------------------------------------------

def _decode_step_kernel(buf_ref, s_ref, ptr_ref, freq_ref, cdf_ref, *rest,
                        prob_bits: int, k: int, lane_tables: bool,
                        has_cands: bool):
    if has_cands:
        cand_ref = rest[0]
        s_out, ptr_out, sym_ref, probes_ref, under_ref = rest[1:]
    else:
        s_out, ptr_out, sym_ref, probes_ref, under_ref = rest
    s = s_ref[0, :]
    ptr = ptr_ref[0, :]
    slot = s & _U32((1 << prob_bits) - 1)
    if lane_tables:
        freq_t, cdf_t, g = freq_ref[...], cdf_ref[...], onehot_gather_lanes
    else:
        freq_t, cdf_t, g = freq_ref[0], cdf_ref[0], onehot_gather
    cand = cand_ref[...] if has_cands else None
    x, p = search.find_symbol(cdf_t, k, slot, candidates=cand, gather=g)
    f = g(freq_t, x)
    start = g(cdf_t[..., :k], x)
    s = f * (s >> prob_bits) + slot - start
    s, ptr, u = masked_refill(buf_ref[...], s, ptr,
                              limit=buf_ref.shape[0])
    s_out[0, :] = s
    ptr_out[0, :] = ptr
    sym_ref[0, :] = x
    probes_ref[0, :] = p
    under_ref[0, :] = u


def rans_decode_step(buf: jax.Array,    # (cap, lanes) uint8, lane-minor
                     s: jax.Array,      # (lanes,) uint32 rANS states
                     ptr: jax.Array,    # (lanes,) int32 read cursors
                     freq: jax.Array, cdf: jax.Array,
                     prob_bits: int = C.PROB_BITS,
                     candidates: jax.Array | None = None,
                     interpret: bool = True):
    """Pop ONE symbol per lane; coder state lives with the caller.

    Tables are this step's rows: ``(K,)`` shared or ``(lanes, K)`` per-lane
    (``cdf`` with trailing ``K+1``); ``candidates`` an optional
    ``(lanes, topk)`` row of trial symbols.  Returns
    ``(s', ptr', symbols (lanes,), probes (lanes,), under (lanes,))`` —
    ``under`` counts refills that read past the stream end.  Designed to be
    traced inside a ``lax.scan`` (interpret mode inlines the kernel into the
    surrounding XLA program), with the initial ``(s, ptr)`` coming from
    ``core.coder.decoder_init`` and ``buf`` transposed once outside the scan.
    """
    cap, lanes = buf.shape
    k = freq.shape[-1]
    lane_tables = freq.ndim == 2
    if lane_tables and freq.shape[0] != lanes:
        raise ValueError(
            f"per-lane tables must be (lanes, K)=({lanes}, {k}); got "
            f"{freq.shape}")
    has_cands = candidates is not None and candidates.shape[-1] > 0
    extra_in, extra_specs = [], []
    tbl_block = (lambda sh: pl.BlockSpec(sh, lambda i: (0,) * len(sh)))
    if has_cands:
        if candidates.shape[0] != lanes:
            raise ValueError(
                f"candidate row must be (lanes, topk)=({lanes}, *); got "
                f"{candidates.shape}")
        extra_in.append(candidates.astype(_I32))
        extra_specs.append(tbl_block(candidates.shape))
    freq_in = freq if lane_tables else freq.reshape(1, k)
    cdf_in = cdf if lane_tables else cdf.reshape(1, k + 1)
    s2, ptr2, sym, probes, under = pl.pallas_call(
        functools.partial(_decode_step_kernel, prob_bits=prob_bits, k=k,
                          lane_tables=lane_tables, has_cands=has_cands),
        grid=(1,),
        in_specs=[
            tbl_block((cap, lanes)),
            tbl_block((1, lanes)),
            tbl_block((1, lanes)),
            tbl_block(freq_in.shape),
            tbl_block(cdf_in.shape),
        ] + extra_specs,
        out_specs=[tbl_block((1, lanes))] * 5,
        out_shape=[
            jax.ShapeDtypeStruct((1, lanes), _U32),
            jax.ShapeDtypeStruct((1, lanes), _I32),
            jax.ShapeDtypeStruct((1, lanes), _I32),
            jax.ShapeDtypeStruct((1, lanes), _I32),
            jax.ShapeDtypeStruct((1, lanes), _I32),
        ],
        interpret=interpret,
    )(buf, s.reshape(1, lanes), ptr.astype(_I32).reshape(1, lanes),
      freq_in, cdf_in, *extra_in)
    return s2[0], ptr2[0], sym[0], probes[0], under[0]
