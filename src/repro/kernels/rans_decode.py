"""Pallas TPU kernel: prediction-guided multi-lane rANS decode (Sec. IV-C, T3).

The decoder inner loop is the paper's focus: its latency is dominated by CDF
probes (state-to-symbol search) and stream reads.  Kernel design:

  * lane-blocked grid as in rans_encode; per-lane state/pointer vectors are
    carried across a ``fori_loop`` (VREGs) and — when the symbol axis is
    blocked — across grid steps in VMEM scratch;
  * the CDF search is **not** reimplemented here: the kernel imports the
    shared search core (:mod:`repro.core.search`) and substitutes its gather
    primitive with a one-hot contraction (VPU/MXU dense math — the TPU
    replacement for the RTL's table SRAM port).  Symbols *and* the canonical
    Fig. 4(b) probe counters are therefore structurally identical to
    ``core.coder.decode``;
  * prediction-guided decoding uses the ``core.predictors`` protocol
    directly (``predictor.init/predict/update`` run inside the kernel), so
    ``NeighborAverage``/``LastValue``/``ZeroPredictor`` behave identically
    in kernel and reference paths — bit-exactness is structural (the
    bracket only narrows the search start, the search itself is unchanged);
  * **adaptive tables**: besides a static ``(K,)`` TableSet the kernel
    accepts per-position ``(T, K)`` and per-position-per-lane
    ``(T, lanes, K)`` tables — the neural-prior layouts of
    ``serve.compress``.  The T axis is blocked through VMEM
    (``t_block`` rows of freq/cdf per grid step); decoder state persists in
    scratch between T blocks, so arbitrarily long adaptive streams decode
    without holding all T tables on chip;
  * fixed 2-step masked byte refill mirrors the encoder's renorm bound.

Grid: ``(lanes // lane_block, ceil(T / t_block))`` — the T axis iterates
fastest, so each lane block streams its table blocks sequentially while the
byte stream (cap x Lb) stays resident.

VMEM per grid step: stream (cap x Lb) + tables (t_block x [Lb x] (2K+1)
u32) + symbols out (t_block x Lb).  For T=4096, Lb=128, K=256 static:
~3.7 MB; for the (T, lanes, K) adaptive layout, t_block=8 keeps the table
slab at ~2.1 MB.

Context layout note: the predictor protocol's ``(lanes, window)`` context is
kept as-is inside the kernel (sublane-major for the tiny ``window`` axis);
on a real TPU a lane-minor layout would map better onto VREGs, but the
shared-protocol contract wins here and ``window`` is small.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants as C
from repro.core import search
from repro.kernels.common import (onehot_gather, onehot_gather_lanes,
                                  onehot_gather_rows)

_U32 = jnp.uint32
_I32 = jnp.int32


def _decode_kernel(buf_ref, start_ref, freq_ref, cdf_ref,
                   sym_ref, probes_ref,
                   s_scr, ptr_scr, ctx_scr,
                   *, t_len: int, t_block: int, prob_bits: int, k: int,
                   layout: str, predictor, ctx_w: int):
    lanes = buf_ref.shape[1]
    mask = _U32((1 << prob_bits) - 1)
    buf = buf_ref[...]        # (cap, lanes) resident in VMEM
    j = pl.program_id(1)      # T-block index (innermost grid axis)

    @pl.when(j == 0)
    def _init():
        # read the 4-byte big-endian state header once per lane block
        ptr = start_ref[0].astype(_I32)
        s = jnp.zeros((lanes,), _U32)
        for _ in range(4):
            byte = onehot_gather_rows(buf, ptr).astype(_U32)
            s = (s << 8) | byte
            ptr = ptr + 1
        s_scr[0, :] = s
        ptr_scr[0, :] = ptr
        probes_ref[0, :] = jnp.zeros((lanes,), _I32)
        if predictor is not None and ctx_w:
            ctx_scr[...] = predictor.init(lanes)

    if layout == "static":
        freq_all = freq_ref[0]        # (K,)
        cdf_all = cdf_ref[0]          # (K+1,)

    if predictor is not None:
        ctx0 = (ctx_scr[...] if ctx_w
                else jnp.zeros((lanes, 0), _I32))
    else:
        ctx0 = jnp.zeros((lanes, 0), _I32)

    # number of valid positions in this T block (last block may be ragged)
    n_t = jnp.minimum(t_block, t_len - j * t_block)

    def body(t, carry):
        s, ptr, probes, ctx = carry
        slot = s & mask
        if layout == "static":
            freq_t, cdf_t, g = freq_all, cdf_all, onehot_gather
        elif layout == "perpos":
            freq_t = freq_ref[pl.dslice(t, 1), :][0]       # (K,)
            cdf_t = cdf_ref[pl.dslice(t, 1), :][0]         # (K+1,)
            g = onehot_gather
        else:  # "lane": per-position per-lane rows
            freq_t = freq_ref[pl.dslice(t, 1), :, :][0]    # (lanes, K)
            cdf_t = cdf_ref[pl.dslice(t, 1), :, :][0]      # (lanes, K+1)
            g = onehot_gather_lanes
        if predictor is not None:
            pred = predictor.predict(ctx)
            x, p = search.find_symbol(cdf_t, k, slot, mu=pred.mu,
                                      delta=pred.delta,
                                      candidates=pred.candidates, gather=g)
            ctx = predictor.update(ctx, x)
        else:
            x, p = search.find_symbol(cdf_t, k, slot, gather=g)
        sym_ref[pl.dslice(t, 1), :] = x.reshape(1, lanes)
        f = g(freq_t, x)
        start = g(cdf_t[..., :k], x)
        s = f * (s >> prob_bits) + slot - start
        for _ in range(C.MAX_RENORM_STEPS):
            cond = s < _U32(C.RANS_L)
            byte = onehot_gather_rows(buf, ptr).astype(_U32)
            s = jnp.where(cond, (s << C.RENORM_SHIFT) | byte, s)
            ptr = ptr + cond.astype(_I32)
        return s, ptr, probes + p, ctx

    s, ptr, probes, ctx = jax.lax.fori_loop(
        0, n_t, body, (s_scr[0, :], ptr_scr[0, :], probes_ref[0, :], ctx0))
    s_scr[0, :] = s
    ptr_scr[0, :] = ptr
    probes_ref[0, :] = probes
    if predictor is not None and ctx_w:
        ctx_scr[...] = ctx


@functools.partial(jax.jit,
                   static_argnames=("t_len", "prob_bits", "predictor",
                                    "lane_block", "t_block", "interpret"))
def rans_decode_lanes(buf: jax.Array,      # (lanes, cap) uint8 forward stream
                      start: jax.Array,    # (lanes,) int32
                      freq: jax.Array, cdf: jax.Array,
                      t_len: int,
                      prob_bits: int = C.PROB_BITS,
                      predictor=None,
                      lane_block: int = 128,
                      t_block: int | None = None,
                      interpret: bool = True):
    """Decode t_len symbols/lane.  Returns (symbols (lanes,T), probes (lanes,)).

    Table layouts (detected from ``freq.ndim``):
      * ``(K,)``            — static shared table (classic rANS);
      * ``(T, K)``          — per-position shared rows (neural prior, all
                              lanes share each step's distribution);
      * ``(T, lanes, K)``   — per-position per-lane rows (the
                              ``serve.compress`` TableSet layout).
    ``cdf`` must carry the matching shape with a trailing ``K+1``.

    ``predictor`` is a ``core.predictors`` config (hashable NamedTuple) or
    None; ``t_block`` blocks the T axis through VMEM (None = whole stream in
    one block).  ``probes`` are the canonical per-lane Fig. 4(b) counters of
    ``core.search`` — bit-identical to ``core.coder.decode``'s.
    """
    lanes, cap = buf.shape
    if lanes % lane_block:
        raise ValueError(f"lanes={lanes} not a multiple of {lane_block}")
    k = freq.shape[-1]
    t_block = t_len if t_block is None else min(t_block, t_len)
    t_block = max(t_block, 1)
    n_tb = -(-t_len // t_block)

    if freq.ndim == 1:
        layout = "static"
        freq_in, cdf_in = freq.reshape(1, k), cdf.reshape(1, k + 1)
        freq_spec = pl.BlockSpec((1, k), lambda i, j: (0, 0))
        cdf_spec = pl.BlockSpec((1, k + 1), lambda i, j: (0, 0))
    elif freq.ndim == 2:
        if freq.shape[0] != t_len:
            raise ValueError(
                f"per-position tables carry T={freq.shape[0]} rows but "
                f"t_len={t_len}")
        layout = "perpos"
        freq_in, cdf_in = freq, cdf
        freq_spec = pl.BlockSpec((t_block, k), lambda i, j: (j, 0))
        cdf_spec = pl.BlockSpec((t_block, k + 1), lambda i, j: (j, 0))
    elif freq.ndim == 3:
        if freq.shape[0] != t_len or freq.shape[1] != lanes:
            raise ValueError(
                f"per-lane tables must be (T, lanes, K)=({t_len}, {lanes}, "
                f"{k}); got {freq.shape}")
        layout = "lane"
        freq_in, cdf_in = freq, cdf
        freq_spec = pl.BlockSpec((t_block, lane_block, k),
                                 lambda i, j: (j, i, 0))
        cdf_spec = pl.BlockSpec((t_block, lane_block, k + 1),
                                lambda i, j: (j, i, 0))
    else:
        raise ValueError(f"unsupported table rank {freq.ndim}")

    ctx_w = (int(predictor.init(lane_block).shape[-1])
             if predictor is not None else 0)
    grid = (lanes // lane_block, n_tb)

    sym, probes = pl.pallas_call(
        functools.partial(_decode_kernel, t_len=t_len, t_block=t_block,
                          prob_bits=prob_bits, k=k, layout=layout,
                          predictor=predictor, ctx_w=ctx_w),
        grid=grid,
        in_specs=[
            pl.BlockSpec((cap, lane_block), lambda i, j: (0, i)),
            pl.BlockSpec((1, lane_block), lambda i, j: (0, i)),
            freq_spec,
            cdf_spec,
        ],
        out_specs=[
            pl.BlockSpec((t_block, lane_block), lambda i, j: (j, i)),
            pl.BlockSpec((1, lane_block), lambda i, j: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, lanes), _I32),
            jax.ShapeDtypeStruct((1, lanes), _I32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, lane_block), _U32),              # rANS states
            pltpu.VMEM((1, lane_block), _I32),              # read cursors
            pltpu.VMEM((lane_block, max(1, ctx_w)), _I32),  # predictor ctx
        ],
        interpret=interpret,
    )(buf.T, start.reshape(1, lanes).astype(_I32), freq_in, cdf_in)
    return sym.T, probes[0]
