"""Pallas TPU kernel: SPC BF16 -> fixed-point quantization with mass
correction (paper Sec. IV-A, T1).

The host/XLA reference (core/spc.py) uses two stable argsorts for the
largest-remainder correction.  Sorting is hostile to the TPU vector unit, so
the kernel computes **pairwise stable ranks as dense K x K comparisons** —
an MXU-shaped reformulation that produces *identical* integer frequencies:

    rank_desc(i) = #{j : r_j > r_i} + #{j < i : r_j == r_i}
    rank_asc(i)  = #{j : r_j < r_i} + #{j < i : r_j == r_i}

and the negative-delta waterfill's exclusive prefix-capacity becomes a masked
matrix-vector product  cum_excl(i) = sum_j [rank_asc(j) < rank_asc(i)] cap(j).

VMEM: the (Bb, K, K) comparison cube dominates — Bb=8, K=256 -> 4 MB fp32.
Tile the batch dim via the grid for larger alphabets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import constants as C

_U32 = jnp.uint32
_I32 = jnp.int32


def _spc_quantize_kernel(p_ref, freq_ref, *, prob_bits: int):
    total = 1 << prob_bits
    k = p_ref.shape[1]
    # single BF16 -> fixed-point conversion (the paper's one-shot cast)
    p = p_ref[...].astype(jnp.bfloat16).astype(jnp.float32)
    p = jnp.where(jnp.isfinite(p) & (p > 0), p, 0.0)
    scaled = p * jnp.float32(total)
    f0 = jnp.maximum(1, jnp.round(scaled)).astype(_I32)       # (B, K)
    delta = total - jnp.sum(f0, axis=1, keepdims=True)        # (B, 1)
    resid = scaled - f0.astype(jnp.float32)

    # pairwise stable ranks (dense comparisons instead of argsort)
    ri = resid[:, :, None]                                    # (B, K, 1)
    rj = resid[:, None, :]                                    # (B, 1, K)
    jlt = jax.lax.broadcasted_iota(_I32, (1, k, k), 2) < \
        jax.lax.broadcasted_iota(_I32, (1, k, k), 1)          # j < i
    eq_tie = (rj == ri) & jlt
    rank_desc = jnp.sum(((rj > ri) | eq_tie).astype(_I32), axis=2)
    rank_asc = jnp.sum(((rj < ri) | eq_tie).astype(_I32), axis=2)

    # delta > 0: base share + largest-remainder top-up
    f_pos = f0 + delta // k + (rank_desc < delta % k).astype(_I32)

    # delta < 0: waterfill smallest residual first, capacity f0 - 1
    need = -delta                                             # (B, 1)
    cap = f0 - 1                                              # (B, K)
    before = (rank_asc[:, None, :] < rank_asc[:, :, None])    # (B, i, j)
    cum_excl = jnp.sum(before.astype(jnp.float32)
                       * cap[:, None, :].astype(jnp.float32),
                       axis=2).astype(_I32)
    take = jnp.clip(need - cum_excl, 0, cap)
    f_neg = f0 - take

    f = jnp.where(delta >= 0, f_pos, f_neg)
    freq_ref[...] = f.astype(_U32)


@functools.partial(jax.jit, static_argnames=("prob_bits", "batch_block",
                                             "interpret"))
def spc_quantize(probs: jax.Array,          # (B, K) float
                 prob_bits: int = C.PROB_BITS,
                 batch_block: int = 8,
                 interpret: bool = True) -> jax.Array:
    """Batched BF16->fixed-point quantization.  Returns (B, K) uint32 freqs."""
    b, k = probs.shape
    if b % batch_block:
        raise ValueError(f"batch {b} not a multiple of {batch_block}")
    return pl.pallas_call(
        functools.partial(_spc_quantize_kernel, prob_bits=prob_bits),
        grid=(b // batch_block,),
        in_specs=[pl.BlockSpec((batch_block, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((batch_block, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, k), _U32),
        interpret=interpret,
    )(probs.astype(jnp.float32))
