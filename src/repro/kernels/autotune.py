"""VMEM-occupancy autotuner for the RAS kernels (DESIGN.md §10).

The kernels expose two block knobs — ``lane_block`` (lanes per grid step)
and ``t_block`` (symbol rows per grid step) — plus, on the banked-ring
encode path, the ring size derived from ``t_block``.  This module owns the
selection policy:

  * **occupancy model**: :func:`encode_vmem_bytes` / :func:`decode_vmem_bytes`
    mirror the per-grid-step VMEM math in the kernel docstrings
    (``kernels/rans_encode.py`` / ``kernels/rans_decode.py``) exactly —
    symbols + stream block + table planes + candidates + scratch.  The
    budget is :data:`VMEM_BYTES` (the v5e per-core VMEM the roofline model
    in ``analysis/roofline.py`` re-exports; tests pin the two constants
    identical) with a 2x headroom factor for Pallas double-buffering.
  * **encode work model**: the banked ring makes per-byte scatter cost
    O(ring) instead of O(cap), but every grid step pays one O(cap) drain
    and a fixed step overhead, so the best ``t_block`` balances
    ``bytes x ring(t_block)`` against ``steps x (cap + overhead)``
    (:func:`select_encode_t_block`).  Measured interpret-mode wall-clock
    tracks this model (BENCH_encode.json's ring-vs-onehot points).
  * **decode**: no ring; fewer grid steps is strictly better, so
    :func:`select_decode_t_block` returns the whole chunk unless the
    adaptive table slab would blow the VMEM budget, then halves.

Everything here is host-side integer math on static shapes — safe to call
from inside jit'd wrappers (the knobs are static argnames).
"""

from __future__ import annotations

from repro.core import constants as C
from repro.kernels.common import next_pow2

# TPU v5e: ~16 MB of VMEM per core (the pallas guide's planning number);
# analysis/roofline.py re-exports this so the roofline and the autotuner
# can never disagree about the machine model.
VMEM_BYTES = 16 * 2 ** 20
# leave half for Pallas pipelining/double-buffering of the blocked inputs
VMEM_BUDGET = VMEM_BYTES // 2
# per-grid-step fixed cost in row-equivalents (kernel dispatch, scratch
# turnover); calibrated against the interpret-mode ring sweep in
# benchmarks/bench_speed.py — large enough that tiny chunks stay unblocked
STEP_OVERHEAD_ROWS = 3072


def ring_size(t_block: int) -> int:
    """Bank rows for one encode grid step's worst case: ``t_block`` symbols
    emit at most ``MAX_RENORM_STEPS`` bytes each, plus the 4-byte state
    header at the chunk's last step; rounded to a power of two so the
    cursor wrap is one integer mask (DESIGN.md §10)."""
    return next_pow2(C.MAX_RENORM_STEPS * t_block + 4)


def select_lane_block(lanes: int, lane_block: int = 128) -> int:
    """Lane grid blocking: full VREG-width groups when the lane count
    tiles them, else one collapsed group (correctness over occupancy —
    the serve/parallel paths run narrow lane counts)."""
    return lane_block if lane_block and lanes % lane_block == 0 else lanes


def _table_plane_bytes(t_block: int, lane_block: int, k: int,
                       layout: str, n_planes: int) -> int:
    """u32 table-plane bytes per grid step for one of the three layouts."""
    if layout == "lane":
        return n_planes * t_block * lane_block * k * 4
    if layout == "perpos":
        return n_planes * t_block * k * 4
    return n_planes * k * 4                     # static: T-invariant


def encode_vmem_bytes(t_block: int, lane_block: int, k: int, layout: str,
                      cap: int, ring: int | None = None) -> int:
    """Fused-encode VMEM occupancy per grid step (kernel docstring math):
    symbol block + resident stream block + five encode planes + geometry
    outputs + state/cursor scratch [+ the byte-ring bank]."""
    syms = t_block * lane_block * 4
    stream = cap * lane_block
    planes = _table_plane_bytes(t_block, lane_block, k, layout, n_planes=5)
    geometry = 3 * lane_block * 4               # start/length/overflow
    scratch = 2 * lane_block * 4                # states + cursors
    bank = (ring or 0) * lane_block
    return syms + stream + planes + geometry + scratch + bank


def decode_vmem_bytes(t_block: int, lane_block: int, k: int, layout: str,
                      cap: int, topk: int = 0, ctx_w: int = 0,
                      slab: bool = False) -> int:
    """Decode VMEM occupancy per grid step (kernel docstring math):
    stream block (dense input block, or the slab path's DMA'd window
    scratch — same footprint) + freq/cdf planes + candidate block + symbol
    output + state/cursor/context scratch."""
    stream = cap * lane_block
    freq = _table_plane_bytes(t_block, lane_block, k, layout, n_planes=1)
    cdf = _table_plane_bytes(t_block, lane_block, k + 1, layout, n_planes=1)
    cands = t_block * lane_block * topk * 4
    syms = t_block * lane_block * 4
    probes = lane_block * 4
    scratch = 2 * lane_block * 4 + lane_block * max(1, ctx_w) * 4
    return stream + freq + cdf + cands + syms + probes + scratch


def _t_block_candidates(chunk: int) -> list[int]:
    """The whole chunk plus power-of-two blockings down to 8 rows."""
    cands = [chunk]
    tb = 8
    while tb < chunk:
        cands.append(tb)
        tb *= 2
    return cands


def select_encode_t_block(chunk: int, cap: int, lane_block: int, k: int,
                          layout: str) -> int:
    """Pick the banked-ring encode's ``t_block`` by the analytic work
    model, VMEM-validated.

    Per chunk the ring path costs about
    ``MAX_RENORM_STEPS * chunk * ring(tb)`` scatter selects plus
    ``ceil(chunk / tb) * (cap + STEP_OVERHEAD_ROWS)`` drain/step rows;
    the one-hot path it replaces cost ``MAX_RENORM_STEPS * chunk * cap``.
    Candidates whose occupancy exceeds :data:`VMEM_BUDGET` are dropped
    (falling back to the smallest candidate if none fit).
    """
    best_tb, best_cost = None, None
    for tb in _t_block_candidates(chunk):
        r = ring_size(tb)
        cost = (C.MAX_RENORM_STEPS * chunk * r
                + -(-chunk // tb) * (cap + STEP_OVERHEAD_ROWS))
        if encode_vmem_bytes(tb, lane_block, k, layout, cap,
                             ring=r) > VMEM_BUDGET:
            continue
        if best_cost is None or cost < best_cost:
            best_tb, best_cost = tb, cost
    if best_tb is None:                 # nothing fits: smallest candidate
        best_tb = min(_t_block_candidates(chunk))
    return best_tb


def select_decode_t_block(chunk: int, cap: int, lane_block: int, k: int,
                          layout: str, topk: int = 0,
                          ctx_w: int = 0) -> int:
    """Pick the decode ``t_block``: the whole chunk (fewest grid steps)
    unless the adaptive table slab would exceed :data:`VMEM_BUDGET`, then
    the largest power-of-two blocking that fits (at least 8 rows)."""
    tb = chunk
    while tb > 8 and decode_vmem_bytes(tb, lane_block, k, layout, cap,
                                       topk=topk,
                                       ctx_w=ctx_w) > VMEM_BUDGET:
        tb = next_pow2(tb) // 2     # halve (rounding non-pow2 down to pow2)
    return max(tb, 1)
