"""Pallas TPU kernel: multi-lane rANS encode (paper Sec. IV-B, T2+T4).

Kernel shape (hardware adaptation — see DESIGN.md §2):

  * grid ``(lane blocks, chunks, T blocks)`` — the lane dim is last in the
    data layout and sized in multiples of 128 (= VREG width); each grid
    step owns ``lane_block`` independent rANS states held in registers
    across a ``fori_loop`` over symbols (the RTL's "stationary dataflow:
    state and symbols stay resident, probabilities stream");
  * the encode update itself is **not** implemented here: the kernel
    imports the shared update core (:mod:`repro.core.update`) and
    substitutes its gather primitive with a one-hot contraction (VPU/MXU
    dense math — the TPU replacement for the RTL's table SRAM port).
    Byte streams are therefore structurally identical to
    ``core.coder.encode``;
  * the data-dependent byte FIFO of the RTL is split out of the kernel: the
    kernel emits the core's **fixed-shape renorm records**
    (``bytes (T, 2, lanes)`` + ``mask (T, 2, lanes)``, at most
    MAX_RENORM_STEPS=2 bytes per symbol — DESIGN.md §4), and the shared
    vectorized compaction (:func:`repro.core.bitstream.compact_records`)
    builds the per-lane streams.  This keeps the kernel free of dynamic
    addressing — pure VPU math at one symbol per "cycle" (loop step),
    exactly the paper's two-stage pipeline;
  * **adaptive tables**: besides a static ``(K,)`` TableSet the kernel
    accepts per-position ``(T, K)`` and per-position-per-lane
    ``(T, lanes, K)`` tables — the neural-prior layouts of
    ``serve.compress``.  The T axis is blocked through VMEM (``t_block``
    rows of the five encode planes per grid step); encoder state persists
    in scratch between T blocks, so arbitrarily long adaptive streams
    encode without holding all T tables on chip.  rANS is LIFO, so the
    T-block grid axis walks **backward** (the index maps reverse the block
    order) and each block's inner loop walks its rows in reverse;
  * **chunk grid axis**: chunked streams (independent per-chunk flush — the
    interleaved-ANS construction) are ONE ``pallas_call``: the chunk axis
    is a grid dimension, encoder state resets to ``RANS_L`` at each chunk's
    first grid step and the per-chunk final state is written at its last.
    Each chunk's rows are padded to a whole number of T blocks; padding
    rows emit mask-0 records which the shared compaction drops.

Grid: ``(lanes // lane_block, n_chunks, ceil(chunk_size / t_block))`` — the
T axis iterates fastest (innermost), then chunks, so each (lane block,
chunk) streams its table blocks sequentially while state lives in VMEM
scratch.

VMEM per grid step: symbols (t_block x Lb x 4 B) + records
(t_block x 2 x Lb x 2 B) + five table planes (t_block x [Lb x] K x 4 B
adaptive, K x 4 B static).  For T=4096, Lb=128, K=256 static: ~4.2 MB; for
the (T, lanes, K) adaptive layout, t_block=8 keeps the table slab at
~1.3 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants as C
from repro.core import update
from repro.core.spc import TableSet
from repro.kernels.common import (onehot_gather, onehot_gather_lanes,
                                  pad_chunk_rows)

_U32 = jnp.uint32
_U8 = jnp.uint8

_PLANES = ("rcp", "rshift", "bias", "cmpl", "x_max")


def _encode_kernel(sym_ref, rcp_ref, rshift_ref, bias_ref, cmpl_ref,
                   xmax_ref, bytes_ref, mask_ref, state_ref, s_scr,
                   *, t_len: int, chunk_size: int, t_block: int, n_tb: int,
                   layout: str):
    lanes = sym_ref.shape[1]
    c = pl.program_id(1)      # chunk index
    j = pl.program_id(2)      # T-block step (innermost; blocks walk backward)

    @pl.when(j == 0)
    def _reset():
        # per-chunk state reset: every chunk is a standalone stream
        s_scr[0, :] = jnp.full((lanes,), C.RANS_L, _U32)

    b = n_tb - 1 - j          # T-block index within the chunk (LIFO order)
    # valid rows in this block: the final chunk may be ragged, and padding
    # rows (up to a whole T block) must emit nothing
    chunk_len = jnp.minimum(chunk_size, t_len - c * chunk_size)
    n_t = jnp.clip(chunk_len - b * t_block, 0, t_block)

    # zero the record block first: rows >= n_t are padding (mask 0), and
    # valid rows overwrite below
    bytes_ref[...] = jnp.zeros(bytes_ref.shape, _U8)
    mask_ref[...] = jnp.zeros(mask_ref.shape, _U8)

    if layout == "static":
        planes_static = update.EncTables(
            rcp_ref[0], rshift_ref[0], bias_ref[0], cmpl_ref[0], xmax_ref[0])

    def body(i, s):
        t = n_t - 1 - i       # rANS is LIFO: walk rows in reverse
        x = sym_ref[pl.dslice(t, 1), :][0]
        if layout == "static":
            planes, g = planes_static, onehot_gather
        elif layout == "perpos":
            planes = update.EncTables(
                rcp_ref[pl.dslice(t, 1), :][0],
                rshift_ref[pl.dslice(t, 1), :][0],
                bias_ref[pl.dslice(t, 1), :][0],
                cmpl_ref[pl.dslice(t, 1), :][0],
                xmax_ref[pl.dslice(t, 1), :][0])
            g = onehot_gather
        else:  # "lane": per-position per-lane rows (lanes, K)
            planes = update.EncTables(
                rcp_ref[pl.dslice(t, 1), :, :][0],
                rshift_ref[pl.dslice(t, 1), :, :][0],
                bias_ref[pl.dslice(t, 1), :, :][0],
                cmpl_ref[pl.dslice(t, 1), :, :][0],
                xmax_ref[pl.dslice(t, 1), :, :][0])
            g = onehot_gather_lanes
        e = update.gather_encode_entry(planes, x, gather=g)
        s, recs = update.encode_step(s, e)
        for r, (byte, cond) in enumerate(recs):
            bytes_ref[pl.dslice(t, 1), pl.dslice(r, 1), :] = (
                byte.reshape(1, 1, lanes))
            mask_ref[pl.dslice(t, 1), pl.dslice(r, 1), :] = (
                cond.astype(_U8).reshape(1, 1, lanes))
        return s

    s = jax.lax.fori_loop(0, n_t, body, s_scr[0, :])
    s_scr[0, :] = s

    @pl.when(j == n_tb - 1)
    def _final():
        # the last (backward) block ends at t=0: the chunk's final state
        state_ref[0, :] = s_scr[0, :]


@functools.partial(jax.jit,
                   static_argnames=("chunk_size", "prob_bits", "lane_block",
                                    "t_block", "interpret"))
def rans_encode_records(symbols: jax.Array,   # (lanes, T) int32
                       tbl: TableSet,
                       chunk_size: int | None = None,
                       prob_bits: int = C.PROB_BITS,
                       lane_block: int = 128,
                       t_block: int | None = None,
                       interpret: bool = True):
    """Run the encode kernel — ONE ``pallas_call`` for the whole stream.

    Table layouts (detected from ``tbl.freq.ndim``):
      * ``(K,)``            — static shared table (classic rANS);
      * ``(T, K)``          — per-position shared rows (neural prior, all
                              lanes share each step's distribution);
      * ``(T, lanes, K)``   — per-position per-lane rows (the
                              ``serve.compress`` TableSet layout).

    ``chunk_size`` (None = monolithic): cut the stream into independent
    chunks, each flushed separately — the chunk axis is a *grid* dimension
    with in-kernel state reset, not a host-side loop of kernel launches.
    ``t_block`` blocks the T axis through VMEM (None = whole chunk in one
    block).

    Returns ``(bytes, mask, states)`` with shapes
    ``(n_chunks, padded_chunk, 2, lanes)`` / same / ``(n_chunks, lanes)``
    where ``padded_chunk = ceil(chunk_size / t_block) * t_block``; padding
    rows carry mask 0 and are dropped by ``compact_records``.
    """
    lanes, t_len = symbols.shape
    if lanes % lane_block:
        lane_block = lanes
    chunk = t_len if chunk_size is None else chunk_size
    if chunk <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk}")
    chunk = min(chunk, t_len)
    n_chunks = -(-t_len // chunk)
    tb = chunk if t_block is None else max(1, min(t_block, chunk))
    n_tb = -(-chunk // tb)
    padded_chunk = n_tb * tb
    total_rows = n_chunks * padded_chunk

    k = tbl.freq.shape[-1]
    ndim = tbl.freq.ndim
    planes = update.encode_planes(tbl)
    if ndim == 1:
        layout = "static"
        planes_in = [p.reshape(1, k) for p in planes]
        tbl_specs = [pl.BlockSpec((1, k), lambda i, c, j: (0, 0))] * 5
    elif ndim == 2:
        if tbl.freq.shape[0] != t_len:
            raise ValueError(
                f"per-position tables carry T={tbl.freq.shape[0]} rows but "
                f"t_len={t_len}")
        layout = "perpos"
        planes_in = [pad_chunk_rows(p, t_len, chunk, n_chunks, padded_chunk)
                     for p in planes]
        tbl_specs = [pl.BlockSpec(
            (tb, k), lambda i, c, j: (c * n_tb + n_tb - 1 - j, 0))] * 5
    elif ndim == 3:
        if tbl.freq.shape[0] != t_len or tbl.freq.shape[1] != lanes:
            raise ValueError(
                f"per-lane tables must be (T, lanes, K)=({t_len}, {lanes}, "
                f"{k}); got {tbl.freq.shape}")
        layout = "lane"
        planes_in = [pad_chunk_rows(p, t_len, chunk, n_chunks, padded_chunk)
                     for p in planes]
        tbl_specs = [pl.BlockSpec(
            (tb, lane_block, k),
            lambda i, c, j: (c * n_tb + n_tb - 1 - j, i, 0))] * 5
    else:
        raise ValueError(f"unsupported table rank {ndim}")

    sym_in = pad_chunk_rows(symbols.T.astype(jnp.int32), t_len, chunk,
                             n_chunks, padded_chunk)
    grid = (lanes // lane_block, n_chunks, n_tb)

    rec_b, rec_m, states = pl.pallas_call(
        functools.partial(_encode_kernel, t_len=t_len, chunk_size=chunk,
                          t_block=tb, n_tb=n_tb, layout=layout),
        grid=grid,
        in_specs=[pl.BlockSpec((tb, lane_block),
                               lambda i, c, j: (c * n_tb + n_tb - 1 - j, i))]
        + tbl_specs,
        out_specs=[
            pl.BlockSpec((tb, C.MAX_RENORM_STEPS, lane_block),
                         lambda i, c, j: (c * n_tb + n_tb - 1 - j, 0, i)),
            pl.BlockSpec((tb, C.MAX_RENORM_STEPS, lane_block),
                         lambda i, c, j: (c * n_tb + n_tb - 1 - j, 0, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total_rows, C.MAX_RENORM_STEPS, lanes),
                                 _U8),
            jax.ShapeDtypeStruct((total_rows, C.MAX_RENORM_STEPS, lanes),
                                 _U8),
            jax.ShapeDtypeStruct((n_chunks, lanes), _U32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, lane_block), _U32),   # encoder states across T
        ],
        interpret=interpret,
    )(sym_in, *planes_in)
    shape = (n_chunks, padded_chunk, C.MAX_RENORM_STEPS, lanes)
    return rec_b.reshape(shape), rec_m.reshape(shape), states
