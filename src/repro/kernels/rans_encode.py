"""Pallas TPU kernel: multi-lane rANS encode (paper Sec. IV-B, T2+T4).

Kernel shape (hardware adaptation — see DESIGN.md §2):

  * grid over **lane blocks** (lane dim last, multiples of 128 = VREG width);
    each grid step owns ``lane_block`` independent rANS states held in
    registers across a ``fori_loop`` over symbols (the RTL's "stationary
    dataflow: state and symbols stay resident, probabilities stream");
  * the data-dependent byte FIFO of the RTL is split out of the kernel: the
    kernel emits **fixed-shape renorm records** ``bytes (T, 2, lanes)`` +
    ``mask (T, 2, lanes)`` (at most MAX_RENORM_STEPS=2 bytes per symbol,
    provable), and a vectorized XLA scatter in ops.py compacts them into
    per-lane streams.  This keeps the kernel free of dynamic addressing —
    pure VPU math at one symbol per "cycle" (loop step), exactly the
    paper's two-stage pipeline;
  * table lookups (freq/rcp/bias/cmpl/x_max by symbol) are one-hot
    contractions against VMEM-resident SPC tables (shared by all lanes —
    the paper's shared CDF/frequency tables behind the SPC).

VMEM budget per grid step (BlockSpec):
    symbols  T x Lb x 4   B
    records  T x 2 x Lb x 2 B   (bytes + mask, uint8)
    tables   6 x K x 4    B
  For T=4096, Lb=128, K=256: ~4.2 MB — fits a single VMEM partition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import constants as C
from repro.kernels.common import onehot_gather, umulhi32

_U32 = jnp.uint32
_U8 = jnp.uint8


def _encode_kernel(sym_ref, freq_ref, xmax_ref, rcp_ref, rshift_ref,
                   bias_ref, cmpl_ref,
                   bytes_ref, mask_ref, state_ref,
                   *, t_len: int, prob_bits: int):
    lanes = sym_ref.shape[1]
    freq = freq_ref[0]
    xmax = xmax_ref[0]
    rcp = rcp_ref[0]
    rshift = rshift_ref[0]
    bias = bias_ref[0]
    cmpl = cmpl_ref[0]

    def body(i, s):
        t = t_len - 1 - i  # rANS is LIFO: walk symbols in reverse
        x = sym_ref[pl.dslice(t, 1), :][0]
        e_xmax = onehot_gather(xmax, x)
        # stage A: fixed 2-step byte renorm -> fixed-shape records
        for j in range(C.MAX_RENORM_STEPS):
            cond = s >= e_xmax
            byte = (s & _U32(0xFF)).astype(_U8)
            bytes_ref[pl.dslice(t, 1), pl.dslice(j, 1), :] = (
                byte.reshape(1, 1, lanes))
            mask_ref[pl.dslice(t, 1), pl.dslice(j, 1), :] = (
                cond.astype(_U8).reshape(1, 1, lanes))
            s = jnp.where(cond, s >> C.RENORM_SHIFT, s)
        # stage B: two-path update (Barrett quotient || remainder+CDF)
        q = umulhi32(s, onehot_gather(rcp, x)) >> onehot_gather(rshift, x)
        s = s + onehot_gather(bias, x) + q * onehot_gather(cmpl, x)
        return s

    s0 = jnp.full((lanes,), C.RANS_L, _U32)
    s = jax.lax.fori_loop(0, t_len, body, s0)
    state_ref[0, :] = s


@functools.partial(jax.jit,
                   static_argnames=("prob_bits", "lane_block", "interpret"))
def rans_encode_records(symbols: jax.Array,   # (lanes, T) int32
                        freq: jax.Array, x_max: jax.Array, rcp: jax.Array,
                        rshift: jax.Array, bias: jax.Array, cmpl: jax.Array,
                        prob_bits: int = C.PROB_BITS,
                        lane_block: int = 128,
                        interpret: bool = True):
    """Run the encode kernel; returns (bytes (T,2,lanes), mask, states)."""
    lanes, t_len = symbols.shape
    if lanes % lane_block:
        raise ValueError(f"lanes={lanes} not a multiple of {lane_block}")
    k = freq.shape[-1]
    grid = (lanes // lane_block,)

    tbl_spec = pl.BlockSpec((1, k), lambda i: (0, 0))
    out = pl.pallas_call(
        functools.partial(_encode_kernel, t_len=t_len, prob_bits=prob_bits),
        grid=grid,
        in_specs=[pl.BlockSpec((t_len, lane_block), lambda i: (0, i))]
        + [tbl_spec] * 6,
        out_specs=[
            pl.BlockSpec((t_len, C.MAX_RENORM_STEPS, lane_block),
                         lambda i: (0, 0, i)),
            pl.BlockSpec((t_len, C.MAX_RENORM_STEPS, lane_block),
                         lambda i: (0, 0, i)),
            pl.BlockSpec((1, lane_block), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t_len, C.MAX_RENORM_STEPS, lanes), _U8),
            jax.ShapeDtypeStruct((t_len, C.MAX_RENORM_STEPS, lanes), _U8),
            jax.ShapeDtypeStruct((1, lanes), _U32),
        ],
        interpret=interpret,
    )(symbols.T.astype(jnp.int32), freq.reshape(1, k), x_max.reshape(1, k),
      rcp.reshape(1, k), rshift.reshape(1, k), bias.reshape(1, k),
      cmpl.reshape(1, k))
    return out
