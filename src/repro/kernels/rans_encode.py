"""Pallas TPU kernel: multi-lane rANS encode (paper Sec. IV-B, T2+T4).

Kernel shape (hardware adaptation — see DESIGN.md §2/§8):

  * grid ``(lane blocks, chunks, T blocks)`` — the lane dim is last in the
    data layout and sized in multiples of 128 (= VREG width); each grid
    step owns ``lane_block`` independent rANS states held in registers
    across a ``fori_loop`` over symbols (the RTL's "stationary dataflow:
    state and symbols stay resident, probabilities stream");
  * the encode update itself is **not** implemented here: the kernel
    imports the shared update core (:mod:`repro.core.update`) and
    substitutes its gather primitive with a one-hot contraction (VPU/MXU
    dense math — the TPU replacement for the RTL's table SRAM port).
    Byte streams are therefore structurally identical to
    ``core.coder.encode``;
  * **fused in-kernel byte compaction** (:func:`rans_encode_lanes`, the
    production datapath): a per-lane byte cursor lives in VMEM scratch and
    every renorm record of :func:`repro.core.update.encode_step` is
    scattered straight into the per-lane output streams (one-hot row
    scatter — ``kernels.common.onehot_scatter_rows``).  The LIFO backward
    block walk already emits bytes in exactly the order the wire format
    stores them reversed, so the cursor simply decrements from ``cap`` —
    the TPU analogue of the RAS byte FIFO.  The kernel emits packed
    ``(cap, lanes)`` byte planes plus per-lane start/length/overflow — no
    host-side compaction pass, so encoded bytes cross HBM once;
  * the **records path** (:func:`rans_encode_records`) is retained as the
    bytes-moved reference: it emits the core's fixed-shape renorm records
    (``bytes (T, 2, lanes)`` + ``mask (T, 2, lanes)``) to HBM and leaves
    compaction to :func:`repro.core.bitstream.compact_records` — every
    encoded byte crosses HBM ~2x.  ``benchmarks/bench_speed.py`` diffs the
    two datapaths; the differential tests pin them byte-identical;
  * **adaptive tables**: besides a static ``(K,)`` TableSet the kernel
    accepts per-position ``(T, K)`` and per-position-per-lane
    ``(T, lanes, K)`` tables — the neural-prior layouts of
    ``serve.compress``.  The T axis is blocked through VMEM (``t_block``
    rows of the five encode planes per grid step); encoder state persists
    in scratch between T blocks, so arbitrarily long adaptive streams
    encode without holding all T tables on chip.  rANS is LIFO, so the
    T-block grid axis walks **backward** (the index maps reverse the block
    order) and each block's inner loop walks its rows in reverse;
  * **chunk grid axis**: chunked streams (independent per-chunk flush — the
    interleaved-ANS construction) are ONE ``pallas_call``: the chunk axis
    is a grid dimension, encoder state (and the fused path's byte cursor)
    resets at each chunk's first grid step and the per-chunk stream
    geometry is written at its last.  Each chunk's rows are padded to a
    whole number of T blocks; padding rows emit nothing.

Grid: ``(lanes // lane_block, n_chunks, ceil(chunk_size / t_block))`` — the
T axis iterates fastest (innermost), then chunks, so each (lane block,
chunk) streams its table blocks sequentially while state — and, fused, the
chunk's ``(cap, lane_block)`` output stream — lives in VMEM across T blocks.

VMEM per grid step (fused): symbols (t_block x Lb x 4 B) + stream block
(cap x Lb x 1 B) + five table planes (t_block x [Lb x] K x 4 B adaptive,
K x 4 B static).  For T=4096, Lb=128, K=256 static: ~5.2 MB; for the
(T, lanes, K) adaptive layout, t_block=8 keeps the table slab at ~1.3 MB.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import constants as C
from repro.core import update
from repro.core.spc import TableSet
from repro.kernels.autotune import ring_size, select_encode_t_block
from repro.kernels.common import (onehot_gather, onehot_gather_lanes,
                                  onehot_scatter_rows, pad_chunk_rows)

_U32 = jnp.uint32
_U8 = jnp.uint8
_I32 = jnp.int32
_M8 = np.uint32(0xFF)

_PLANES = ("rcp", "rshift", "bias", "cmpl", "x_max")


class _Plan(NamedTuple):
    """Shared grid/layout plan of both encode entrypoints (records and
    fused): table layout, padded chunk geometry, kernel inputs + specs."""

    layout: str                  # "static" | "perpos" | "lane"
    lanes: int
    t_len: int
    chunk: int                   # effective chunk size (t_len if monolithic)
    n_chunks: int
    tb: int                      # T-block rows per grid step
    n_tb: int
    padded_chunk: int
    total_rows: int
    k: int
    grid: tuple
    sym_in: jax.Array
    sym_spec: pl.BlockSpec
    planes_in: list
    tbl_specs: list


def _encode_plan(symbols: jax.Array, tbl: TableSet,
                 chunk_size: int | None, lane_block: int,
                 t_block: int | None) -> _Plan:
    """Validate shapes and build the chunk-padded inputs + BlockSpecs shared
    by the records and fused kernels (the LIFO-reversed T-block maps)."""
    lanes, t_len = symbols.shape
    chunk = t_len if chunk_size is None else chunk_size
    if chunk <= 0:
        raise ValueError(f"chunk_size must be positive, got {chunk}")
    chunk = min(chunk, t_len)
    n_chunks = -(-t_len // chunk)
    tb = chunk if t_block is None else max(1, min(t_block, chunk))
    n_tb = -(-chunk // tb)
    padded_chunk = n_tb * tb
    total_rows = n_chunks * padded_chunk

    k = tbl.freq.shape[-1]
    ndim = tbl.freq.ndim
    planes = update.encode_planes(tbl)
    if ndim == 1:
        layout = "static"
        planes_in = [p.reshape(1, k) for p in planes]
        tbl_specs = [pl.BlockSpec((1, k), lambda i, c, j: (0, 0))] * 5
    elif ndim == 2:
        if tbl.freq.shape[0] != t_len:
            raise ValueError(
                f"per-position tables carry T={tbl.freq.shape[0]} rows but "
                f"t_len={t_len}")
        layout = "perpos"
        planes_in = [pad_chunk_rows(p, t_len, chunk, n_chunks, padded_chunk)
                     for p in planes]
        tbl_specs = [pl.BlockSpec(
            (tb, k), lambda i, c, j: (c * n_tb + n_tb - 1 - j, 0))] * 5
    elif ndim == 3:
        if tbl.freq.shape[0] != t_len or tbl.freq.shape[1] != lanes:
            raise ValueError(
                f"per-lane tables must be (T, lanes, K)=({t_len}, {lanes}, "
                f"{k}); got {tbl.freq.shape}")
        layout = "lane"
        planes_in = [pad_chunk_rows(p, t_len, chunk, n_chunks, padded_chunk)
                     for p in planes]
        tbl_specs = [pl.BlockSpec(
            (tb, lane_block, k),
            lambda i, c, j: (c * n_tb + n_tb - 1 - j, i, 0))] * 5
    else:
        raise ValueError(f"unsupported table rank {ndim}")

    sym_in = pad_chunk_rows(symbols.T.astype(jnp.int32), t_len, chunk,
                            n_chunks, padded_chunk)
    sym_spec = pl.BlockSpec((tb, lane_block),
                            lambda i, c, j: (c * n_tb + n_tb - 1 - j, i))
    grid = (lanes // lane_block, n_chunks, n_tb)
    return _Plan(layout=layout, lanes=lanes, t_len=t_len, chunk=chunk,
                 n_chunks=n_chunks, tb=tb, n_tb=n_tb,
                 padded_chunk=padded_chunk, total_rows=total_rows, k=k,
                 grid=grid, sym_in=sym_in, sym_spec=sym_spec,
                 planes_in=planes_in, tbl_specs=tbl_specs)


def _block_entry(sym_ref, rcp_ref, rshift_ref, bias_ref, cmpl_ref, xmax_ref,
                 t, layout: str, planes_static):
    """Gather the encode-side table entry for row ``t`` of this T block."""
    x = sym_ref[pl.dslice(t, 1), :][0]
    if layout == "static":
        planes, g = planes_static, onehot_gather
    elif layout == "perpos":
        planes = update.EncTables(
            rcp_ref[pl.dslice(t, 1), :][0],
            rshift_ref[pl.dslice(t, 1), :][0],
            bias_ref[pl.dslice(t, 1), :][0],
            cmpl_ref[pl.dslice(t, 1), :][0],
            xmax_ref[pl.dslice(t, 1), :][0])
        g = onehot_gather
    else:  # "lane": per-position per-lane rows (lanes, K)
        planes = update.EncTables(
            rcp_ref[pl.dslice(t, 1), :, :][0],
            rshift_ref[pl.dslice(t, 1), :, :][0],
            bias_ref[pl.dslice(t, 1), :, :][0],
            cmpl_ref[pl.dslice(t, 1), :, :][0],
            xmax_ref[pl.dslice(t, 1), :, :][0])
        g = onehot_gather_lanes
    return x, planes, g


def _encode_kernel(sym_ref, rcp_ref, rshift_ref, bias_ref, cmpl_ref,
                   xmax_ref, bytes_ref, mask_ref, state_ref, s_scr,
                   *, t_len: int, chunk_size: int, t_block: int, n_tb: int,
                   layout: str):
    """Records kernel: fixed-shape renorm record planes out to HBM
    (compaction deferred to ``core.bitstream.compact_records``)."""
    lanes = sym_ref.shape[1]
    c = pl.program_id(1)      # chunk index
    j = pl.program_id(2)      # T-block step (innermost; blocks walk backward)

    @pl.when(j == 0)
    def _reset():
        # per-chunk state reset: every chunk is a standalone stream
        s_scr[0, :] = jnp.full((lanes,), C.RANS_L, _U32)

    b = n_tb - 1 - j          # T-block index within the chunk (LIFO order)
    # valid rows in this block: the final chunk may be ragged, and padding
    # rows (up to a whole T block) must emit nothing
    chunk_len = jnp.minimum(chunk_size, t_len - c * chunk_size)
    n_t = jnp.clip(chunk_len - b * t_block, 0, t_block)

    # zero the record block first: rows >= n_t are padding (mask 0), and
    # valid rows overwrite below
    bytes_ref[...] = jnp.zeros(bytes_ref.shape, _U8)
    mask_ref[...] = jnp.zeros(mask_ref.shape, _U8)

    if layout == "static":
        planes_static = update.EncTables(
            rcp_ref[0], rshift_ref[0], bias_ref[0], cmpl_ref[0], xmax_ref[0])
    else:
        planes_static = None

    def body(i, s):
        t = n_t - 1 - i       # rANS is LIFO: walk rows in reverse
        x, planes, g = _block_entry(sym_ref, rcp_ref, rshift_ref, bias_ref,
                                    cmpl_ref, xmax_ref, t, layout,
                                    planes_static)
        e = update.gather_encode_entry(planes, x, gather=g)
        s, recs = update.encode_step(s, e)
        for r, (byte, cond) in enumerate(recs):
            bytes_ref[pl.dslice(t, 1), pl.dslice(r, 1), :] = (
                byte.reshape(1, 1, lanes))
            mask_ref[pl.dslice(t, 1), pl.dslice(r, 1), :] = (
                cond.astype(_U8).reshape(1, 1, lanes))
        return s

    s = jax.lax.fori_loop(0, n_t, body, s_scr[0, :])
    s_scr[0, :] = s

    @pl.when(j == n_tb - 1)
    def _final():
        # the last (backward) block ends at t=0: the chunk's final state
        state_ref[0, :] = s_scr[0, :]


def _encode_fused_kernel(sym_ref, rcp_ref, rshift_ref, bias_ref, cmpl_ref,
                         xmax_ref, buf_ref, start_ref, len_ref, ovf_ref,
                         s_scr, ptr_scr, *scr,
                         t_len: int, chunk_size: int, t_block: int,
                         n_tb: int, layout: str, cap: int,
                         ring: int | None = None):
    """Fused kernel: renorm bytes scatter straight into the per-lane output
    streams (DESIGN.md §8) — no record planes, no host-side compaction.

    The per-lane byte cursor ``ptr`` starts at ``cap`` and decrements per
    emitted byte.  Two scatter datapaths share the cursor semantics of
    ``coder._emit_backward`` (an overflowed cursor goes negative, its
    writes drop — never wrap — and ``cap - ptr`` still reports the true
    byte need):

    * ``ring=None`` (one-hot): each write lands at ``ptr - 1`` via a
      one-hot row select over the chunk's full ``(cap, lanes)`` stream
      block — O(cap x lanes) VPU work per renorm byte;
    * ``ring=<pow2>`` (banked byte ring, DESIGN.md §10): each write lands
      at ``(ptr - 1) & (ring - 1)`` in a ``(ring, lanes)`` VMEM bank —
      O(ring x lanes) per byte.  Because the write row is the *global*
      cursor mod ring, bank row ``r`` always holds target stream row
      ``r (mod ring)``: the per-grid-step drain needs NO rotation, just a
      vertical tile of the bank to ``cap`` rows masked to the rows this
      step's cursor actually crossed (``[ptr_final, ptr_start)`` — a
      contiguous descending LIFO run of at most ``2*t_block + 4 <= ring``
      bytes, so positions are distinct mod ring and stale bank rows are
      never selected).  One roll/flush per grid step; with an unblocked T
      axis that is literally one per chunk.  Negative cursor rows fall
      outside the clipped drain window, preserving overflow/drop parity
      bit-for-bit (including ``cap < 4`` header clipping).

    At the chunk's last grid step the 4-byte big-endian state header is
    flushed through the same scatter path (low byte first — backward
    writes make it big-endian forward) and start/length/overflow are
    published.
    """
    lanes = sym_ref.shape[1]
    bank_scr = scr[0] if ring is not None else None
    c = pl.program_id(1)      # chunk index
    j = pl.program_id(2)      # T-block step (innermost; blocks walk backward)

    @pl.when(j == 0)
    def _reset():
        # per-chunk reset: fresh state, cursor at the buffer tail, zeroed
        # stream block (bytes outside the final span stay 0 on the wire).
        # The ring bank needs no zeroing: the drain mask only selects rows
        # the cursor crossed this step, which are always freshly written.
        s_scr[0, :] = jnp.full((lanes,), C.RANS_L, _U32)
        ptr_scr[0, :] = jnp.full((lanes,), cap, _I32)
        buf_ref[...] = jnp.zeros(buf_ref.shape, _U8)

    b = n_tb - 1 - j          # T-block index within the chunk (LIFO order)
    chunk_len = jnp.minimum(chunk_size, t_len - c * chunk_size)
    n_t = jnp.clip(chunk_len - b * t_block, 0, t_block)

    if layout == "static":
        planes_static = update.EncTables(
            rcp_ref[0], rshift_ref[0], bias_ref[0], cmpl_ref[0], xmax_ref[0])
    else:
        planes_static = None

    def scatter(buf, ptr, byte, cond):
        if ring is None:
            return onehot_scatter_rows(buf, ptr - 1, byte, cond)
        return onehot_scatter_rows(buf, (ptr - 1) & _I32(ring - 1), byte,
                                   cond)

    def body(i, carry):
        s, ptr, buf = carry
        t = n_t - 1 - i       # rANS is LIFO: walk rows in reverse
        x, planes, g = _block_entry(sym_ref, rcp_ref, rshift_ref, bias_ref,
                                    cmpl_ref, xmax_ref, t, layout,
                                    planes_static)
        e = update.gather_encode_entry(planes, x, gather=g)
        s, recs = update.encode_step(s, e)
        for byte, cond in recs:
            buf = scatter(buf, ptr, byte, cond)
            ptr = ptr - cond.astype(_I32)
        return s, ptr, buf

    ptr0 = ptr_scr[0, :]      # cursor at this grid step's start (drain hi)
    s, ptr, buf = jax.lax.fori_loop(
        0, n_t, body,
        (s_scr[0, :], ptr0, bank_scr[...] if ring is not None
         else buf_ref[0]))

    if ring is None:
        buf_ref[0] = buf
        s_scr[0, :] = s
        ptr_scr[0, :] = ptr

        @pl.when(j == n_tb - 1)
        def _flush():
            # chunk's last (backward) block ends at t=0: flush the 4-byte
            # big-endian state header (low byte first — backward writes
            # make it big-endian forward) and publish the stream geometry.
            # A negative cursor means the stream outgrew `cap` — its writes
            # dropped in the scatter, so the stream is truncated-but-
            # flagged, never wrapped.
            s = s_scr[0, :]
            ptr = ptr_scr[0, :]
            buf = buf_ref[0]
            emit = jnp.ones((lanes,), jnp.bool_)
            for shift in (0, 8, 16, 24):
                byte = ((s >> shift) & _M8).astype(_U8)
                buf = onehot_scatter_rows(buf, ptr - 1, byte, emit)
                ptr = ptr - 1
            buf_ref[0] = buf
            ptr_scr[0, :] = ptr
            start_ref[0, :] = jnp.maximum(ptr, 0)
            len_ref[0, :] = jnp.full((lanes,), cap, _I32) - ptr
            ovf_ref[0, :] = (ptr < 0).astype(_I32)
        return

    # ---- banked-ring drain (one roll/flush per grid step) ----
    # fold the header through the same banked path at the chunk's last step
    last = j == n_tb - 1
    hptr, hbank = ptr, buf
    emit = jnp.ones((lanes,), jnp.bool_)
    for shift in (0, 8, 16, 24):
        byte = ((s >> shift) & _M8).astype(_U8)
        hbank = scatter(hbank, hptr, byte, emit)
        hptr = hptr - 1
    bank = jnp.where(last, hbank, buf)
    ptr_f = jnp.where(last, hptr, ptr)
    bank_scr[...] = bank
    # bank row r holds target stream row r (mod ring): tile vertically to
    # cap rows and keep only the rows this step's cursor crossed
    reps = -(-cap // ring)
    tiled = (jnp.concatenate([bank] * reps, axis=0)[:cap] if reps > 1
             else bank[:cap])
    row = jax.lax.broadcasted_iota(_I32, (cap, lanes), 0)
    lo = jnp.clip(ptr_f, 0, cap)[None, :]
    hi = jnp.clip(ptr0, 0, cap)[None, :]
    drained = (row >= lo) & (row < hi)
    buf_ref[0] = jnp.where(drained, tiled, buf_ref[0])
    s_scr[0, :] = s
    ptr_scr[0, :] = ptr_f

    @pl.when(j == n_tb - 1)
    def _publish():
        ptr = ptr_scr[0, :]
        start_ref[0, :] = jnp.maximum(ptr, 0)
        len_ref[0, :] = jnp.full((lanes,), cap, _I32) - ptr
        ovf_ref[0, :] = (ptr < 0).astype(_I32)


@functools.partial(jax.jit,
                   static_argnames=("chunk_size", "prob_bits", "lane_block",
                                    "t_block", "interpret"))
def rans_encode_records(symbols: jax.Array,   # (lanes, T) int32
                       tbl: TableSet,
                       chunk_size: int | None = None,
                       prob_bits: int = C.PROB_BITS,
                       lane_block: int = 128,
                       t_block: int | None = None,
                       interpret: bool = True):
    """Records-path encode — the bytes-moved *reference* datapath.

    ONE ``pallas_call`` emitting fixed-shape renorm record planes
    (``bytes``/``mask`` of shape ``(n_chunks, padded_chunk, 2, lanes)``)
    plus per-chunk final states; the caller compacts them host-side with
    :func:`repro.core.bitstream.compact_records`.  Every encoded byte
    crosses HBM ~2x (records out, compaction in) — the production path is
    :func:`rans_encode_lanes`, which fuses compaction into the kernel.
    Kept for the bytes-moved benchmark and as a second in-kernel
    implementation the fused path is differential-tested against.

    Table layouts (detected from ``tbl.freq.ndim``):
      * ``(K,)``            — static shared table (classic rANS);
      * ``(T, K)``          — per-position shared rows (neural prior, all
                              lanes share each step's distribution);
      * ``(T, lanes, K)``   — per-position per-lane rows (the
                              ``serve.compress`` TableSet layout).

    ``chunk_size`` (None = monolithic): cut the stream into independent
    chunks, each flushed separately — the chunk axis is a *grid* dimension
    with in-kernel state reset, not a host-side loop of kernel launches.
    ``t_block`` blocks the T axis through VMEM (None = whole chunk in one
    block).

    Returns ``(bytes, mask, states)`` with shapes
    ``(n_chunks, padded_chunk, 2, lanes)`` / same / ``(n_chunks, lanes)``
    where ``padded_chunk = ceil(chunk_size / t_block) * t_block``; padding
    rows carry mask 0 and are dropped by ``compact_records``.
    """
    lanes, _ = symbols.shape
    if lanes % lane_block:
        lane_block = lanes
    p = _encode_plan(symbols, tbl, chunk_size, lane_block, t_block)

    rec_b, rec_m, states = pl.pallas_call(
        functools.partial(_encode_kernel, t_len=p.t_len, chunk_size=p.chunk,
                          t_block=p.tb, n_tb=p.n_tb, layout=p.layout),
        grid=p.grid,
        in_specs=[p.sym_spec] + p.tbl_specs,
        out_specs=[
            pl.BlockSpec((p.tb, C.MAX_RENORM_STEPS, lane_block),
                         lambda i, c, j: (c * p.n_tb + p.n_tb - 1 - j, 0, i)),
            pl.BlockSpec((p.tb, C.MAX_RENORM_STEPS, lane_block),
                         lambda i, c, j: (c * p.n_tb + p.n_tb - 1 - j, 0, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p.total_rows, C.MAX_RENORM_STEPS, lanes),
                                 _U8),
            jax.ShapeDtypeStruct((p.total_rows, C.MAX_RENORM_STEPS, lanes),
                                 _U8),
            jax.ShapeDtypeStruct((p.n_chunks, lanes), _U32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, lane_block), _U32),   # encoder states across T
        ],
        interpret=interpret,
    )(p.sym_in, *p.planes_in)
    shape = (p.n_chunks, p.padded_chunk, C.MAX_RENORM_STEPS, lanes)
    return rec_b.reshape(shape), rec_m.reshape(shape), states


@functools.partial(jax.jit,
                   static_argnames=("cap", "chunk_size", "prob_bits",
                                    "lane_block", "t_block", "interpret",
                                    "scatter"))
def rans_encode_lanes(symbols: jax.Array,   # (lanes, T) int32
                      tbl: TableSet,
                      cap: int,
                      chunk_size: int | None = None,
                      prob_bits: int = C.PROB_BITS,
                      lane_block: int = 128,
                      t_block: int | None = None,
                      interpret: bool = True,
                      scatter: str = "ring"):
    """Fused-compaction encode — ONE ``pallas_call``, packed streams out.

    The production encode datapath (DESIGN.md §8): renorm bytes scatter
    directly into per-lane output streams inside the kernel (per-lane byte
    cursor in VMEM scratch), so the kernel emits finished wire-format
    streams — byte-identical to ``coder.encode[_chunked]`` and to the
    records path + ``compact_records``, with no host-side compaction pass.

    ``scatter`` selects the in-kernel byte datapath (byte-identical by
    construction, differential-tested):

    * ``"ring"`` (default, DESIGN.md §10): bytes land in a power-of-two
      ``(ring, lane_block)`` VMEM bank at the cursor mod ring — O(ring)
      selects per byte plus one roll/flush per grid step.  The ring is
      sized from ``t_block`` (:func:`ring_size`), so blocking the T axis
      is what makes it small; with ``t_block=None`` the ring spans the
      whole chunk's worst case.
    * ``"onehot"``: the PR-5 path — every byte is a one-hot select over
      the full ``(cap, lane_block)`` stream block, O(cap) per byte.  Kept
      as the differential reference and for the measured scatter-cost
      reduction in ``BENCH_encode.json``.

    Table layouts and ``chunk_size``/``t_block`` semantics are those of
    :func:`rans_encode_records`.  ``cap`` is the per-(chunk, lane) byte
    budget (static: it sizes the output planes); streams that outgrow it
    are truncated-but-flagged exactly like every other encode path.

    Returns ``(buf, start, length, overflow)`` with shapes
    ``(n_chunks, lanes, cap)`` uint8 / ``(n_chunks, lanes)`` int32 x2 /
    ``(n_chunks, lanes)`` bool — ``ChunkedLanes``-layout planes; a
    monolithic call (``chunk_size=None``) yields ``n_chunks == 1`` and the
    caller drops the leading axis for ``EncodedLanes``.
    """
    lanes, _ = symbols.shape
    if lanes % lane_block:
        lane_block = lanes
    if cap <= 0:
        raise ValueError(f"cap must be positive, got {cap}")
    if scatter not in ("ring", "onehot"):
        raise ValueError(f"scatter must be 'ring' or 'onehot', got "
                         f"{scatter!r}")
    if scatter == "ring" and t_block is None:
        # autotuned T blocking: the ring is sized from t_block, so an
        # unblocked T axis would make it span the whole chunk's worst case
        # (>= cap — no cheaper than one-hot).  The analytic work model
        # picks the blocking that minimizes scatter + drain + step cost
        # within the VMEM budget (kernels/autotune.py).
        _, t_len = symbols.shape
        chunk = t_len if chunk_size is None else min(chunk_size, t_len)
        layout = {1: "static", 2: "perpos", 3: "lane"}.get(tbl.freq.ndim)
        if layout is not None and chunk > 0:
            t_block = select_encode_t_block(chunk, cap, lane_block,
                                            tbl.freq.shape[-1], layout)
    p = _encode_plan(symbols, tbl, chunk_size, lane_block, t_block)
    ring = ring_size(p.tb) if scatter == "ring" else None
    scratch = [
        pltpu.VMEM((1, lane_block), _U32),   # encoder states across T
        pltpu.VMEM((1, lane_block), _I32),   # byte cursors across T
    ]
    if ring is not None:
        scratch.append(pltpu.VMEM((ring, lane_block), _U8))  # byte ring bank

    buf, start, length, ovf = pl.pallas_call(
        functools.partial(_encode_fused_kernel, t_len=p.t_len,
                          chunk_size=p.chunk, t_block=p.tb, n_tb=p.n_tb,
                          layout=p.layout, cap=cap, ring=ring),
        grid=p.grid,
        in_specs=[p.sym_spec] + p.tbl_specs,
        out_specs=[
            pl.BlockSpec((1, cap, lane_block), lambda i, c, j: (c, 0, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
            pl.BlockSpec((1, lane_block), lambda i, c, j: (c, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p.n_chunks, cap, lanes), _U8),
            jax.ShapeDtypeStruct((p.n_chunks, lanes), _I32),
            jax.ShapeDtypeStruct((p.n_chunks, lanes), _I32),
            jax.ShapeDtypeStruct((p.n_chunks, lanes), _I32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(p.sym_in, *p.planes_in)
    # (n_chunks, cap, lanes) -> the ChunkedLanes (n_chunks, lanes, cap)
    # device form (the mirror of the decode kernel's input transpose)
    return buf.swapaxes(1, 2), start, length, ovf.astype(jnp.bool_)
