"""Pure-jnp oracles for every Pallas kernel (the per-kernel ref.py contract).

Each oracle is the *already-validated* core implementation (which is itself
checked byte-for-byte against the scalar golden reference), so
kernel == ref == golden is a single equivalence chain:

    rans_encode  -> repro.core.coder.encode        (byte-identical streams)
    rans_decode  -> repro.core.coder.decode        (identical symbols+probes)
    spc_quantize -> repro.core.spc.quantize_probs  (identical frequencies)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import coder, constants as C, spc
from repro.core.predictors import NeighborAverage


def rans_encode_ref(symbols: jax.Array, tbl: spc.TableSet,
                    cap: int | None = None) -> coder.EncodedLanes:
    return coder.encode(symbols, tbl, cap=cap)


def rans_encode_chunked_ref(symbols: jax.Array, tbl: spc.TableSet,
                            chunk_size: int,
                            cap: int | None = None) -> coder.ChunkedLanes:
    """Oracle for the kernel's chunk grid axis: the coder's chunked encode
    (itself a ``core.update`` consumer, byte-identical per chunk)."""
    return coder.encode_chunked(symbols, tbl, chunk_size, cap=cap)


def rans_decode_ref(enc: coder.EncodedLanes, n_symbols: int,
                    tbl: spc.TableSet, use_pred: bool = False,
                    window: int = 4, delta: int = 8, predictor=None,
                    lane_probes: bool = False):
    """Oracle = ``coder.decode`` (which consumes the same ``core.search``
    core as the kernel, so symbols AND per-lane probe counters match
    structurally).  ``use_pred`` is sugar for the paper's neighbour-average
    predictor; any ``core.predictors`` config can be passed directly."""
    if predictor is None and use_pred:
        predictor = NeighborAverage(window=window, delta=delta)
    return coder.decode(enc, n_symbols, tbl, predictor=predictor,
                        lane_probes=lane_probes)


def rans_decode_chunked_ref(chunks: coder.ChunkedLanes, n_symbols: int,
                            tbl: spc.TableSet, chunk_size: int,
                            predictor=None, lane_probes: bool = False):
    return coder.decode_chunked(chunks, n_symbols, tbl, chunk_size,
                                predictor=predictor, lane_probes=lane_probes)


def spc_quantize_ref(probs: jax.Array,
                     prob_bits: int = C.PROB_BITS) -> jax.Array:
    return spc.quantize_probs(probs, prob_bits)
