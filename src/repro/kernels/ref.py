"""Pure-jnp oracles for every Pallas kernel (the per-kernel ref.py contract).

Each oracle is the *already-validated* core implementation (which is itself
checked byte-for-byte against the scalar golden reference), so
kernel == ref == golden is a single equivalence chain:

    rans_encode  -> repro.core.coder.encode        (byte-identical streams)
    rans_decode  -> repro.core.coder.decode        (identical symbols+probes)
    spc_quantize -> repro.core.spc.quantize_probs  (identical frequencies)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import coder, constants as C, spc
from repro.core.predictors import NeighborAverage


def rans_encode_ref(symbols: jax.Array, tbl: spc.TableSet,
                    cap: int | None = None) -> coder.EncodedLanes:
    return coder.encode(symbols, tbl, cap=cap)


def rans_decode_ref(enc: coder.EncodedLanes, n_symbols: int,
                    tbl: spc.TableSet, use_pred: bool = False,
                    window: int = 4, delta: int = 8):
    pred = NeighborAverage(window=window, delta=delta) if use_pred else None
    sym, avg = coder.decode(enc, n_symbols, tbl, predictor=pred)
    return sym, avg


def spc_quantize_ref(probs: jax.Array,
                     prob_bits: int = C.PROB_BITS) -> jax.Array:
    return spc.quantize_probs(probs, prob_bits)
