"""Shared helpers for the RAS Pallas kernels.

TPU adaptation notes (DESIGN.md §2):

  * Dynamic per-lane gathers/scatters (the RTL's per-lane FIFO pointers and
    CDF probes) have no native TPU vector instruction.  We lower every such
    access to a **one-hot contraction**: ``table[idx]`` becomes
    ``sum(onehot(idx, K) * table)`` which the MXU/VPU executes as dense
    vector math.  This is the canonical TPU pattern for data-dependent
    addressing and is what the kernels below emit.
  * The lane dimension is kept **last** and sized in multiples of 128 so a
    lane group maps onto one VREG row; all per-lane quantities are
    ``(lanes,)`` vectors.
  * All integer math is uint32 with the same limb tricks as repro.core, so
    the kernels are bit-exact replicas of the reference pipeline.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
# single-source integer primitives (core/update.py uses numpy-scalar masks,
# so Pallas kernels see literals, not captured device constants); kept as a
# re-export for the kernels' historical import path.
from repro.core.update import umulhi32  # noqa: F401


def pad_chunk_rows(a: jax.Array, t_len: int, chunk_size: int,
                   n_chunks: int, padded_chunk: int) -> jax.Array:
    """Re-lay rows [0, t_len) chunk-major with each chunk padded to
    ``padded_chunk`` rows (zeros; padding rows are never read/emitting).

    The shared layout transform of the chunk-grid kernels: both the encode
    and decode kernels cut a stream into a chunk grid axis whose every chunk
    spans a whole number of T blocks, so ragged chunks (and the ragged final
    chunk) get zero rows appended up to ``padded_chunk``.
    """
    if padded_chunk == chunk_size and n_chunks * chunk_size == t_len:
        return a    # aligned layout: the re-lay would be an identity copy
    parts = []
    for ci in range(n_chunks):
        sl = a[ci * chunk_size:min((ci + 1) * chunk_size, t_len)]
        pad = padded_chunk - sl.shape[0]
        parts.append(jnp.pad(sl, ((0, pad),) + ((0, 0),) * (a.ndim - 1)))
    return jnp.concatenate(parts, axis=0)


def unpad_chunk_rows(a: jax.Array, t_len: int, chunk_size: int,
                     n_chunks: int, padded_chunk: int) -> jax.Array:
    """Inverse of :func:`pad_chunk_rows`: gather the ``t_len`` valid rows
    back out of the chunk-major padded layout (padding rows dropped)."""
    if padded_chunk == chunk_size and n_chunks * chunk_size == t_len:
        return a
    rows = np.concatenate([
        ci * padded_chunk
        + np.arange(min(chunk_size, t_len - ci * chunk_size))
        for ci in range(n_chunks)])
    return a[jnp.asarray(rows, jnp.int32)]


def onehot_gather(table: jax.Array, idx: jax.Array) -> jax.Array:
    """``table[idx]`` as a one-hot contraction.

    table: (K,) uint32/int32; idx: (lanes,) int32  ->  (lanes,) table dtype.
    Exactly one mask element is hot per lane, so a uint32 sum cannot wrap.
    """
    k = table.shape[-1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (idx.shape[0], k), 1)
    hot = iota == idx[:, None].astype(jnp.int32)
    vals = jnp.where(hot, jnp.broadcast_to(table[None, :], hot.shape),
                     jnp.zeros_like(table, shape=hot.shape))
    return jnp.sum(vals, axis=1, dtype=table.dtype)


def onehot_gather_lanes(table: jax.Array, idx: jax.Array) -> jax.Array:
    """``table[lane, idx[lane]]`` per-lane table gather via one-hot.

    table: (lanes, K); idx: (lanes,) int32 -> (lanes,) table dtype.
    The adaptive-table analogue of :func:`onehot_gather`: each lane owns its
    own table row (the neural-prior layout), so the one-hot mask contracts
    the row dimension lane-locally.
    """
    lanes, k = table.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (lanes, k), 1)
    hot = iota == idx[:, None].astype(jnp.int32)
    vals = jnp.where(hot, table, jnp.zeros_like(table))
    return jnp.sum(vals, axis=1, dtype=table.dtype)


def onehot_gather_rows(buf: jax.Array, row_idx: jax.Array) -> jax.Array:
    """``buf[row_idx[lane], lane]`` per-lane row gather via one-hot.

    buf: (cap, lanes); row_idx: (lanes,) int32 -> (lanes,) buf dtype.
    Out-of-range rows gather 0 (used for exhausted stream reads).
    """
    cap, lanes = buf.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (cap, lanes), 0)
    hot = iota == row_idx[None, :].astype(jnp.int32)
    vals = jnp.where(hot, buf, jnp.zeros_like(buf))
    return jnp.sum(vals.astype(jnp.int32), axis=0).astype(buf.dtype)


def read_state_header(buf: jax.Array, ptr: jax.Array,
                      gather=onehot_gather_rows, limit=None):
    """Per-lane 4-byte big-endian rANS state header read (decoder init).

    buf: (cap, lanes) uint8; ptr: (lanes,) int32 read cursors.  Returns the
    reconstructed ``(lanes,)`` uint32 states, the advanced cursors, and a
    ``(lanes,)`` int32 underflow count (header reads at or past ``limit`` —
    the lane's stream end; the one-hot gather already yields 0 there, the
    count makes the exhaustion *detectable*).  The in-kernel single source
    of ``coder.decoder_init``'s header walk, shared by the full decode
    kernel's per-chunk reset and the fused serve step.

    ``gather`` selects the per-lane byte access: the default reads the
    dense right-aligned ``(cap, lanes)`` layout; the zero-copy slab decode
    passes :func:`onehot_gather_lanes` with a lane-major ``(lanes, cap)``
    VMEM window (DESIGN.md §10).  ``limit`` is an int or ``(lanes,)`` array
    of one-past-the-end read bounds (``cap`` for the dense layout,
    ``wstart + wlen`` for slab windows); None skips the accounting.
    """
    s = jnp.zeros((ptr.shape[0],), jnp.uint32)
    under = jnp.zeros((ptr.shape[0],), jnp.int32)
    for _ in range(4):
        if limit is not None:
            under = under + (ptr >= limit).astype(jnp.int32)
        byte = gather(buf, ptr).astype(jnp.uint32)
        s = (s << 8) | byte
        ptr = ptr + 1
    return s, ptr, under


def masked_refill(buf: jax.Array, s: jax.Array, ptr: jax.Array,
                  gather=onehot_gather_rows, limit=None):
    """Fixed ``MAX_RENORM_STEPS``-stage masked byte refill (decode renorm).

    buf: (cap, lanes) uint8; s: (lanes,) uint32; ptr: (lanes,) int32.
    Mirrors the encoder's staged renorm bound: at most two byte reads per
    symbol, lanes above ``RANS_L`` are masked out (the RTL's clock gating).
    Shared by the full decode kernel and the fused serve step kernel.
    ``gather``/``limit`` follow :func:`read_state_header`'s contract; the
    third return is the per-lane count of *active* refills that read at or
    past ``limit`` (stream exhaustion — the injected byte is 0).
    """
    under = jnp.zeros((s.shape[0],), jnp.int32)
    for _ in range(C.MAX_RENORM_STEPS):
        cond = s < jnp.uint32(C.RANS_L)
        if limit is not None:
            under = under + (cond & (ptr >= limit)).astype(jnp.int32)
        byte = gather(buf, ptr).astype(jnp.uint32)
        s = jnp.where(cond, (s << C.RENORM_SHIFT) | byte, s)
        ptr = ptr + cond.astype(jnp.int32)
    return s, ptr, under


def next_pow2(n: int) -> int:
    """Smallest power of two >= ``n`` (>= 1): ring/bank sizes are pow2 so
    the banked cursor's ``& (ring - 1)`` wrap is one integer mask."""
    return 1 << max(int(n) - 1, 0).bit_length()


def onehot_scatter_rows(buf: jax.Array, row_idx: jax.Array, vals: jax.Array,
                        cond: jax.Array) -> jax.Array:
    """``buf[row_idx[lane], lane] = vals[lane]`` where ``cond[lane]``,
    via one-hot select — the write analogue of :func:`onehot_gather_rows`.

    buf: (cap, lanes); row_idx/vals/cond: (lanes,) -> updated (cap, lanes).
    Out-of-range rows (including the negative indices of an overflowed
    backward cursor) match no iota row, so the write is *dropped* — the
    in-kernel equivalent of the coder's out-of-bounds drop sentinel
    (DESIGN.md §3: truncated-but-flagged, never wrapped).
    """
    cap, lanes = buf.shape
    iota = jax.lax.broadcasted_iota(jnp.int32, (cap, lanes), 0)
    hot = (iota == row_idx[None, :].astype(jnp.int32)) & cond[None, :]
    return jnp.where(hot, jnp.broadcast_to(vals[None, :], buf.shape), buf)
