"""jit'd public wrappers around the RAS Pallas kernels.

``rans_encode`` / ``rans_encode_chunked`` wrap the **fused-compaction**
encode kernel (``rans_encode_lanes``): the shared ``core.update`` two-stage
update runs in-kernel and the renorm bytes scatter straight into per-lane
output streams (in-kernel byte cursor — DESIGN.md §8), so the wrappers
return packed ``EncodedLanes``/``ChunkedLanes`` with **no host-side
``compact_records`` pass** and every encoded byte crosses HBM once.
Results are byte-identical to ``repro.core.coder.encode`` /
``encode_chunked`` (the pure-JAX records reference) and therefore to the
scalar golden reference, for static ``(K,)``, per-position ``(T, K)`` and
per-lane ``(T, lanes, K)`` TableSets.  The chunked encode is a single
``pallas_call`` (chunk grid axis with in-kernel state + cursor reset).
``rans_decode`` / ``rans_decode_chunked`` wrap the prediction-guided decode
kernel (static and adaptive TableSets plus ``(T, lanes, topk)`` model-top-k
candidate planes; symbols AND per-lane probe counters are bit-identical to
the pure-JAX coder — both consume ``core.search``).  The chunked decode,
like the chunked encode, is a single ``pallas_call`` (chunk grid axis with
in-kernel state/pointer/context reset).  ``rans_decode_step`` (re-exported
from ``kernels.rans_decode``) is the fused serve decode's building block:
ONE symbol pop per lane with caller-threaded coder state, traced inside
the model scan of ``serve.compress`` (DESIGN.md §9).  ``spc_quantize``
wraps the mass-correction kernel.  All default to ``interpret=True`` (this
container is CPU-only; on a real TPU pass interpret=False).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import constants as C
# stream compaction lives in core (wire format); re-exported here for
# back-compat with the historical kernels-side import path.  The kernel
# encode wrappers below no longer call it — compaction is fused in-kernel
# (rans_encode_lanes) — but it remains the host-side half of the records
# *reference* path (rans_encode_records), which the fused path is
# differential-tested and benchmarked against.
from repro.core.bitstream import compact_records  # noqa: F401
from repro.core.bitstream import ContainerSlab
from repro.core.coder import (ChunkedLanes, EncodedLanes, _check_exhausted,
                              default_cap, num_chunks)
from repro.core.predictors import NeighborAverage
from repro.core.spc import TableSet, build_tables
from repro.kernels.rans_decode import (rans_decode_lanes, rans_decode_slab,
                                       rans_decode_step)  # noqa: F401
from repro.kernels.rans_encode import (rans_encode_lanes,  # noqa: F401
                                       rans_encode_records)

import numpy as np


def rans_encode(symbols: jax.Array, tbl: TableSet,
                cap: int | None = None,
                prob_bits: int = C.PROB_BITS,
                lane_block: int = 128,
                t_block: int | None = None,
                scatter: str = "ring",
                interpret: bool = True) -> EncodedLanes:
    """Kernel-backed multi-lane encode (bit-exact vs. core/golden).

    Fused datapath: ONE ``pallas_call`` returning finished wire-format
    streams — the in-kernel byte cursor scatters every renorm byte into
    its lane's stream as it is emitted, so there is no record-plane HBM
    round-trip and no host-side ``compact_records`` pass.  Static ``(K,)``
    and adaptive ``(T, K)`` / ``(T, lanes, K)`` TableSets are all encoded
    in-kernel (adaptive layouts block the T axis through VMEM —
    ``t_block``).  When the lane count does not tile the ``lane_block``
    grid the block collapses to one lane group (correctness over
    occupancy — the serve/parallel paths run narrow lane counts).
    """
    lanes, t_len = symbols.shape
    cap = default_cap(t_len) if cap is None else cap
    if t_len == 0:
        return _header_only_stream(lanes, cap)
    buf, start, length, overflow = rans_encode_lanes(
        symbols, tbl, cap=cap, prob_bits=prob_bits, lane_block=lane_block,
        t_block=t_block, scatter=scatter, interpret=interpret)
    return EncodedLanes(buf=buf[0], start=start[0], length=length[0],
                        overflow=overflow[0])


def _header_only_stream(lanes: int, cap: int) -> EncodedLanes:
    """The ``n_symbols == 0`` stream: 4 flush bytes of the initial state.

    Byte-identical to ``coder.encode`` on an empty symbol block (including
    the overflow-flagged ``cap < 4`` corner), built host-side — the kernel
    grid has no T blocks to run.
    """
    hdr = [(C.RANS_L >> sh) & 0xFF for sh in (0, 8, 16, 24)]
    buf = np.zeros((lanes, cap), np.uint8)
    p = cap
    for b in hdr:                   # backward emit with the drop sentinel
        if p > 0:
            buf[:, p - 1] = b
        p -= 1
    return EncodedLanes(buf=jnp.asarray(buf),
                        start=jnp.full((lanes,), max(p, 0), jnp.int32),
                        length=jnp.full((lanes,), cap - p, jnp.int32),
                        overflow=jnp.full((lanes,), p < 0))


def rans_encode_chunked(symbols: jax.Array, tbl: TableSet, chunk_size: int,
                        cap: int | None = None,
                        prob_bits: int = C.PROB_BITS,
                        lane_block: int = 128,
                        t_block: int | None = None,
                        scatter: str = "ring",
                        interpret: bool = True) -> ChunkedLanes:
    """Kernel-backed chunked encode (bit-exact vs. coder.encode_chunked).

    ONE ``pallas_call`` for the whole stream: the chunk axis is a grid
    dimension of the fused kernel (in-kernel per-chunk state + byte-cursor
    reset — no host-side loop of kernel launches and no host-side
    compaction), emitting every chunk's packed stream into one dense
    ``(n_chunks, lanes, cap)`` buffer with the chunk-aware cap
    (``default_cap(chunk_size)`` covers the worst case of every chunk,
    ragged tail included).  Static and per-position TableSets both encode
    in-kernel (per-position rows ride the chunk grid axis).  Overflow
    flags are per (chunk, lane) cell, identical to the records reference.
    """
    lanes, t_len = symbols.shape
    num_chunks(t_len, chunk_size)           # validates chunk_size > 0
    cap = default_cap(min(chunk_size, t_len)) if cap is None else cap
    if t_len == 0:                          # degenerate: zero chunks
        z = jnp.zeros((0, lanes), jnp.int32)
        return ChunkedLanes(buf=jnp.zeros((0, lanes, cap), jnp.uint8),
                            start=z, length=z,
                            overflow=jnp.zeros((0, lanes), bool))
    buf, start, length, overflow = rans_encode_lanes(
        symbols, tbl, cap=cap, chunk_size=chunk_size, prob_bits=prob_bits,
        lane_block=lane_block, t_block=t_block, scatter=scatter,
        interpret=interpret)
    return ChunkedLanes(buf=buf, start=start, length=length,
                        overflow=overflow)


def rans_decode(enc: EncodedLanes, n_symbols: int, tbl: TableSet,
                prob_bits: int = C.PROB_BITS,
                use_pred: bool = False, window: int = 4, delta: int = 8,
                predictor=None,
                candidates: jax.Array | None = None,
                lane_block: int = 128,
                t_block: int | None = None,
                interpret: bool = True,
                lane_probes: bool = False,
                exhausted_flags: bool = False):
    """Kernel-backed decode; returns (symbols (lanes,T), avg probes/symbol).

    Static ``(K,)`` and adaptive ``(T, K)`` / ``(T, lanes, K)`` TableSets
    are all decoded in-kernel (the adaptive layouts block the T axis through
    VMEM — ``t_block``).  ``predictor`` is any ``core.predictors`` config;
    ``use_pred``/``window``/``delta`` remain as sugar for the paper's
    neighbour-average predictor.  ``candidates`` is an optional
    ``(T, lanes, topk)`` model-top-k candidate plane verified in-kernel
    (topk == 0 disables speculation).  When the lane count does not tile
    the ``lane_block`` grid the block collapses to one lane group
    (correctness over occupancy — the serve/parallel paths run narrow lane
    counts).  ``lane_probes``: also return the per-lane counters
    ``(lanes,)``.  A decode that reads past a lane's stream end raises
    :class:`~repro.core.coder.StreamExhaustedError` host-side; traced
    callers (shard_map bodies) pass ``exhausted_flags=True`` to get the
    per-lane bool flag appended instead.
    """
    if predictor is None and use_pred:
        predictor = NeighborAverage(window=window, delta=delta)
    lanes = enc.buf.shape[0]
    if lanes % lane_block:
        lane_block = lanes
    if n_symbols == 0:                      # degenerate: nothing to decode
        out = (jnp.zeros((lanes, 0), jnp.int32), jnp.float32(0.0))
        if lane_probes:
            out = out + (jnp.zeros((lanes,), jnp.int32),)
        return out + (jnp.zeros((lanes,), bool),) if exhausted_flags else out
    sym, probes, under = rans_decode_lanes(
        enc.buf, enc.start, tbl.freq, tbl.cdf, t_len=n_symbols,
        prob_bits=prob_bits, predictor=predictor, candidates=candidates,
        lane_block=lane_block, t_block=t_block, interpret=interpret)
    probes = probes[0]
    under = under[0] > 0
    avg = jnp.mean(probes.astype(jnp.float32)) / n_symbols
    out = (sym, avg, probes) if lane_probes else (sym, avg)
    if exhausted_flags:
        return out + (under,)
    _check_exhausted(under, "rans_decode")
    return out


def rans_decode_chunked(chunks: ChunkedLanes | None = None,
                        n_symbols: int | None = None,
                        tbl: TableSet | None = None,
                        chunk_size: int | None = None,
                        prob_bits: int = C.PROB_BITS,
                        predictor=None,
                        candidates: jax.Array | None = None,
                        lane_block: int = 128,
                        t_block: int | None = None,
                        interpret: bool = True,
                        lane_probes: bool = False,
                        chunk_probes: bool = False,
                        exhausted_flags: bool = False,
                        from_container: ContainerSlab | None = None):
    """Kernel-backed chunked decode (mirrors :func:`rans_encode_chunked`).

    ONE ``pallas_call`` for the whole stream: the chunk axis is a grid
    dimension of the decode kernel (in-kernel per-chunk state/pointer/
    context reset — no host-side loop of kernel launches).  Each (chunk,
    lane) cell is a standalone stream, so the kernel re-reads the 4-byte
    state header at every chunk's first grid step exactly like
    ``coder.decode_chunked``'s per-chunk ``decoder_init``.  Per-position
    TableSets (leading T dim of ``n_symbols``) and ``(T, lanes, topk)``
    candidate planes ride the chunk grid axis; static tables are reused.
    Probe accounting matches the pure-JAX path per lane and per chunk (both
    consume ``core.search``).  Returns ``(symbols (lanes, T), avg_probes
    [, per-lane probes][, per-(chunk, lane) probes])``.

    **Zero-copy entry point**: pass ``from_container=`` a validated
    :class:`~repro.core.bitstream.ContainerSlab` (from
    ``bitstream.parse_chunked``) instead of ``chunks`` and the kernel reads
    straight off the packed payload slab — no host-side right-align copy
    anywhere on the path (DESIGN.md §10).  ``n_symbols``/``chunk_size``
    default to the container's header values.  Symbols and probes are
    bit-identical to the dense ``ChunkedLanes`` path.
    """
    if from_container is not None:
        if chunks is not None:
            raise ValueError(
                "pass either a dense ChunkedLanes stream or "
                "from_container=<ContainerSlab>, not both")
        cs = from_container
        if n_symbols is None:
            n_symbols = cs.meta.n_symbols
        if chunk_size is None:
            chunk_size = cs.meta.chunk_size
        n_chunks, lanes = cs.offset.shape
    else:
        if chunks is None:
            raise ValueError("a ChunkedLanes stream or from_container=... "
                             "is required")
        n_chunks, lanes = chunks.buf.shape[:2]
    n_total = num_chunks(n_symbols, chunk_size)
    if n_chunks != n_total:
        raise ValueError(
            f"stream has {n_chunks} chunks but n_symbols="
            f"{n_symbols} at chunk_size={chunk_size} implies {n_total}; "
            "decode with the chunk_size the stream was encoded with")
    if lanes % lane_block:
        lane_block = lanes
    if n_symbols == 0:                      # degenerate: zero chunks
        out = (jnp.zeros((lanes, 0), jnp.int32), jnp.float32(0.0))
        if lane_probes:
            out = out + (jnp.zeros((lanes,), jnp.int32),)
        if chunk_probes:
            out = out + (jnp.zeros((0, lanes), jnp.int32),)
        if exhausted_flags:
            out = out + (jnp.zeros((0, lanes), bool),)
        return out
    if from_container is not None:
        if cs.slab.shape[0] >= 2 ** 31:
            raise ValueError(
                f"container payload of {cs.slab.shape[0]} bytes exceeds "
                "the int32 index range of the device slab paths")
        # window size: >= 4 so the state-header read always has rows even
        # for degenerate (hostile but validated) all-empty indexes
        cap = max(cs.cap, 4)
        slab = np.asarray(cs.slab, np.uint8)
        if slab.shape[0] < cap:        # tiny payload: pad so base=0 works
            slab = np.concatenate(
                [slab, np.zeros(cap - slab.shape[0], np.uint8)])
        # host-clipped DMA bases: the in-kernel copy can never leave the
        # slab; wstart re-bases each cell's offset into its window
        base = np.clip(cs.offset, 0, slab.shape[0] - cap).astype(np.int32)
        wstart = (cs.offset - base).astype(np.int32)
        wlen = cs.length.astype(np.int32)
        sym, cprobes, cunder = rans_decode_slab(
            jnp.asarray(slab), jnp.asarray(base), jnp.asarray(wstart),
            jnp.asarray(wlen), tbl.freq, tbl.cdf, cap=cap,
            t_len=n_symbols, chunk_size=chunk_size, prob_bits=prob_bits,
            predictor=predictor, candidates=candidates,
            lane_block=lane_block, t_block=t_block, interpret=interpret)
    else:
        sym, cprobes, cunder = rans_decode_lanes(
            chunks.buf, chunks.start, tbl.freq, tbl.cdf, t_len=n_symbols,
            chunk_size=chunk_size, prob_bits=prob_bits, predictor=predictor,
            candidates=candidates, lane_block=lane_block, t_block=t_block,
            interpret=interpret)
    avg_probes = (jnp.sum(cprobes.astype(jnp.float32))
                  / (lanes * n_symbols))
    out = (sym, avg_probes)
    if lane_probes:
        out = out + (jnp.sum(cprobes, axis=0),)
    if chunk_probes:
        out = out + (cprobes,)
    if exhausted_flags:
        return out + (cunder > 0,)
    _check_exhausted(cunder > 0, "rans_decode_chunked")
    return out


def rans_decode_step_rows(buf_t: jax.Array, s: jax.Array, ptr: jax.Array,
                          tbl: TableSet,
                          prob_bits: int = C.PROB_BITS,
                          candidates: jax.Array | None = None,
                          backend: str = "kernel",
                          interpret: bool = True):
    """One rANS symbol pop across a flattened ``slots x lanes`` row axis.

    The batched serve engine's step primitive (``serve.engine``): rows are
    the engine's continuous-batching batch axis — every row owns a private
    byte stream (one column of ``buf_t``), private coder state and its own
    candidate row, so the per-step kernel that serves one request's lanes
    serves a whole slot batch unchanged (the kernel is row-generic; this
    wrapper is the batch-slot plumbing and the single dispatch point for
    the engine's two step backends).  ``buf_t`` is the ``(cap, rows)``
    TRANSPOSED stream slab — transpose once outside the scan, exactly like
    the fused serve path.  ``tbl`` rows are the per-row per-step TableSet
    ``(rows, K)``; ``candidates`` an optional ``(rows, topk)`` model-top-k
    plane.  ``backend="kernel"`` runs the per-step Pallas kernel
    (``rans_decode_step``), ``backend="coder"`` the pure-JAX
    ``coder.decode_get`` — bit-identical on symbols AND probe counters
    (both consume ``core.search``).  Returns
    ``(s', ptr', symbols (rows,), probes (rows,), under (rows,))`` with
    ``under`` int32 0/1 (this step read past the row's stream end) —
    normalized across both backends.
    """
    if backend == "kernel":
        s2, ptr2, sym, probes, under = rans_decode_step(
            buf_t, s, ptr, tbl.freq, tbl.cdf, prob_bits=prob_bits,
            candidates=candidates, interpret=interpret)
        return s2, ptr2, sym, probes, (under > 0).astype(jnp.int32)
    if backend != "coder":
        raise ValueError(f"unknown step backend {backend!r}")
    from repro.core import coder
    st, sym, probes = coder.decode_get(
        coder.DecState(s, ptr), buf_t.T, tbl, prob_bits,
        candidates=candidates)
    return st.s, st.ptr, sym, probes, st.underflow.astype(jnp.int32)


def spc_quantize_tables(probs: jax.Array,
                        prob_bits: int = C.PROB_BITS,
                        batch_block: int = 8,
                        interpret: bool = True) -> TableSet:
    """Kernel-backed SPC: batched probs -> full TableSet."""
    from repro.kernels.spc_quantize import spc_quantize
    freq = spc_quantize(probs, prob_bits=prob_bits, batch_block=batch_block,
                        interpret=interpret)
    return build_tables(freq, prob_bits)
