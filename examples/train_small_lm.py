"""End-to-end driver: train a compact probability model, then use it for
neural lossless compression (the paper's full hardware-software codesign
loop, Fig. 1).

    PYTHONPATH=src python examples/train_small_lm.py [--steps 300]

1. trains ras-pimc (the paper's compact NN probability generator) on a
   synthetic token stream for a few hundred steps with the fault-tolerant
   loop (checkpoints + restart manager);
2. compresses held-out streams with the trained model through SPC + rANS;
3. decompresses with model-top-k prediction-guided decoding and verifies
   bit-exactness;
4. shows the compression-ratio ladder: static histogram < trained neural.
"""

import argparse
import tempfile

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core import bitstream
from repro.data.pipeline import token_stream
from repro.models import init_model
from repro.serve.compress import histogram_compress, lm_compress, \
    lm_decompress
from repro.train.fault_tolerance import RestartManager
from repro.train.train_loop import init_train_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
args = ap.parse_args()

cfg = get_smoke_config("ras-pimc").with_(grad_accum=1)
params = init_model(cfg, jax.random.PRNGKey(0))
state = init_train_state(params)
step_fn = jax.jit(make_train_step(cfg, base_lr=3e-3))

b, s = 16, 128


def batch_fn(i):
    toks = token_stream(cfg.vocab_size, (b, s + 1), seed=1000 + i)
    return {"tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32)}


print(f"training ras-pimc for {args.steps} steps ...")
with tempfile.TemporaryDirectory() as ckpt:
    mgr = RestartManager(ckpt, save_every=100)

    def wrapped(st, batch):
        st, m = step_fn(st, batch)
        if int(st.step) % 50 == 0:
            print(f"  step {int(st.step):4d} loss "
                  f"{float(m['loss'])/np.log(2):.3f} bits/sym")
        return st, m

    state = mgr.run(state, wrapped, batch_fn, args.steps)

# --- compress held-out data
lanes, t = 8, 256
test = jnp.asarray(token_stream(cfg.vocab_size, (lanes, t), seed=9), jnp.int32)
raw_bytes = lanes * t  # symbols are bytes-scale (vocab 256)

enc_h, _ = histogram_compress(np.asarray(test), cfg.vocab_size)
cr_hist = raw_bytes / bitstream.compressed_size(np.asarray(enc_h.length))

stats = lm_compress(state.params, cfg, test)
cr_lm = raw_bytes / bitstream.compressed_size(np.asarray(stats.enc.length))
print(f"\ncompression ratio: static-histogram {cr_hist:.3f} -> "
      f"trained neural {cr_lm:.3f} "
      f"(model entropy {float(stats.model_xent_bits):.2f} bits/sym)")

dec, probes = lm_decompress(state.params, cfg, stats.enc, t)
exact = np.array_equal(np.asarray(dec), np.asarray(test))
print(f"decompression bit-exact: {exact}; "
      f"avg CDF probes/symbol {float(probes):.2f} "
      f"(model-top-k speculation)")
assert exact and cr_lm > cr_hist
print("OK: neural rANS beats the classical static table, bit-exactly.")
