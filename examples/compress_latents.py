"""Bits-back latent compression over the rANS stack (DESIGN.md §12).

    PYTHONPATH=src python examples/compress_latents.py

Trains the small Bit-Swap hierarchical VAE (models/vae.py) on synthetic
image patches, then codes a held-out image with bits-back over the
craystack-style stack (core/stack.py): latent bins pop against the
posterior, pixels and latents push against the generative model, and the
posterior's recovered bits pay the latent overhead back.  The script
asserts the full contract: bit-exact round trip through BOTH pop backends
(pure-JAX coder and the Pallas per-step decode kernel), exact restoration
of the stack's initial bits (the bits-back identity), and a net rate that
beats the static-histogram rANS baseline.  Runs as a CI smoke step.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import stack
from repro.data.pipeline import synthetic_image
from repro.models import vae
from repro.serve.compress import histogram_compress

LANES, D_X = 64, 64       # 64 patches of 8x8 pixels per image
CAP = 4096


def patches(img: np.ndarray) -> np.ndarray:
    """64x64 image -> (64 patches, 64 pixels) rows (8x8 tiles)."""
    return img.reshape(8, 8, 8, 8).transpose(0, 2, 1, 3).reshape(LANES, D_X)


cfg = vae.VAEConfig(d_x=D_X)
params, loss = vae.train_vae(
    cfg, lambda i: patches(synthetic_image(64, 64, seed=i)).astype(np.int64),
    steps=600, lr=1e-2, seed=0)
print(f"VAE trained: ELBO {loss / np.log(2) / D_X:.3f} bits/pixel")

x = jnp.asarray(patches(synthetic_image(64, 64, seed=999)), jnp.int32)
n_pixels = LANES * D_X

# bits-back encode onto a stack seeded with explicit initial bits; the net
# message cost is the stack's byte growth (initial bits are capital, the
# decode-side pushes restore them exactly)
st0 = stack.stack_init_bits(LANES, CAP, n_bytes=64, seed=7)
bytes0 = np.asarray(stack.stack_bytes(st0))
st = vae.bb_encode(st0, params, x, cfg)
net = int((np.asarray(stack.stack_bytes(st)) - bytes0).sum())
print(f"bits-back: {net} net bytes for {n_pixels} pixels "
      f"({net * 8 / n_pixels:.3f} bpp)")

# decode = exact reverse schedule; pixels and the initial stack must both
# come back bit-for-bit (the bits-back identity)
st_d, x_d = vae.bb_decode(st, params, cfg)
assert np.array_equal(np.asarray(x_d), np.asarray(x))
assert np.array_equal(np.asarray(st_d.s), np.asarray(st0.s))
assert np.array_equal(np.asarray(st_d.ptr), np.asarray(st0.ptr))
assert not np.asarray(st_d.underflow).any()
print("round trip: pixels bit-exact, initial stack bits restored")

# the same schedule with every pop routed through the Pallas per-step
# decode kernel — byte-identical stack evolution (shared search/refill
# cores), so the accelerated path is a drop-in
st_k = vae.bb_encode(st0, params, x, cfg, backend="kernel")
assert np.array_equal(np.asarray(st_k.buf), np.asarray(st.buf))
assert np.array_equal(np.asarray(st_k.s), np.asarray(st.s))
st_kd, x_kd = vae.bb_decode(st_k, params, cfg, backend="kernel")
assert np.array_equal(np.asarray(x_kd), np.asarray(x))
assert np.array_equal(np.asarray(st_kd.s), np.asarray(st0.s))
print("kernel pop backend: byte-identical stack, same round trip")

# flushed stacks ride the existing container tooling
enc = stack.stack_flush(st)
st_r = stack.stack_open(enc)
assert np.array_equal(np.asarray(st_r.s), np.asarray(st.s))

# baseline: static-histogram rANS over the same pixels
hist_enc, _ = histogram_compress(np.asarray(x), 256)
hist = int(np.asarray(hist_enc.length).sum())
print(f"histogram baseline: {hist} bytes ({hist * 8 / n_pixels:.3f} bpp)")
assert net < hist, (
    f"bits-back ({net} B) should beat the histogram baseline ({hist} B)")
print(f"bits-back beats histogram by {(1 - net / hist) * 100:.1f}%")
