"""Image compression with the RAS fabric (the paper's image workload).

    PYTHONPATH=src python examples/compress_images.py

Compresses a synthetic image with (a) zlib/zstd classical baselines,
(b) static-histogram rANS, and measures the prediction-guided decoder's
search-step reduction (Fig. 3 / Fig. 4(b)(c) story).  Runs as a CI smoke
step, so example/API drift fails the build.
"""

import zlib

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitstream, coder
from repro.core.predictors import NeighborAverage
from repro.data.pipeline import synthetic_image
from repro.serve.compress import histogram_compress, histogram_decompress

img = synthetic_image(256, 256, seed=42)
raw = img.tobytes()
print(f"image: {img.shape}, {len(raw)} bytes")

print(f"  zlib -9 : CR {len(raw) / len(zlib.compress(raw, 9)):.3f}")
try:  # zstd is an optional baseline — not part of the locked deps
    import zstandard
    zc = zstandard.ZstdCompressor(level=19)
    print(f"  zstd-19 : CR {len(raw) / len(zc.compress(raw)):.3f}")
except ImportError:
    print("  zstd-19 : skipped (zstandard not installed)")

lanes = 32
rows = img.reshape(lanes, -1).astype(np.int64)
enc, tbl = histogram_compress(rows, 256)
assert not np.asarray(enc.overflow).any()   # fits default_cap by contract
size = bitstream.compressed_size(np.asarray(enc.length))
print(f"  rANS    : CR {len(raw) / size:.3f} (static histogram, "
      f"{lanes} lanes)")

t = rows.shape[1]
_, probes_base = coder.decode(enc, t, tbl)
dec, probes = coder.decode(enc, t, tbl,
                           predictor=NeighborAverage(window=4, delta=8))
assert np.array_equal(np.asarray(dec), rows)
print(f"  decoder CDF probes/symbol: {float(probes_base):.2f} -> "
      f"{float(probes):.2f} with the neighbour-average predictor "
      f"(paper: 7.00 -> 3.15)")

# the same decode through the Pallas kernel (interpret mode on CPU): both
# backends consume core/search.py, so symbols and probe telemetry match
kdec, kprobes = histogram_decompress(enc, t, tbl,
                                     predictor=NeighborAverage(4, 8),
                                     backend="kernel")
assert np.array_equal(np.asarray(kdec), rows)
print(f"  kernel decode: identical symbols, {float(kprobes):.2f} "
      "probes/symbol (same counters)")

# fused-compaction kernel encode (DESIGN.md §8): packed streams come
# straight off the kernel — byte-identical to the coder's, so the packed
# container bytes match too
from repro.kernels import ops

kenc = ops.rans_encode(jnp.asarray(rows, jnp.int32), tbl)
blob = bitstream.pack(*map(np.asarray, enc), n_symbols=t)
kblob = bitstream.pack(*map(np.asarray, kenc), n_symbols=t)
assert kblob == blob
print(f"  kernel encode: fused in-kernel compaction, container "
      f"byte-identical ({len(kblob)} bytes)")
