"""Quickstart: the RAS pipeline in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds mass-corrected fixed-point tables from BF16 probabilities (SPC),
encodes a multi-lane symbol stream with the two-stage rANS coder, decodes it
with prediction-guided search, and verifies bit-exactness against the scalar
golden reference.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitstream, coder, golden, spc
from repro.core.predictors import NeighborAverage
from repro.data.pipeline import image_rows

# 1. a probability model (here: empirical histogram of an image-like stream)
lanes, t = 16, 512
rows = image_rows(lanes, t, seed=0)
counts = np.bincount(rows.ravel(), minlength=256)
tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
print(f"SPC: {tbl.freq.shape[-1]} symbols, mass = {int(tbl.freq.sum())} "
      f"(= 2^{spc.C.PROB_BITS})")

# 2. multi-lane encode (each lane is an independent rANS stream)
enc = coder.encode(jnp.asarray(rows, jnp.int32), tbl)
blob = bitstream.pack(np.asarray(enc.buf), np.asarray(enc.start),
                      np.asarray(enc.length), t)
print(f"encoded {lanes * t} symbols -> {len(blob)} bytes "
      f"({len(blob) * 8 / (lanes * t):.2f} bits/symbol)")

# 3. prediction-guided decode (neighbour average, +-8 window, safe fallback)
dec_base, probes_base = coder.decode(enc, t, tbl)
dec, probes = coder.decode(enc, t, tbl,
                           predictor=NeighborAverage(window=4, delta=8))
assert np.array_equal(np.asarray(dec), rows), "roundtrip failed"
print(f"decode OK; CDF probes/symbol: {float(probes_base):.2f} -> "
      f"{float(probes):.2f} with prediction "
      f"({1 - float(probes)/float(probes_base):.0%} fewer)")

# 4. bit-exactness vs the scalar golden reference
buf, start, length = map(np.asarray, enc)
ref = golden.encode(rows[0], np.asarray(tbl.freq), np.asarray(tbl.cdf))
assert buf[0, start[0]:start[0] + length[0]].tobytes() == ref
print("lane 0 bitstream is byte-identical to the golden reference")
