"""Quickstart: the RAS pipeline in 40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds mass-corrected fixed-point tables from BF16 probabilities (SPC),
encodes a multi-lane symbol stream with the two-stage rANS coder, decodes it
with prediction-guided search, and verifies bit-exactness against the scalar
golden reference.
"""

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitstream, coder, golden, spc
from repro.core.predictors import NeighborAverage
from repro.data.pipeline import image_rows

# 1. a probability model (here: empirical histogram of an image-like stream)
lanes, t = 16, 512
rows = image_rows(lanes, t, seed=0)
counts = np.bincount(rows.ravel(), minlength=256)
tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
print(f"SPC: {tbl.freq.shape[-1]} symbols, mass = {int(tbl.freq.sum())} "
      f"(= 2^{spc.C.PROB_BITS})")

# 2. multi-lane encode (each lane is an independent rANS stream)
enc = coder.encode(jnp.asarray(rows, jnp.int32), tbl)
blob = bitstream.pack(*map(np.asarray, enc), n_symbols=t)
print(f"encoded {lanes * t} symbols -> {len(blob)} bytes "
      f"({len(blob) * 8 / (lanes * t):.2f} bits/symbol)")

# 3. prediction-guided decode (neighbour average, +-8 window, safe fallback)
dec_base, probes_base = coder.decode(enc, t, tbl)
dec, probes = coder.decode(enc, t, tbl,
                           predictor=NeighborAverage(window=4, delta=8))
assert np.array_equal(np.asarray(dec), rows), "roundtrip failed"
print(f"decode OK; CDF probes/symbol: {float(probes_base):.2f} -> "
      f"{float(probes):.2f} with prediction "
      f"({1 - float(probes)/float(probes_base):.0%} fewer)")

# 4. bit-exactness vs the scalar golden reference
buf, start, length, _ = map(np.asarray, enc)
ref = golden.encode(rows[0], np.asarray(tbl.freq), np.asarray(tbl.cdf))
assert buf[0, start[0]:start[0] + length[0]].tobytes() == ref
print("lane 0 bitstream is byte-identical to the golden reference")

# 5. chunked streaming compression: the encoder flushes every `chunk` symbols
# so each (chunk, lane) cell is a standalone stream — they decode
# independently and in parallel (vmap here; shard_map across devices via
# repro.parallel.chunked), and payloads longer than one coder buffer stream
# through in O(chunk) memory.  Container v2 (bitstream.pack_chunked) stores
# a per-cell offset/length index for O(1) random access into the archive.
chunk = 128
chunks = coder.encode_chunked(jnp.asarray(rows, jnp.int32), tbl, chunk)
blob_v2 = bitstream.pack_chunked(*map(np.asarray, chunks), chunk_size=chunk,
                                 n_symbols=t)
cbuf, cstart, cmeta = bitstream.unpack_chunked(blob_v2)
restored = coder.ChunkedLanes(jnp.asarray(cbuf), jnp.asarray(cstart),
                              jnp.asarray(cbuf.shape[-1] - cstart))
dec_chunked, _ = coder.decode_chunked(restored, t, tbl, chunk)
assert np.array_equal(np.asarray(dec_chunked), rows), "chunked roundtrip"
print(f"chunked: {cmeta.n_chunks} chunks x {lanes} lanes -> "
      f"{len(blob_v2)} bytes (v2 container, "
      f"+{(len(blob_v2) - len(blob)) * 8 / (lanes * t):.3f} bits/symbol "
      f"flush overhead), decodes chunk-parallel")
