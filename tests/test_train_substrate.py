"""Substrate tests: optimizer, train loop, checkpoint/restart, elastic
re-mesh, straggler detection, gradient compression."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import train_batch
from repro.models import init_model
from repro.parallel.collectives import (compressed_psum, dequantize_int8,
                                        init_error_tree, quantize_int8)
from repro.train import checkpoint
from repro.train.fault_tolerance import RestartManager, StragglerMonitor
from repro.train.optimizer import (adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_lr,
                                   global_norm)
from repro.train.train_loop import init_train_state, make_train_step

jax.config.update("jax_platforms", "cpu")

CFG = get_smoke_config("ras-pimc").with_(grad_accum=1)
KEY = jax.random.PRNGKey(0)


def _state():
    return init_train_state(init_model(CFG, KEY))


def _batch(i=0, b=4, s=32):
    return jax.tree.map(jnp.asarray, train_batch(CFG, b, s, step=i))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_decreases_loss():
    state = _state()
    step = jax.jit(make_train_step(CFG, base_lr=1e-3))
    losses = []
    for i in range(20):
        state, m = step(state, _batch(i % 2))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.05, losses[::6]


def test_grad_accum_matches_full_batch():
    """accum=4 over a batch == single step on the same batch (same grads)."""
    from repro.train.train_loop import grads_fn
    params = init_model(CFG, KEY)
    batch = _batch(b=8)
    l1, g1 = grads_fn(params, batch, CFG.with_(grad_accum=1))
    l4, g4 = grads_fn(params, batch, CFG.with_(grad_accum=4))
    assert abs(float(l1) - float(l4)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((4,)) * 10.0, "b": jnp.ones((2, 2)) * -10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_cosine_lr_schedule():
    assert float(cosine_lr(jnp.int32(0), base_lr=1.0, warmup=10)) == 0.0
    assert abs(float(cosine_lr(jnp.int32(10), base_lr=1.0, warmup=10))
               - 1.0) < 1e-5
    late = float(cosine_lr(jnp.int32(10_000), base_lr=1.0, warmup=10))
    assert late <= 0.1 + 1e-5


def test_bf16_moments():
    params = init_model(CFG, KEY)
    st = adamw_init(params, moment_dtype=jnp.bfloat16)
    assert all(m.dtype == jnp.bfloat16 for m in jax.tree.leaves(st.m))
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32) * 0.1,
                         params)
    new_p, st2 = adamw_update(grads, st, params, 1e-3)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(new_p))


# ---------------------------------------------------------------------------
# checkpoint / restart / elastic
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = _state()
    step = jax.jit(make_train_step(CFG))
    state, _ = step(state, _batch())
    checkpoint.save(str(tmp_path), 1, state)
    assert checkpoint.latest_step(str(tmp_path)) == 1
    restored = checkpoint.restore(str(tmp_path), 1, state)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomic_publish(tmp_path):
    state = _state()
    checkpoint.save(str(tmp_path), 5, state)
    checkpoint.save(str(tmp_path), 7, state)
    assert checkpoint.latest_step(str(tmp_path)) == 7
    # a half-written dir (no manifest) must be ignored
    os.makedirs(tmp_path / "step_00000009")
    assert checkpoint.latest_step(str(tmp_path)) == 7


def test_restart_manager_recovers(tmp_path):
    state = _state()
    step = jax.jit(make_train_step(CFG))
    crashes = {"armed": True}

    def fault_hook(i):
        if i == 7 and crashes["armed"]:
            crashes["armed"] = False
            raise RuntimeError("synthetic node failure")

    mgr = RestartManager(str(tmp_path), save_every=5, max_failures=2)
    final = mgr.run(state, lambda s, b: step(s, b),
                    lambda i: _batch(i), 10, fault_hook=fault_hook)
    assert int(final.step) == 10
    assert mgr.failures == 1


def test_restart_manager_gives_up(tmp_path):
    state = _state()
    step = jax.jit(make_train_step(CFG))

    def always_fail(i):
        raise RuntimeError("deterministic crash")

    mgr = RestartManager(str(tmp_path), save_every=5, max_failures=2)
    with pytest.raises(RuntimeError):
        mgr.run(state, lambda s, b: step(s, b), lambda i: _batch(i), 10,
                fault_hook=always_fail)


def test_straggler_monitor():
    mon = StragglerMonitor(factor=2.0)
    for _ in range(5):
        mon.observe(0, 0.1)
    assert mon.observe(5, 0.5) is True     # 5x slower than EMA
    assert len(mon.slow_steps) == 1


def test_elastic_remesh(tmp_path):
    """Checkpoint saved under one sharding restores onto another mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = _state()
    checkpoint.save(str(tmp_path), 3, state)
    mesh = jax.make_mesh((1,), ("data",))   # the survivor mesh (1 CPU here)
    shardings = jax.tree.map(
        lambda x: NamedSharding(mesh, P(*([None] * np.ndim(x)))), state)
    restored = checkpoint.restore(str(tmp_path), 3, state,
                                  shardings=shardings)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) * 0.5 + 1e-7


def test_compressed_psum_single_device_identity():
    """On a 1-member axis, compressed psum == dequant(quant(x)) and the
    error feedback captures exactly the quantization residual."""
    x = jnp.asarray(np.random.default_rng(1).normal(size=(64,)), jnp.float32)
    err0 = jnp.zeros_like(x)

    def f(x, e):
        return compressed_psum(x, "i", e, 1)

    out, err = jax.vmap(f, axis_name="i")(x[None], err0[None])
    np.testing.assert_allclose(np.asarray(out[0] + err[0]), np.asarray(x),
                               rtol=1e-5, atol=1e-6)


def test_error_feedback_reduces_bias():
    """Accumulated compressed sums converge to the true sum over steps."""
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(size=(256,)) * 1e-3, jnp.float32)
    err = jnp.zeros_like(g)
    acc = np.zeros(256, np.float64)

    def f(x, e):
        return compressed_psum(x, "i", e, 1)

    for _ in range(50):
        out, err = jax.vmap(f, axis_name="i")(g[None], err[None])
        err = err[0]
        acc += np.asarray(out[0], np.float64)
    np.testing.assert_allclose(acc, np.asarray(g, np.float64) * 50,
                               rtol=0.02, atol=5e-4)
