"""Unified encode datapath (ISSUE 3 + ISSUE 5): one shared update core,
every backend, fused in-kernel compaction.

Acceptance pins:
  * the two-stage rANS update + fixed-depth renorm record emission exist
    exactly once, in ``core/update.py`` — ``coder.encode_put``,
    ``coder.encode_records`` and ``kernels/rans_encode.py`` all consume it
    (source-inspection guard below; ``core/golden.py`` and
    ``core/python_baseline.py`` are exempt as intentionally naive scalar
    references);
  * seeded property sweep of ``umulhi32``/``barrett_div``/``encode_step``
    against Python ``//`` + ``%`` big-int arithmetic, including the f==1
    corner and states near 2**31;
  * kernel-backed encode is byte-identical to the coder for static
    ``(K,)``, per-position ``(T, K)``, per-lane ``(T, lanes, K)`` and
    chunked streams (ragged tails included), with
    ``ops.rans_encode_chunked`` issuing a SINGLE ``pallas_call``;
  * **fused compaction** (ISSUE 5): ``ops.rans_encode[_chunked]`` return
    packed streams straight off the kernel — ``compact_records`` is never
    called on the kernel path — and the fused outputs are byte-identical
    to the records reference (records kernel + host compaction) on every
    table family;
  * cap overflow is flagged, truncated writes are dropped (never wrapped),
    and the behavior is identical across all encode paths — records,
    fused-kernel and pure-JAX — down to caps smaller than the 4-byte state
    header, with the container writers refusing every flagged stream.
"""

import inspect

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream, coder, constants as C, spc, update
from repro.kernels import common as kcommon
from repro.kernels import ops, rans_encode, ref

jax.config.update("jax_platforms", "cpu")


def _assert_streams_equal(got, want):
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# property sweeps: update-core arithmetic vs Python big-int // and %
# ---------------------------------------------------------------------------

def _py_encode_step(s: int, f: int, start: int, prob_bits: int):
    """Scalar reference: staged renorm + textbook two-stage update."""
    x_max = C.x_max_scale(prob_bits) * f
    recs = []
    for _ in range(C.MAX_RENORM_STEPS):
        cond = s >= x_max
        recs.append((s & 0xFF, cond))
        if cond:
            s >>= 8
    return ((s // f) << prob_bits) + (s % f) + start, recs


def _sweep_cases():
    """(f, s) cases: random + f==1 corner + states near 2**31 and the
    renorm thresholds."""
    rng = np.random.default_rng(301)
    total = 1 << C.PROB_BITS
    cases = [(int(rng.integers(1, total)),
              int(rng.integers(C.RANS_L, C.STATE_UPPER)))
             for _ in range(200)]
    for f in (1, 2, 3, total - 1, total // 2):
        x_max = C.x_max_scale(C.PROB_BITS) * f
        for s in (C.RANS_L, C.RANS_L + 1, x_max - 1, x_max, x_max + 1,
                  2**31 - 1, 2**31 - f, C.STATE_UPPER - 1):
            if C.RANS_L <= s < C.STATE_UPPER:
                cases.append((f, s))
    return cases


def test_encode_step_matches_python_reference():
    cases = _sweep_cases()
    total = 1 << C.PROB_BITS
    for f, s in cases:
        tbl = spc.build_tables(jnp.asarray([f, total - f], jnp.uint32))
        e = update.gather_encode_entry(tbl, jnp.zeros((1,), jnp.int32))
        got_s, got_recs = update.encode_step(
            jnp.asarray([s], jnp.uint32), e)
        want_s, want_recs = _py_encode_step(s, f, 0, C.PROB_BITS)
        assert int(got_s[0]) == want_s, (f, s)
        for (gb, gc), (wb, wc) in zip(got_recs, want_recs):
            assert int(gb[0]) == wb and bool(gc[0]) == wc, (f, s)


def test_encode_step_second_symbol_bias_folds_cdf():
    """bias folds C(x): symbol 1 of a two-symbol table lands at start=f0."""
    total = 1 << C.PROB_BITS
    rng = np.random.default_rng(302)
    for _ in range(50):
        f0 = int(rng.integers(1, total))
        f1 = total - f0
        s = int(rng.integers(C.RANS_L, C.STATE_UPPER))
        tbl = spc.build_tables(jnp.asarray([f0, f1], jnp.uint32))
        e = update.gather_encode_entry(tbl, jnp.ones((1,), jnp.int32))
        got_s, _ = update.encode_step(jnp.asarray([s], jnp.uint32), e)
        want_s, _ = _py_encode_step(s, f1, f0, C.PROB_BITS)
        assert int(got_s[0]) == want_s, (f0, s)


def test_barrett_div_and_umulhi_property():
    """update.umulhi32 / update.barrett_div vs Python big-int arithmetic
    (the re-exports in core.coder / kernels.common are this same object)."""
    assert coder.umulhi32 is update.umulhi32
    assert kcommon.umulhi32 is update.umulhi32
    assert coder.barrett_div is update.barrett_div
    rng = np.random.default_rng(303)
    a = rng.integers(0, 2**32, 300, dtype=np.uint64)
    b = rng.integers(0, 2**32, 300, dtype=np.uint64)
    got = np.asarray(update.umulhi32(jnp.asarray(a, jnp.uint32),
                                     jnp.asarray(b, jnp.uint32)))
    np.testing.assert_array_equal(got, ((a * b) >> 32).astype(np.uint32))
    total = 1 << C.PROB_BITS
    f = rng.integers(2, total + 1, 300)
    s = rng.integers(0, 2**31, 300)
    tbl = spc.build_tables(jnp.asarray(
        np.stack([f, total - f + (f == total)], -1), jnp.uint32))
    q = np.asarray(update.barrett_div(jnp.asarray(s, jnp.uint32),
                                      tbl.rcp[:, 0], tbl.rshift[:, 0]))
    np.testing.assert_array_equal(q, (s // f).astype(np.uint32))


# ---------------------------------------------------------------------------
# cross-backend byte differentials: static / (T,K) / (T,lanes,K) / chunked
# ---------------------------------------------------------------------------

def test_encode_kernel_static_differential(rans_case):
    tbl, syms = rans_case(310, k=64, lanes=8, t=70)
    syms = jnp.asarray(syms, jnp.int32)
    _assert_streams_equal(ops.rans_encode(syms, tbl),
                          ref.rans_encode_ref(syms, tbl))


@pytest.fixture(scope="module")
def perpos_enc_case():
    rng = np.random.default_rng(311)
    k, lanes, t = 32, 4, 48
    probs = rng.dirichlet(np.ones(k) * 0.5, size=t).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))        # (T, K)
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    return tbl, syms


@pytest.fixture(scope="module")
def perlane_enc_case():
    rng = np.random.default_rng(312)
    k, lanes, t = 16, 4, 32
    probs = rng.dirichlet(np.ones(k) * 0.5,
                          size=(t, lanes)).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))        # (T, lanes, K)
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    return tbl, syms


def test_encode_kernel_perpos_differential(perpos_enc_case):
    """Per-position (T, K) tables encode in-kernel — the adaptive case the
    static-table kernel could never serve."""
    tbl, syms = perpos_enc_case
    _assert_streams_equal(ops.rans_encode(syms, tbl),
                          coder.encode(syms, tbl))


def test_encode_kernel_perlane_differential(perlane_enc_case):
    """(T, lanes, K) TableSets — the serve.compress neural-prior layout."""
    tbl, syms = perlane_enc_case
    _assert_streams_equal(ops.rans_encode(syms, tbl),
                          coder.encode(syms, tbl))


def test_t_blocked_encode_matches_single_block(perpos_enc_case,
                                               perlane_enc_case):
    """Blocking the T axis through VMEM (t_block < T) must not change a
    byte: encoder state carries across blocks in scratch."""
    for tbl, syms in (perpos_enc_case, perlane_enc_case):
        whole = ops.rans_encode(syms, tbl)
        for t_block in (5, 16, syms.shape[1]):
            _assert_streams_equal(
                ops.rans_encode(syms, tbl, t_block=t_block), whole)


@pytest.mark.parametrize("chunk_size", [13, 16, 48, 49])
def test_encode_kernel_chunked_differential(perpos_enc_case, chunk_size):
    """ops.rans_encode_chunked == coder.encode_chunked per chunk and per
    lane (per-position tables ride the chunk grid axis; tails ragged)."""
    tbl, syms = perpos_enc_case
    _assert_streams_equal(
        ops.rans_encode_chunked(syms, tbl, chunk_size),
        ref.rans_encode_chunked_ref(syms, tbl, chunk_size))


def test_encode_kernel_chunked_static_and_t_blocked(rans_case):
    tbl, syms = rans_case(313, k=64, lanes=8, t=70)
    syms = jnp.asarray(syms, jnp.int32)
    want = coder.encode_chunked(syms, tbl, 17)
    _assert_streams_equal(ops.rans_encode_chunked(syms, tbl, 17), want)
    _assert_streams_equal(
        ops.rans_encode_chunked(syms, tbl, 17, t_block=5), want)


def test_chunked_encode_is_one_pallas_call(perpos_enc_case, monkeypatch):
    """The chunk axis is a grid dimension, not a host-side loop: a 4-chunk
    adaptive encode must launch exactly ONE pallas_call."""
    tbl, syms = perpos_enc_case
    calls = []
    real = rans_encode.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(rans_encode.pl, "pallas_call", counting)
    # fresh shapes so the jit cache cannot satisfy the call without tracing
    sub = syms[:, :45]
    tbl_sub = jax.tree.map(lambda a: a[:45], tbl)
    ops.rans_encode_chunked(sub, tbl_sub, 12)    # 3 full chunks + tail of 9
    assert len(calls) == 1, f"expected 1 pallas_call, saw {len(calls)}"
    assert calls[0][1] == 4                      # chunk grid axis


def _records_reference(syms, tbl, cap, chunk_size=None):
    """The records datapath: records kernel + host-side compact_records —
    the bytes-moved reference the fused kernel must match byte-for-byte."""
    if chunk_size is None:
        b, m, s = rans_encode.rans_encode_records(syms, tbl)
        return bitstream.compact_records(b[0], m[0], s[0], cap)
    b, m, s = rans_encode.rans_encode_records(syms, tbl,
                                              chunk_size=chunk_size)
    enc = jax.vmap(lambda bb, mm, ss:
                   bitstream.compact_records(bb, mm, ss, cap))(b, m, s)
    return coder.ChunkedLanes(enc.buf, enc.start, enc.length, enc.overflow)


def test_fused_encode_matches_records_reference(rans_case, perpos_enc_case,
                                                perlane_enc_case):
    """The fused in-kernel compaction reproduces the records path (records
    kernel + ``compact_records``) byte-for-byte on every table family —
    same buffers, same geometry, same overflow plane (ISSUE 5 tentpole)."""
    tbl_s, syms_s = rans_case(315, k=64, lanes=8, t=70)
    syms_s = jnp.asarray(syms_s, jnp.int32)
    cases = [(tbl_s, syms_s), perpos_enc_case, perlane_enc_case]
    for tbl, syms in cases:
        cap = coder.default_cap(syms.shape[1])
        _assert_streams_equal(ops.rans_encode(syms, tbl, cap=cap),
                              _records_reference(syms, tbl, cap))
    # chunked (ragged tail): per-chunk cap, per-cell overflow plane
    for tbl, syms in (cases[0], cases[1]):
        cap = coder.default_cap(13)
        _assert_streams_equal(
            ops.rans_encode_chunked(syms, tbl, 13, cap=cap),
            _records_reference(syms, tbl, cap, chunk_size=13))


def test_kernel_encode_path_never_calls_compact_records(rans_case,
                                                        monkeypatch):
    """``ops.rans_encode[_chunked]`` return packed streams with NO host-side
    compaction pass: poison ``compact_records`` everywhere and the kernel
    path must still produce coder-identical streams (the acceptance
    criterion of the fused datapath)."""
    def _boom(*a, **k):
        raise AssertionError(
            "compact_records called on the fused kernel encode path")

    tbl, syms = rans_case(316, k=32, lanes=4, t=41)
    syms = jnp.asarray(syms, jnp.int32)
    want = coder.encode(syms, tbl)
    want_ch = coder.encode_chunked(syms, tbl, 11)
    monkeypatch.setattr(bitstream, "compact_records", _boom)
    monkeypatch.setattr(ops, "compact_records", _boom)
    monkeypatch.setattr(coder, "compact_records", _boom)
    _assert_streams_equal(ops.rans_encode(syms, tbl), want)
    _assert_streams_equal(ops.rans_encode_chunked(syms, tbl, 11), want_ch)


def test_parallel_kernel_encode_backend(rans_case):
    """parallel.chunked.encode_chunked(backend="kernel") under shard_map ==
    the coder path, byte for byte (ragged tail included)."""
    from repro.parallel import chunked as pchunked
    tbl, syms = rans_case(314, k=64, lanes=3, t=131)
    syms = jnp.asarray(syms, jnp.int32)
    mesh = pchunked.chunk_mesh()
    want = coder.encode_chunked(syms, tbl, 17)
    got = pchunked.encode_chunked(syms, tbl, 17, mesh=mesh,
                                  backend="kernel")
    _assert_streams_equal(got, want)
    with pytest.raises(ValueError, match="backend"):
        pchunked.encode_chunked(syms, tbl, 17, backend="nope")


# ---------------------------------------------------------------------------
# cap overflow: flagged, truncated, never wrapped — identically everywhere
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def overflow_case():
    rng = np.random.default_rng(320)
    k, lanes, t = 256, 4, 64
    p = np.full(k, 1e-9)
    p[3] = 1.0
    tbl = spc.tables_from_probs(jnp.asarray(p / p.sum(), jnp.float32))
    syms = rng.integers(0, k, (lanes, t))
    syms[0] = 3                    # lane 0: near-zero-bit stream (fits)
    return tbl, jnp.asarray(syms, jnp.int32)


def test_overflow_flagged_and_truncated_not_wrapped(overflow_case):
    tbl, syms = overflow_case
    big = coder.encode(syms, tbl)
    assert not np.asarray(big.overflow).any()
    need = np.asarray(big.length)
    cap = int(need[0]) + 4         # fits lane 0 only
    small = coder.encode(syms, tbl, cap=cap)
    ovf = np.asarray(small.overflow)
    assert not ovf[0] and ovf[1:].all()
    # length reports the true byte need of the overflowed lanes
    np.testing.assert_array_equal(np.asarray(small.length), need)
    # no wrap corruption: every surviving byte equals the ample-cap
    # encode's buffer tail (pre-fix, wrapped writes clobbered it)
    bb = np.asarray(big.buf)
    np.testing.assert_array_equal(np.asarray(small.buf),
                                  bb[:, bb.shape[1] - cap:])
    # the non-overflowed lane still decodes clean and unflagged; a
    # truncated lane that over-reads its window is detected (post-sweep:
    # the plain entry raises, the flags form isolates it per lane)
    dec, _, under = coder.decode(small, syms.shape[1], tbl,
                                 return_exhausted=True)
    np.testing.assert_array_equal(np.asarray(dec)[0], np.asarray(syms)[0])
    under = np.asarray(under)
    assert not under[0] and under.any()
    with pytest.raises(coder.StreamExhaustedError):
        coder.decode(small, syms.shape[1], tbl)


def test_overflow_identical_across_encode_paths(overflow_case):
    tbl, syms = overflow_case
    cap = 16                       # overflows lanes 1..3
    want = coder.encode(syms, tbl, cap=cap)
    _assert_streams_equal(coder.encode_records(syms, tbl, cap=cap), want)
    _assert_streams_equal(ops.rans_encode(syms, tbl, cap=cap), want)


def test_overflowed_streams_refuse_to_pack(overflow_case):
    """The container writers validate the overflow plane: a truncated
    stream raises instead of shipping an undecodable blob (the plane rides
    the ``pack(*map(np.asarray, enc), ...)`` idiom as the 4th field)."""
    tbl, syms = overflow_case
    small = coder.encode(syms, tbl, cap=16)
    with pytest.raises(ValueError, match="overflow"):
        bitstream.pack(*map(np.asarray, small), n_symbols=syms.shape[1])
    ch = coder.encode_chunked(syms, tbl, 16, cap=12)
    with pytest.raises(ValueError, match="overflow"):
        bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=16,
                               n_symbols=syms.shape[1])
    # healthy streams still pack
    ok = coder.encode(syms, tbl)
    blob = bitstream.pack(*map(np.asarray, ok), n_symbols=syms.shape[1])
    assert bitstream.unpack(blob)[2].n_symbols == syms.shape[1]


def test_overflow_chunked(overflow_case):
    tbl, syms = overflow_case
    want = coder.encode_chunked(syms, tbl, 16, cap=12)
    assert np.asarray(want.overflow).any()
    got = ops.rans_encode_chunked(syms, tbl, 16, cap=12)
    _assert_streams_equal(got, want)
    # ample cap: no flags anywhere
    ok = coder.encode_chunked(syms, tbl, 16)
    assert not np.asarray(ok.overflow).any()


@pytest.mark.parametrize("cap", [3, 5, 12])
def test_overflow_parity_tiny_caps_all_paths(overflow_case, cap):
    """Overflow propagation is identical across the pure-JAX coder, the
    records reference and the fused kernel, down to caps smaller than the
    4-byte state header (where even the header is clipped), on both the
    monolithic and chunked paths — and every flagged stream refuses to
    pack (ISSUE 5 satellite: no path may under-flag a too-small cap)."""
    tbl, syms = overflow_case
    want = coder.encode(syms, tbl, cap=cap)
    assert np.asarray(want.overflow).any()
    _assert_streams_equal(coder.encode_records(syms, tbl, cap=cap), want)
    _assert_streams_equal(ops.rans_encode(syms, tbl, cap=cap), want)
    _assert_streams_equal(_records_reference(syms, tbl, cap), want)
    want_ch = coder.encode_chunked(syms, tbl, 16, cap=cap)
    assert np.asarray(want_ch.overflow).any()
    fused_ch = ops.rans_encode_chunked(syms, tbl, 16, cap=cap)
    _assert_streams_equal(fused_ch, want_ch)
    _assert_streams_equal(
        _records_reference(syms, tbl, cap, chunk_size=16), want_ch)
    # truncated-but-flagged streams refuse to pack on every path
    for enc in (want, ops.rans_encode(syms, tbl, cap=cap)):
        with pytest.raises(ValueError, match="overflow"):
            bitstream.pack(*map(np.asarray, enc), n_symbols=syms.shape[1])
    for ch in (want_ch, fused_ch):
        with pytest.raises(ValueError, match="overflow"):
            bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=16,
                                   n_symbols=syms.shape[1])


# ---------------------------------------------------------------------------
# structural guard: no private update logic outside core/update.py
# (core/golden.py and core/python_baseline.py are exempt: intentionally
# naive scalar references)
# ---------------------------------------------------------------------------

def test_no_private_update_logic_outside_core():
    csrc = inspect.getsource(coder)
    ksrc = inspect.getsource(rans_encode)
    gsrc = inspect.getsource(kcommon)
    for src, name in ((csrc, "core/coder.py"), (ksrc, "kernels/rans_encode"),
                      (gsrc, "kernels/common.py")):
        assert "def umulhi32" not in src, f"{name} redefines umulhi32"
        assert "def barrett_div" not in src, f"{name} redefines barrett_div"
        # the encode-side renorm shift appears only in the update core
        # (decode-side refill shifts left, which is allowed)
        assert ">> C.RENORM_SHIFT" not in src, (
            f"{name} reimplements the encode renorm")
        assert "x_max" not in src or name != "core/coder.py", (
            "core/coder.py touches the renorm threshold directly")
    # both consumers run the shared core
    for src, name in ((csrc, "core/coder.py"),
                      (ksrc, "kernels/rans_encode")):
        assert "update.encode_step" in src, f"{name} bypasses the core"
        assert "update.gather_encode_entry" in src
    # compaction is single-sourced in core/bitstream (kernels re-export)
    osrc = inspect.getsource(ops)
    assert "from repro.core.bitstream import compact_records" in osrc
    assert "def compact_records" not in osrc
    assert "def compact_records" in inspect.getsource(bitstream)
    assert ops.compact_records is bitstream.compact_records
    assert coder.compact_records is bitstream.compact_records


def test_update_module_is_single_source():
    doc = update.__doc__
    for anchor in ("Sec. IV-B", "Sec. IV-A", "DESIGN.md §6",
                   "MAX_RENORM_STEPS"):
        assert anchor in doc
