"""Launch/analysis substrate tests (no 512-device init — that is dryrun-only)."""

import numpy as np
import jax
import pytest

from repro.analysis.hlo import collective_stats, op_histogram, _shape_bytes
from repro.analysis.roofline import model_flops, scan_multiplier
from repro.configs import ARCH_IDS, SHAPES, get_config, grid, shape_applicable

jax.config.update("jax_platforms", "cpu")


def test_grid_covers_40_cells():
    cells = list(grid())
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    skipped = [c for c in cells if not c[2]]
    assert len(skipped) == 8           # 8 full-attention archs x long_500k
    assert all(s == "long_500k" for _, s, ok, _ in cells if not ok)


def test_exact_assigned_configs():
    """The pool configs must match the assignment sheet exactly."""
    want = {
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
        "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
    }
    for arch, (nl, d, h, kv, ff, v) in want.items():
        c = get_config(arch)
        got = (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
               c.vocab_size)
        assert got == (nl, d, h, kv, ff, v), (arch, got)


def test_moe_flags():
    assert get_config("mixtral-8x22b").n_experts == 8
    assert get_config("mixtral-8x22b").sliding_window == 4096
    assert get_config("phi3.5-moe-42b-a6.6b").n_experts == 16
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("recurrentgemma-2b").block_pattern == \
        ("rec", "rec", "attn")


def test_stage_layer_counts():
    for arch in ARCH_IDS:
        if arch == "ras-pimc":
            continue
        cfg = get_config(arch)
        total = sum(len(pat) * reps for pat, reps in cfg.stages)
        assert total == cfg.n_layers, (arch, total)


def test_scan_multiplier():
    cfg = get_config("llama3-405b")
    assert scan_multiplier(cfg, SHAPES["train_4k"]) == 126 * cfg.grad_accum
    assert scan_multiplier(cfg, SHAPES["decode_32k"]) == 126


def test_model_flops_train_matches_6nd():
    cfg = get_config("qwen3-4b")
    mf = model_flops(cfg, SHAPES["train_4k"])
    n = cfg.param_count_estimate()
    assert abs(mf - 6 * n * 256 * 4096) / mf < 1e-9


def test_hlo_collective_parser():
    hlo = """
ENTRY %main (p0: f32[8,16]) -> f32[8,16] {
  %p0 = f32[8,16] parameter(0)
  %ar = f32[8,16] all-reduce(%p0), replica_groups={}
  ROOT %out = f32[8,16] add(%ar, %p0)
}
%body (x: bf16[4]) -> bf16[4] {
  %x = bf16[4] parameter(0)
  %ag = bf16[16] all-gather(%x), dimensions={0}
  ROOT %r = bf16[4] slice(%ag)
}
"""
    st = collective_stats(hlo)
    assert st["all-reduce"]["count"] == 1
    assert st["all-reduce"]["bytes"] == 8 * 16 * 4
    assert st["all-gather"]["count"] == 1
    assert st["entry_bytes"] == 8 * 16 * 4   # all-reduce in ENTRY
    assert st["body_bytes"] == 8             # all-gather operand in %body
    assert _shape_bytes("(f32[2,2], bf16[4])") == 16 + 8
    hist = op_histogram(hlo)
    assert any(op == "parameter" for op, _ in hist)


def test_mesh_helpers():
    from repro.launch.mesh import make_mesh_for
    # on 1 CPU device only shape (1,1) is constructible
    m = make_mesh_for(1)
    assert m.devices.size == 1
