"""Shared test substrate: hermetic CPU JAX + persistent compilation cache.

Importing this before any test module guarantees every suite runs on the
CPU backend (the container has no accelerator) and that XLA executables
persist across pytest sessions under ``.pytest_cache/jax`` — the suite's
wall time is dominated by recompilation, so warm runs are several times
faster.  Session-scoped fixtures below hold the TableSet/symbol cases that
many tests used to rebuild per-test.
"""

from __future__ import annotations

import os

import numpy as np
import pytest

import jax

# hermetic default: pin CPU (the container has no accelerator).  An explicit
# JAX_PLATFORMS in the environment wins, so the real-hardware kernel tier
# can run on a TPU host: JAX_PLATFORMS=tpu pytest tests/test_tpu_hw.py -m tpu
if not os.environ.get("JAX_PLATFORMS"):
    jax.config.update("jax_platforms", "cpu")

_CACHE_DIR = os.path.join(os.path.dirname(os.path.dirname(__file__)),
                          ".pytest_cache", "jax")
try:  # persistent XLA compilation cache (saves minutes on warm runs)
    jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
except Exception:  # older jax: cache knobs absent — correctness unaffected
    pass

import jax.numpy as jnp  # noqa: E402  (after backend pinning)

from repro.core import spc  # noqa: E402


@pytest.fixture(autouse=True, scope="module")
def _release_jax_executables_per_module():
    """Drop in-process jit caches at module teardown.

    The suite compiles hundreds of executables (the fused serve-decode
    scans are large); keeping every one mapped for the whole session can
    exhaust process code-mapping resources and segfault XLA's compiler
    late in the run on small CI hosts.  Compiled artifacts persist in the
    on-disk cache above, so cross-module re-compiles stay cheap — this
    only bounds *live* executables, trading a little cache-lookup time
    for a flat memory-map profile.
    """
    yield
    jax.clear_caches()


def _build_case(seed, k, lanes, t, conc):
    rng = np.random.default_rng(seed)
    tbl = spc.tables_from_probs(
        jnp.asarray(rng.dirichlet(np.full(k, conc)), jnp.float32))
    syms = rng.integers(0, k, (lanes, t))
    return tbl, syms


@pytest.fixture(scope="session")
def rans_case():
    """Memoized (TableSet, symbols) factory shared across the session.

    ``rans_case(seed, k=96, lanes=3, t=257, conc=0.4)`` — identical
    signature to the old per-module ``_random_case`` helpers, but each
    distinct case is built once per session instead of once per test.
    """
    cache: dict = {}

    def make(seed, k=96, lanes=3, t=257, conc=0.4):
        key = (seed, k, lanes, t, conc)
        if key not in cache:
            cache[key] = _build_case(seed, k, lanes, t, conc)
        return cache[key]

    return make


@pytest.fixture(scope="session")
def image_histogram_tbl():
    """Static 256-symbol TableSet from the shared image-rows histogram."""
    from repro.data.pipeline import image_rows
    counts = np.bincount(image_rows(8, 4096, seed=0).ravel(), minlength=256)
    return jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
