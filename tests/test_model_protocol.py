"""The model-state protocol: one compression stack for the whole zoo.

Pins the tentpole refactor's contracts:

* registry integrity — every ``ARCH_IDS`` entry loads both configs, its
  ``abstract_model`` shapes build, and its family dispatches a protocol;
* ``StateSpec`` classification — ring vs recurrent leaves, bounded vs
  unbounded ring windows, and the derived wrap/ring lengths the engine's
  admission guard runs on;
* the serve layer imports ONLY the protocol surface (grep-guard: no
  ``repro.models.transformer`` import survives in serve/);
* named errors instead of silent mis-batching — ``prefill_chunk`` on a
  recurrent family and ``BatchEngine(prefill="force")`` both raise
  :class:`PrefillUnsupportedError`;
* zoo round trips — Mamba2 (pure recurrent) and RecurrentGemma (ring +
  recurrent hybrid) smoke configs: kernel/coder containers byte-identical
  and the FUSED kernel decompress bit-exact, state carried across chunk
  boundaries (ragged tail included);
* engine semantics for recurrent state — streams longer than ``max_len``
  are accepted (recurrent state never wraps) and stay byte-identical to
  the single-request path; ``prefill="auto"`` steps down cleanly; frozen
  rows keep their recurrent leaves bit-exactly (the freeze-select
  regression at the ``_chunk_body`` level); windowed-dense prefill steps
  down by RING length, not ``max_len`` (the mixtral wrap fix).
"""

import glob
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (ARCH_IDS, SERVE_SMOKE_ARCHS, get_config,
                           get_protocol as registry_protocol,
                           get_smoke_config)
from repro.core import bitstream
from repro.data.pipeline import token_stream
from repro.models import (PrefillUnsupportedError, abstract_model,
                          can_prefill, decode_step, init_model, init_state,
                          prefill_chunk, recurrent_state_tree, ring_length,
                          state_spec, wrap_length)
from repro.serve.compress import lm_compress_chunked, lm_decompress_chunked
from repro.serve.engine import BatchEngine, _chunk_body

jax.config.update("jax_platforms", "cpu")

CHUNK = 8


@pytest.fixture(scope="module")
def zoo():
    """Initialized smoke params for the serve-wired archs (built once)."""
    out = {}
    for arch in ("mamba2-130m", "recurrentgemma-2b"):
        cfg = get_smoke_config(arch)
        out[arch] = (cfg, init_model(cfg, jax.random.PRNGKey(0)))
    return out


def _toks(cfg, lanes, t_len, seed):
    return np.asarray(token_stream(cfg.vocab_size, (lanes, t_len),
                                   seed=seed), np.int32)


def _blob(params, cfg, toks, backend="coder"):
    stats = lm_compress_chunked(params, cfg, jnp.asarray(toks), CHUNK,
                                backend=backend)
    enc = jax.tree.map(np.asarray, stats.chunks)
    return bitstream.pack_chunked(enc.buf, enc.start, enc.length,
                                  enc.overflow, chunk_size=CHUNK,
                                  n_symbols=toks.shape[1])


# ---------------------------------------------------------------------------
# registry integrity + protocol dispatch
# ---------------------------------------------------------------------------

def test_registry_integrity():
    for arch in ARCH_IDS:
        cfg, smoke = get_config(arch), get_smoke_config(arch)
        assert cfg.name and smoke.vocab_size >= 256
        proto = registry_protocol(arch)
        assert proto.family == cfg.family
        # abstract shapes build without allocating anything
        tree = abstract_model(smoke)
        assert jax.tree.leaves(tree), arch
        spec = state_spec(smoke)
        assert spec.ring or spec.recurrent or spec.kinds == ("cross",), arch


def test_unknown_arch_and_family_are_named_errors():
    with pytest.raises(KeyError, match="unknown arch"):
        get_smoke_config("no-such-arch")
    from repro.models import get_protocol
    bad = get_smoke_config("ras-pimc").with_(family="holographic")
    with pytest.raises(KeyError, match="no model protocol"):
        get_protocol(bad)


def test_state_spec_classification():
    pimc = get_smoke_config("ras-pimc")       # pure unbounded ring
    ssm = get_smoke_config("mamba2-130m")     # pure recurrent
    hyb = get_smoke_config("recurrentgemma-2b")   # ring(16) + recurrent
    moe = get_smoke_config("mixtral-8x22b")   # sliding-window ring(16)
    sp, ss, sh, sm = map(state_spec, (pimc, ssm, hyb, moe))
    assert sp.ring and not sp.recurrent and sp.ring_window == -1
    assert ss.recurrent and not ss.ring and ss.ring_window == 0
    assert sh.ring and sh.recurrent
    assert sh.ring_window == hyb.local_window == 16
    assert sm.ring_window == moe.sliding_window == 16
    # wrap/ring lengths drive the engine admission guard
    assert wrap_length(pimc, 32) == 32          # unbounded ring wraps
    assert wrap_length(ssm, 32) is None         # O(1) state never wraps
    assert wrap_length(hyb, 32) is None         # 32 >= window: saturates
    assert wrap_length(hyb, 8) == 8             # under-sized ring wraps
    assert ring_length(hyb, 32) == 16           # allocated = min(len, win)
    assert ring_length(pimc, 32) == 32


def test_state_leaves_row_axis_and_recurrent_tree():
    for arch in SERVE_SMOKE_ARCHS:
        cfg = get_smoke_config(arch)
        st = init_state(cfg, 3, 16)
        for leaf in jax.tree.leaves(st):
            assert leaf.shape[1] == 3, arch     # protocol row-axis pin
            assert not np.asarray(leaf).any(), arch  # zeros = fresh reset
        rec = recurrent_state_tree(st)
        assert any(jax.tree.leaves(rec)) == state_spec(cfg).recurrent


def test_serve_imports_protocol_only():
    """Grep-guard: serve/ never imports an architecture module again."""
    serve_dir = os.path.join(os.path.dirname(__file__), os.pardir, "src",
                             "repro", "serve")
    paths = glob.glob(os.path.join(serve_dir, "*.py"))
    assert paths
    for path in paths:
        src = open(path).read()
        assert "models.transformer" not in src, path
        assert "models import transformer" not in src, path


# ---------------------------------------------------------------------------
# named errors instead of silent mis-batching
# ---------------------------------------------------------------------------

def test_prefill_unsupported_is_named(zoo):
    cfg, params = zoo["mamba2-130m"]
    st = init_state(cfg, 2, 16)
    toks = jnp.zeros((2, 4), jnp.int32)
    with pytest.raises(PrefillUnsupportedError, match="sequential state"):
        prefill_chunk(params, st, toks, jnp.zeros(2, jnp.int32),
                      jnp.full(2, 4, jnp.int32), cfg)
    with pytest.raises(PrefillUnsupportedError, match="prefill='force'"):
        BatchEngine(params, cfg, slots=1, lanes=2, chunk_size=CHUNK,
                    prefill="force")
    assert not can_prefill(cfg)


# ---------------------------------------------------------------------------
# zoo round trips: compress -> container -> fused kernel decompress
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-2b"])
def test_zoo_chunked_roundtrip_bit_exact(zoo, arch):
    cfg, params = zoo[arch]
    toks = _toks(cfg, 2, 20, seed=3)            # 20 = 2 full chunks + tail
    blob_c = _blob(params, cfg, toks, backend="coder")
    blob_k = _blob(params, cfg, toks, backend="kernel")
    assert blob_c == blob_k                     # backends byte-identical
    slab = bitstream.parse_chunked(blob_k)
    dec, _ = lm_decompress_chunked(params, cfg, slab, 20, CHUNK,
                                   backend="kernel")
    assert np.array_equal(np.asarray(dec), toks)
    dec2, _ = lm_decompress_chunked(params, cfg, slab, 20, CHUNK,
                                    backend="coder")
    assert np.array_equal(np.asarray(dec2), toks)


# ---------------------------------------------------------------------------
# engine: recurrent state across slot join/retire and long streams
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["mamba2-130m", "recurrentgemma-2b"])
def test_engine_recurrent_long_stream_byte_identical(zoo, arch):
    """T > max_len is ACCEPTED for recurrent/window-saturated state (the
    old transformer-only guard raised) and stays byte-identical to the
    single-request path; prefill='auto' steps down to the step program."""
    cfg, params = zoo[arch]
    eng = BatchEngine(params, cfg, slots=2, lanes=2, chunk_size=CHUNK,
                      max_len=16)
    assert eng._prog_prefill is None            # clean step-down
    long_toks = _toks(cfg, 2, 40, seed=5)       # 40 > max_len=16
    short_toks = _toks(cfg, 2, 20, seed=6)
    rid_l = eng.submit_compress(long_toks)      # no allow_wrap needed
    rid_s = eng.submit_compress(short_toks)
    res = eng.run()
    assert res[rid_l].ok and res[rid_s].ok
    assert eng.prefill_cycles == 0
    assert res[rid_l].blob == _blob(params, cfg, long_toks)
    assert res[rid_s].blob == _blob(params, cfg, short_toks)
    did = eng.submit_decompress(res[rid_l].blob)
    out = eng.run()[did]
    assert out.ok and np.array_equal(out.tokens, long_toks)


def test_engine_unbounded_ring_still_guards():
    """Full-attention archs keep the wrap guard — state-spec-driven, not
    dropped: T > max_len without allow_wrap is still a named rejection."""
    cfg = get_smoke_config("ras-pimc")
    params = init_model(cfg, jax.random.PRNGKey(1))
    eng = BatchEngine(params, cfg, slots=1, lanes=2, chunk_size=CHUNK,
                      max_len=16)
    with pytest.raises(ValueError, match="exceeds the engine ring"):
        eng.submit_compress(_toks(cfg, 2, 24, seed=1))


def test_frozen_rows_keep_recurrent_state(zoo):
    """_chunk_body-level freeze regression: a row with n_valid < chunk_size
    must end the cycle with recurrent state INDEPENDENT of whatever sits in
    its teacher-forced inputs past n_valid (before the freeze-select,
    frozen steps kept mutating (h, conv) on garbage tokens)."""
    cfg, params = zoo["mamba2-130m"]
    rows = 2
    kw = dict(cfg=cfg, chunk_size=CHUNK, prob_bits=12, topk=4,
              backend="coder", interpret=True)

    def run(tf):
        cache = init_state(cfg, rows, 16)
        tok = jnp.zeros((rows, 1), jnp.int32)
        fresh = jnp.ones(rows, bool)
        pos0 = jnp.zeros(rows, jnp.int32)
        mode = jnp.full(rows, 1, jnp.int32)             # MODE_COMPRESS
        n_valid = jnp.asarray([CHUNK, 3], jnp.int32)    # row 1 freezes at 3
        # compress rows carry an empty stream window, as in _build_cycle
        buf = jnp.zeros((rows, 64), jnp.uint8)
        start = jnp.zeros(rows, jnp.int32)
        cache, *_ = _chunk_body(params, cache, tok, fresh, pos0, mode,
                                n_valid, jnp.asarray(tf), buf, start, **kw)
        return jax.tree.map(lambda a: np.asarray(a[:, 1]), cache)

    base = _toks(cfg, rows, CHUNK, seed=9)
    poisoned = base.copy()
    poisoned[1, 3:] = (poisoned[1, 3:] + 7) % cfg.vocab_size
    a, b = run(base), run(poisoned)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y), a, b)


def test_windowed_dense_prefill_steps_down_by_ring_length():
    """Sliding-window dense (mixtral): the allocated ring is min(max_len,
    window), so a request with window < T <= max_len must NOT take the
    prefill fast path (attn_prefill needs pos0 + S <= ring slots) — and
    the step-path output stays byte-identical to the single-request path.
    Before the ring_len fix this wrongly prefilled on a wrapped ring."""
    cfg = get_smoke_config("mixtral-8x22b")     # sliding_window = 16
    params = init_model(cfg, jax.random.PRNGKey(3))
    eng = BatchEngine(params, cfg, slots=1, lanes=2, chunk_size=CHUNK,
                      max_len=32)
    assert eng.ring_len == 16 and eng._prog_prefill is not None
    toks = _toks(cfg, 2, 24, seed=4)            # 16 < 24 <= 32
    rid = eng.submit_compress(toks)             # accepted: window saturates
    res = eng.run()
    assert res[rid].ok and eng.prefill_cycles == 0
    assert res[rid].blob == _blob(params, cfg, toks)
    # an in-ring request still rides the fast path
    short = _toks(cfg, 2, 12, seed=8)
    rid2 = eng.submit_compress(short)
    res2 = eng.run()
    assert res2[rid2].ok and eng.prefill_cycles > 0
    assert res2[rid2].blob == _blob(params, cfg, short)
