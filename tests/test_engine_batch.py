"""Batched serving engine: continuous batching + byte-identity contract.

The engine's whole claim is that co-batching requests into slots of one
traced step program changes THROUGHPUT and nothing else: every per-request
blob (compress) and token matrix (decompress) is byte-identical to the
single-request ``lm_compress_chunked`` / ``lm_decompress_chunked`` path.
These tests pin that contract across the scheduler's moving parts —
ragged chunk-boundary join/retire, seeded Poisson arrivals, per-request
cap overflow, queue overflow, both step backends, and the golden-vector
corpus payloads.
"""

import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import bitstream
from repro.data.pipeline import token_stream
from repro.models import init_model
from repro.serve.compress import lm_compress_chunked, lm_decompress_chunked
from repro.serve.engine import (BatchEngine, EngineQueueFullError,
                                RequestOverflowError)

jax.config.update("jax_platforms", "cpu")

CFG = get_smoke_config("ras-pimc")
KEY = jax.random.PRNGKey(2)
LANES = 4

_GEN_PATH = os.path.join(os.path.dirname(__file__), "golden_vectors")
sys.path.insert(0, _GEN_PATH)
from generate import CASES, build_case  # noqa: E402


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, KEY)


def _tokens(t_len, seed):
    return np.asarray(token_stream(CFG.vocab_size, (LANES, t_len),
                                   seed=seed), np.int32)


def _ref_blob(params, toks, chunk_size, prob_bits=None):
    """The single-request reference: lm_compress_chunked -> container."""
    stats = lm_compress_chunked(params, CFG, jnp.asarray(toks),
                                chunk_size=chunk_size)
    enc = jax.tree.map(np.asarray, stats.chunks)
    kw = {} if prob_bits is None else {"prob_bits": prob_bits}
    return bitstream.pack_chunked(enc.buf, enc.start, enc.length,
                                  enc.overflow, chunk_size=chunk_size,
                                  n_symbols=toks.shape[1], **kw)


def test_ragged_join_retire_byte_identity(params):
    """Three ragged requests through two slots: requests join and retire at
    chunk boundaries mid-run (the third admits only once a slot frees) and
    every blob still equals its single-request reference byte for byte."""
    eng = BatchEngine(params, CFG, slots=2, lanes=LANES, chunk_size=8,
                      max_len=32)
    toks = [_tokens(20, 3), _tokens(16, 4), _tokens(9, 5)]
    rids = [eng.submit_compress(t) for t in toks]
    res = eng.run()
    for rid, t in zip(rids, toks):
        assert res[rid].ok, res[rid].error
        assert res[rid].blob == _ref_blob(params, t, 8)
    # continuous batching actually happened: the third request was queued
    # behind a full engine and admitted on a later cycle into a freed slot
    cycles = {rid: cyc for rid, _slot, cyc in eng.admission_log}
    assert cycles[rids[0]] == 0 and cycles[rids[1]] == 0
    assert cycles[rids[2]] > 0


def test_mixed_compress_decompress_cobatch(params):
    """Compress and decompress requests share one step program; decoded
    tokens equal the single-request decode AND the original stream."""
    t_a, t_b = _tokens(16, 6), _tokens(12, 7)
    blob_b = _ref_blob(params, t_b, 8)
    eng = BatchEngine(params, CFG, slots=2, lanes=LANES, chunk_size=8,
                      max_len=32)
    rc = eng.submit_compress(t_a)
    rd = eng.submit_decompress(blob_b)
    res = eng.run()
    assert res[rc].ok and res[rc].blob == _ref_blob(params, t_a, 8)
    assert res[rd].ok
    np.testing.assert_array_equal(res[rd].tokens, t_b)
    single = lm_decompress_chunked(params, CFG, bitstream.parse_chunked(blob_b),
                                   t_b.shape[1], 8)[0]
    np.testing.assert_array_equal(res[rd].tokens, np.asarray(single))


def test_golden_vector_corpus_identity(params):
    """The committed golden-vector symbol payloads, fed as token streams
    (every case is lanes=4 with k < vocab), compress through the engine
    byte-identically to the single-request path — the corpus the container
    format is pinned on also pins the scheduler."""
    eng = BatchEngine(params, CFG, slots=2, lanes=LANES, chunk_size=16,
                      max_len=64)
    payloads, rids = [], []
    for case in CASES:
        _tbl, syms = build_case(case)
        payloads.append(np.asarray(syms, np.int32))
        rids.append(eng.submit_compress(payloads[-1]))
    res = eng.run()
    for rid, toks in zip(rids, payloads):
        assert res[rid].ok, res[rid].error
        assert res[rid].blob == _ref_blob(params, toks, 16)


def test_poisson_admission_determinism(params):
    """Seeded Poisson arrivals on the virtual clock: two runs of the same
    workload produce the same admission schedule and identical bytes."""
    rng = np.random.default_rng(17)
    arrivals = np.cumsum(rng.exponential(2.0, size=5))
    toks = [_tokens(12 + 4 * (i % 2), 20 + i) for i in range(5)]

    def run_once():
        eng = BatchEngine(params, CFG, slots=2, lanes=LANES, chunk_size=8,
                          max_len=16)
        rids = [eng.submit_compress(t, arrival=float(a))
                for t, a in zip(toks, arrivals)]
        res = eng.run(clock="virtual")
        return eng.admission_log, [res[r].blob for r in rids]

    log1, blobs1 = run_once()
    log2, blobs2 = run_once()
    assert log1 == log2
    assert blobs1 == blobs2
    for t, b in zip(toks, blobs1):
        assert b == _ref_blob(params, t, 8)


def test_overflow_isolation(params):
    """A request whose byte budget overflows dies with a named error;
    the co-batched neighbor's output is untouched, byte for byte."""
    t_small_cap, t_ok = _tokens(16, 30), _tokens(16, 31)
    eng = BatchEngine(params, CFG, slots=2, lanes=LANES, chunk_size=8,
                      max_len=16)
    r_bad = eng.submit_compress(t_small_cap, cap=5)
    r_ok = eng.submit_compress(t_ok)
    res = eng.run()
    assert not res[r_bad].ok
    assert isinstance(res[r_bad].error, RequestOverflowError)
    assert "cap=5" in str(res[r_bad].error)
    assert res[r_ok].ok
    assert res[r_ok].blob == _ref_blob(params, t_ok, 8)


def test_queue_full_rejects_at_the_door(params):
    eng = BatchEngine(params, CFG, slots=1, lanes=LANES, chunk_size=8,
                      max_len=16, max_queue=1)
    eng.submit_compress(_tokens(8, 40))
    with pytest.raises(EngineQueueFullError):
        eng.submit_compress(_tokens(8, 41))


def test_kernel_step_backend_parity(params):
    """The fused Pallas decode step and the pure-XLA coder step are the
    same codec: identical blobs from the same engine workload."""
    toks = _tokens(12, 50)
    blobs = {}
    for backend in ("coder", "kernel"):
        eng = BatchEngine(params, CFG, slots=1, lanes=LANES, chunk_size=8,
                          max_len=16, step_backend=backend)
        rid = eng.submit_compress(toks)
        res = eng.run()
        assert res[rid].ok, res[rid].error
        blobs[backend] = res[rid].blob
    assert blobs["coder"] == blobs["kernel"]
    assert blobs["coder"] == _ref_blob(params, toks, 8)


def test_lane_mesh_parity(params):
    """shard_map over the ("lanes",) mesh changes placement, not bytes."""
    from repro.parallel.chunked import lane_mesh
    toks = _tokens(12, 51)
    eng = BatchEngine(params, CFG, slots=1, lanes=LANES, chunk_size=8,
                      max_len=16, mesh=lane_mesh())
    rid = eng.submit_compress(toks)
    res = eng.run()
    assert res[rid].ok, res[rid].error
    assert res[rid].blob == _ref_blob(params, toks, 8)


def test_prefill_fast_path_byte_identity(params):
    """Compress-only cycles take the batched prefill program (teacher-
    forced inputs are known up front) and every blob still equals both the
    ``prefill="off"`` step path and the single-request reference."""
    toks = [_tokens(20, 70), _tokens(16, 71), _tokens(9, 72)]
    blobs, pf = {}, {}
    for mode in ("auto", "off"):
        eng = BatchEngine(params, CFG, slots=2, lanes=LANES, chunk_size=8,
                          max_len=32, prefill=mode)
        rids = [eng.submit_compress(t) for t in toks]
        res = eng.run()
        for rid in rids:
            assert res[rid].ok, res[rid].error
        blobs[mode] = [res[r].blob for r in rids]
        pf[mode] = eng.prefill_cycles
    assert pf["auto"] > 0 and pf["off"] == 0
    assert blobs["auto"] == blobs["off"]
    for t, b in zip(toks, blobs["auto"]):
        assert b == _ref_blob(params, t, 8)


def test_prefill_steps_down_for_wrap_and_decode(params):
    """Wrapped streams and decompress rows feed back step to step, so the
    scheduler must dispatch the sequential program for those cycles:
    ``prefill_cycles`` stays 0 and the outputs stay exact."""
    toks = _tokens(24, 73)
    eng = BatchEngine(params, CFG, slots=1, lanes=LANES, chunk_size=8,
                      max_len=16, prefill="auto")
    rid = eng.submit_compress(toks, allow_wrap=True)
    res = eng.run()
    assert res[rid].ok, res[rid].error
    assert eng.prefill_cycles == 0

    t_b = _tokens(12, 74)
    blob = _ref_blob(params, t_b, 8)
    eng2 = BatchEngine(params, CFG, slots=1, lanes=LANES, chunk_size=8,
                       max_len=16, prefill="auto")
    rd = eng2.submit_decompress(blob)
    res2 = eng2.run()
    assert res2[rd].ok, res2[rd].error
    assert eng2.prefill_cycles == 0
    np.testing.assert_array_equal(res2[rd].tokens, t_b)


def test_wrap_rejected_then_allowed_roundtrip(params):
    """seq > max_len is refused with a named error by default; with
    allow_wrap=True the stream conditions on the ring window and a second
    engine at the same geometry round-trips it exactly."""
    toks = _tokens(24, 60)
    eng = BatchEngine(params, CFG, slots=1, lanes=LANES, chunk_size=8,
                      max_len=16)
    with pytest.raises(ValueError, match="allow_wrap"):
        eng.submit_compress(toks)
    rid = eng.submit_compress(toks, allow_wrap=True)
    res = eng.run()
    assert res[rid].ok, res[rid].error
    eng2 = BatchEngine(params, CFG, slots=1, lanes=LANES, chunk_size=8,
                       max_len=16)
    rid2 = eng2.submit_decompress(res[rid].blob, allow_wrap=True)
    res2 = eng2.run()
    assert res2[rid2].ok, res2[rid2].error
    np.testing.assert_array_equal(res2[rid2].tokens, toks)
