"""Zero-copy container decode + banked-ring encode (DESIGN.md §10).

Four contracts of the fused-container PR, pinned:

  * **golden-corpus parity** — decoding every frozen golden container
    ZERO-COPY from the packed slab (``parse_chunked`` ->
    ``from_container``) returns symbols and per-lane probe counters
    identical to the host-unpack dense reference, across v1/v2,
    CRC/no-CRC, static/per-position/per-lane tables, ragged and aligned
    chunking;
  * **the host copy is off the hot path** — with the host right-align
    gather poisoned to raise, the zero-copy kernel path, the threaded
    ``parallel.chunked`` path, and the container-fed serve decodes all
    still run (and the host reference demonstrably trips the poison);
  * **banked-ring identity** — the ring scatter is byte-identical to the
    one-hot scatter it replaced AND to the records reference, across
    table families x chunking x caps including the degenerate cap < 4
    (position-exact overflow/drop semantics);
  * **autotuner model** — the VMEM occupancy model shares one machine
    constant with ``analysis.roofline`` and its selections always fit the
    budget.
"""

import importlib.util
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream, coder, spc
from repro.kernels import ops

jax.config.update("jax_platforms", "cpu")

_GEN_PATH = os.path.join(os.path.dirname(__file__), "golden_vectors",
                         "generate.py")
_spec = importlib.util.spec_from_file_location("golden_generate_zc",
                                               _GEN_PATH)
golden = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(golden)

_IDS = [c["name"] for c in golden.CASES]


def _stored(case):
    with open(golden.blob_path(case), "rb") as f:
        return f.read()


# ---------------------------------------------------------------------------
# golden-corpus zero-copy parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("case", golden.CASES, ids=_IDS)
def test_golden_container_zero_copy_parity(case):
    """from_container(stored bytes) == host-unpack dense reference, symbols
    and per-lane probes, on every golden case (v1 included: it parses as a
    single-chunk slab)."""
    tbl, syms = golden.build_case(case)
    blob = _stored(case)
    cs = bitstream.parse_chunked(blob)
    t = case["t"]
    chunk = case["chunk_size"] if case["fmt"] == "v2" else t

    buf, start, meta = bitstream.unpack_chunked(blob)
    ch = coder.ChunkedLanes(jnp.asarray(buf), jnp.asarray(start),
                            jnp.asarray(buf.shape[2] - start))
    ref, _, lp_ref = ops.rans_decode_chunked(ch, t, tbl, chunk,
                                             lane_probes=True)
    got, _, lp_got = ops.rans_decode_chunked(
        n_symbols=t, tbl=tbl, chunk_size=chunk, lane_probes=True,
        from_container=cs)
    np.testing.assert_array_equal(np.asarray(got), syms)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(lp_got), np.asarray(lp_ref))


def test_zero_copy_with_candidates_and_t_block():
    """Speculative candidates and explicit T-blocking ride the zero-copy
    path unchanged (probe accounting identical to the dense kernel)."""
    rng = np.random.default_rng(7)
    k, lanes, t, chunk = 32, 4, 50, 13
    probs = rng.dirichlet(np.full(k, 0.5), size=t).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    topk = 4
    cands = jnp.asarray(rng.integers(0, k, (t, lanes, topk)), jnp.int32)
    ch = coder.encode_chunked(syms, tbl, chunk)
    blob = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=chunk,
                                  n_symbols=t)
    cs = bitstream.parse_chunked(blob)
    ref, _, lp_ref = ops.rans_decode_chunked(ch, t, tbl, chunk,
                                             candidates=cands, t_block=5,
                                             lane_probes=True)
    got, _, lp_got = ops.rans_decode_chunked(
        n_symbols=t, tbl=tbl, chunk_size=chunk, candidates=cands,
        t_block=5, lane_probes=True, from_container=cs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(syms))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(lp_got), np.asarray(lp_ref))


# ---------------------------------------------------------------------------
# the host right-align copy never runs on the zero-copy hot paths
# ---------------------------------------------------------------------------

def _poison(monkeypatch):
    def boom(*a, **k):
        raise AssertionError(
            "host right-align copy ran on a zero-copy hot path")
    monkeypatch.setattr(bitstream, "_right_align_cells", boom)


def test_poisoned_host_copy_trips_the_reference(monkeypatch):
    """Positive control: the poison is real — the host unpack paths die."""
    case = golden.CASES[1]
    blob = _stored(case)
    _poison(monkeypatch)
    with pytest.raises(AssertionError, match="zero-copy hot path"):
        bitstream.unpack_chunked(blob)
    with pytest.raises(AssertionError, match="zero-copy hot path"):
        bitstream.unpack(_stored(golden.CASES[0]))


def test_zero_copy_kernel_paths_never_touch_host_copy(monkeypatch):
    """With the host gather poisoned: parse_chunked + from_container and
    the threaded parallel path still decode correctly."""
    from repro.parallel import chunked as par
    case = golden.CASES[1]
    tbl, syms = golden.build_case(case)
    blob = _stored(case)
    t, chunk = case["t"], case["chunk_size"]
    _poison(monkeypatch)
    cs = bitstream.parse_chunked(blob)
    got, _ = ops.rans_decode_chunked(n_symbols=t, tbl=tbl, chunk_size=chunk,
                                     from_container=cs)
    np.testing.assert_array_equal(np.asarray(got), syms)
    got2, _ = par.decode_chunked(cs, t, tbl, chunk, backend="kernel")
    np.testing.assert_array_equal(np.asarray(got2), syms)
    # coder backend threads through the device-side gather, not the host
    got3, _ = par.decode_chunked(cs, t, tbl, chunk, backend="coder")
    np.testing.assert_array_equal(np.asarray(got3), syms)


def test_serve_container_paths_never_touch_host_copy(monkeypatch):
    """Container-fed serve decodes (fused per-chunk windows and the
    two-pass zero-copy replay) run with the host gather poisoned and
    return the original tokens."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import token_stream
    from repro.models import init_model
    from repro.serve.compress import (lm_compress_chunked,
                                      lm_decompress_chunked)
    cfg = get_smoke_config("ras-pimc")
    params = init_model(cfg, jax.random.PRNGKey(2))
    toks = jnp.asarray(token_stream(cfg.vocab_size, (2, 26), seed=21),
                       jnp.int32)
    st = lm_compress_chunked(params, cfg, toks, chunk_size=13,
                             backend="kernel")
    blob = bitstream.pack_chunked(*map(np.asarray, st.chunks),
                                  chunk_size=13, n_symbols=26)
    _poison(monkeypatch)
    cs = bitstream.parse_chunked(blob)
    for backend in ("kernel", "two_pass"):
        dec, _ = lm_decompress_chunked(params, cfg, cs, 26, 13,
                                       backend=backend)
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks),
                                      backend)


# ---------------------------------------------------------------------------
# banked-ring scatter identity (incl. cap < 4 overflow parity)
# ---------------------------------------------------------------------------

def _family(kind, rng, k, lanes, t):
    if kind == "static":
        probs = rng.dirichlet(np.full(k, 0.5))
    elif kind == "perpos":
        probs = rng.dirichlet(np.full(k, 0.5), size=t)
    else:
        probs = rng.dirichlet(np.full(k, 0.5), size=(t, lanes))
    tbl = spc.tables_from_probs(jnp.asarray(probs.astype(np.float32)))
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    return tbl, syms


@pytest.mark.parametrize("kind", ["static", "perpos", "perlane"])
@pytest.mark.parametrize("chunk", [None, 13])
def test_ring_scatter_byte_identical(kind, chunk):
    """ring == onehot == pure-JAX coder on every table family x chunking,
    with and without explicit T-blocking."""
    rng = np.random.default_rng(60)
    k, lanes, t = 16, 4, 48
    tbl, syms = _family(kind, rng, k, lanes, t)
    if chunk is None:
        want = coder.encode(syms, tbl)
        ring = ops.rans_encode(syms, tbl)
        onehot = ops.rans_encode(syms, tbl, scatter="onehot")
        ring_tb = ops.rans_encode(syms, tbl, t_block=5)
    else:
        want = coder.encode_chunked(syms, tbl, chunk)
        ring = ops.rans_encode_chunked(syms, tbl, chunk)
        onehot = ops.rans_encode_chunked(syms, tbl, chunk, scatter="onehot")
        ring_tb = ops.rans_encode_chunked(syms, tbl, chunk, t_block=5)
    for a, b, c, d in zip(want, ring, onehot, ring_tb):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(d))


@pytest.mark.parametrize("cap", [1, 3, 7])
def test_ring_overflow_parity_tiny_caps(cap):
    """Under-provisioned caps (including cap < 4, where even the state
    header cannot fit): truncated cells carry position-exact bytes and
    identical overflow flags on ring, one-hot and coder paths — negative
    ring cursors drop exactly like negative one-hot rows."""
    rng = np.random.default_rng(61)
    k, lanes, t, chunk = 16, 4, 48, 13
    tbl, syms = _family("static", rng, k, lanes, t)
    want = coder.encode_chunked(syms, tbl, chunk, cap=cap)
    ring = ops.rans_encode_chunked(syms, tbl, chunk, cap=cap)
    onehot = ops.rans_encode_chunked(syms, tbl, chunk, cap=cap,
                                     scatter="onehot")
    assert bool(np.asarray(want.overflow).any())   # caps genuinely tight
    for a, b, c in zip(want, ring, onehot):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_unknown_scatter_rejected():
    rng = np.random.default_rng(62)
    tbl, syms = _family("static", rng, 16, 4, 8)
    with pytest.raises(ValueError, match="scatter"):
        ops.rans_encode(syms, tbl, scatter="nope")


# ---------------------------------------------------------------------------
# autotuner model
# ---------------------------------------------------------------------------

def test_autotuner_and_roofline_share_the_machine_model():
    from repro.analysis import roofline
    from repro.kernels import autotune
    assert roofline.VMEM_BYTES is autotune.VMEM_BYTES
    assert autotune.VMEM_BUDGET <= autotune.VMEM_BYTES


def test_ring_size_covers_worst_case_emission():
    """ring(t_block) must cover the worst-case bytes of one grid step —
    MAX_RENORM_STEPS per symbol plus the 4-byte header — and stay a power
    of two within 2x of that bound."""
    from repro.core import constants as C
    from repro.kernels.autotune import ring_size
    for tb in (1, 5, 8, 13, 16, 48, 128, 512):
        need = C.MAX_RENORM_STEPS * tb + 4
        r = ring_size(tb)
        assert r >= need and r < 2 * need
        assert r & (r - 1) == 0


@pytest.mark.parametrize("layout,k", [("static", 256), ("perpos", 64),
                                      ("lane", 32)])
def test_selected_blocks_fit_the_vmem_budget(layout, k):
    from repro.kernels import autotune as at
    for chunk in (13, 48, 128, 1024):
        cap = coder.default_cap(chunk)
        tb = at.select_encode_t_block(chunk, cap, 128, k, layout)
        assert 1 <= tb <= chunk
        assert at.encode_vmem_bytes(tb, 128, k, layout, cap,
                                    ring=at.ring_size(tb)) <= at.VMEM_BUDGET
        dtb = at.select_decode_t_block(chunk, cap, 128, k, layout, topk=4)
        assert 1 <= dtb <= chunk
        if dtb < chunk:     # only blocked when the full chunk didn't fit
            assert at.decode_vmem_bytes(chunk, 128, k, layout, cap,
                                        topk=4) > at.VMEM_BUDGET


# ---------------------------------------------------------------------------
# vectorized right-align micro-assert (RAS_BITSTREAM_SELFTEST)
# ---------------------------------------------------------------------------

def test_right_align_vectorized_equals_loop_oracle(monkeypatch):
    """The one-gather right-align equals the per-cell loop oracle on both
    branches — checked directly and via the env-gated in-function
    self-assert."""
    rng = np.random.default_rng(63)
    cap, cells = 9, 12
    length = rng.integers(0, cap + 1, size=cells).astype(np.int64)
    starts = np.array([rng.integers(0, cap - ln + 1) for ln in length],
                      np.int64)
    buf = rng.integers(0, 256, size=(cells, cap)).astype(np.uint8)
    payload = np.concatenate(
        [buf[i, s:s + ln] for i, (s, ln) in enumerate(zip(starts, length))])
    offsets = np.concatenate([[0], np.cumsum(length)[:-1]])
    fast = bitstream._right_align_cells(payload, offsets.reshape(1, -1),
                                        length.reshape(1, -1), cap)
    slow = bitstream._right_align_cells_loop(payload, offsets.reshape(1, -1),
                                             length.reshape(1, -1), cap)
    np.testing.assert_array_equal(fast, slow)
    for i, (s, ln) in enumerate(zip(starts, length)):
        np.testing.assert_array_equal(fast[0, i, cap - ln:],
                                      buf[i, s:s + ln])

    monkeypatch.setenv("RAS_BITSTREAM_SELFTEST", "1")
    case = golden.CASES[1]
    tbl, syms = golden.build_case(case)
    buf2, start2, meta = bitstream.unpack_chunked(_stored(case))
    ch = coder.ChunkedLanes(jnp.asarray(buf2), jnp.asarray(start2),
                            jnp.asarray(buf2.shape[2] - start2))
    got, _ = coder.decode_chunked(ch, case["t"], tbl, case["chunk_size"])
    np.testing.assert_array_equal(np.asarray(got), syms)
