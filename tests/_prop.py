"""Tiny vendored property-test substrate (replaces the hypothesis dep).

The hermetic test environment has no ``hypothesis``; the four properties it
used to drive are rewritten as deterministic seeded sweeps.  ``sweep``
yields independently-seeded ``numpy.random.Generator`` draws derived from
one root seed, so every run (and every CI machine) sees the identical case
list — shrinking is traded for reproducibility, coverage counts stay the
same as the old ``max_examples`` settings.
"""

from __future__ import annotations

from typing import Iterator

import numpy as np


def sweep(seed: int, n: int) -> Iterator[np.random.Generator]:
    """Yield ``n`` deterministic, independently-seeded Generators.

    Each draw gets its own child Generator (spawned off the root seed) so
    inserting or reordering draws inside one case never perturbs the
    others — the property hypothesis's per-example RNG gave us.
    """
    root = np.random.SeedSequence(seed)
    for child in root.spawn(n):
        yield np.random.default_rng(child)


def ints(rng: np.random.Generator, lo: int, hi: int, size=None):
    """Inclusive-bounds integer draw (st.integers(lo, hi) semantics)."""
    return rng.integers(lo, hi, size=size, endpoint=True)


def floats(rng: np.random.Generator, lo: float, hi: float, size=None):
    """Uniform float draw on [lo, hi] (st.floats(lo, hi) semantics)."""
    return lo + (hi - lo) * rng.random(size)


def seeds(seed: int, n: int) -> list[int]:
    """n deterministic 31-bit seeds — for parametrizing whole test cases."""
    return [int(ints(rng, 0, 2**31 - 1)) for rng in sweep(seed, n)]
