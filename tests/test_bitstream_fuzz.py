"""Container corruption fuzz tier (ISSUE 4 satellite; ``-m fuzz``).

Seeded adversarial inputs against ``bitstream.unpack`` / ``unpack_chunked``
(via :mod:`tests._prop`'s deterministic sweeps — every run and every CI
machine sees the identical case list).  Contract under corruption:

  * **never crash** — any truncation or byte flip raises ``ValueError``
    (named region/cell), never a raw ``struct.error``/numpy error, never a
    segfault-shaped surprise from a bogus allocation;
  * **never silently mis-decode what integrity covers** — with
    ``FLAG_CHUNK_CRC32`` every payload byte is inside some cell's CRC, so
    every payload flip MUST raise and MUST name the damaged (chunk, lane);
    a deliberately wrong CRC cell in the index must be caught the same way;
  * truncations at every boundary (header, length/index table, payload)
    raise errors naming the truncated region.

Checksum-less v2 and v1 payloads carry no integrity bits — flips there may
legally "succeed"; the assertion for them is only the no-crash contract
(the docs call out the tradeoff; writers default to checksums on).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from _prop import ints, sweep
from repro.core import bitstream, coder, spc

jax.config.update("jax_platforms", "cpu")

pytestmark = pytest.mark.fuzz


def _make_corpus():
    rng = np.random.default_rng(90)
    k, lanes, t, chunk = 32, 4, 48, 13
    tbl = spc.tables_from_probs(
        jnp.asarray(rng.dirichlet(np.full(k, 0.5)), jnp.float32))
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    enc = coder.encode(syms, tbl)
    ch = coder.encode_chunked(syms, tbl, chunk)
    v1 = bitstream.pack(*map(np.asarray, enc), n_symbols=t)
    v2c = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=chunk,
                                 n_symbols=t, checksums=True)
    v2n = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=chunk,
                                 n_symbols=t, checksums=False)
    return {"blobs": {"v1": v1, "v2_crc": v2c, "v2_nocrc": v2n},
            "tbl": tbl, "syms": syms, "t": t, "chunk": chunk}


@pytest.fixture(scope="module")
def corpus():
    return _make_corpus()


@pytest.fixture(scope="module")
def blobs(corpus):
    return corpus["blobs"]


def _reader(name):
    return bitstream.unpack if name == "v1" else bitstream.unpack_chunked


def _must_only_value_error(read, blob):
    """The no-crash contract: success or ValueError, nothing else."""
    try:
        read(blob)
        return None
    except ValueError as e:
        return e
    # any other exception type propagates and fails the test


# ---------------------------------------------------------------------------
# truncations: every prefix must raise a named error, never crash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["v1", "v2_crc", "v2_nocrc"])
def test_truncation_fuzz(blobs, name):
    blob, read = blobs[name], _reader(name)
    cuts = {0, 1, 3, 4, 7, len(blob) - 1}
    for rng in sweep(91, 40):
        cuts.add(int(ints(rng, 0, len(blob) - 1)))
    for cut in sorted(cuts):
        with pytest.raises(ValueError,
                           match="truncated|not a RAS|unsupported"):
            read(blob[:cut])


def test_truncation_errors_name_the_region(blobs):
    blob = blobs["v2_crc"]
    with pytest.raises(ValueError, match="header"):
        bitstream.unpack_chunked(blob[:10])
    with pytest.raises(ValueError, match="chunk index"):
        bitstream.unpack_chunked(blob[:bitstream._HEADER_V2.size + 5])
    with pytest.raises(ValueError, match=r"chunk \d+, lane \d+"):
        bitstream.unpack_chunked(blob[:len(blob) - 3])
    v1 = blobs["v1"]
    with pytest.raises(ValueError, match="lane"):
        bitstream.unpack(v1[:len(v1) - 3])


# ---------------------------------------------------------------------------
# byte flips: no-crash everywhere; CRC'd payloads must be caught by cell
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["v1", "v2_crc", "v2_nocrc"])
def test_header_and_body_flip_fuzz(blobs, name):
    """Flip one byte anywhere (header, index/length table, payload): the
    reader either parses or raises ValueError — no other exception type."""
    blob, read = blobs[name], _reader(name)
    for rng in sweep(92, 120):
        pos = int(ints(rng, 0, len(blob) - 1))
        bit = int(ints(rng, 0, 7))
        mut = bytearray(blob)
        mut[pos] ^= 1 << bit
        _must_only_value_error(read, bytes(mut))


def test_payload_flip_always_caught_with_checksums(blobs):
    """FLAG_CHUNK_CRC32: every payload byte is inside some cell's CRC, so
    every payload flip raises AND names the damaged (chunk, lane)."""
    blob = blobs["v2_crc"]
    _, _, meta = bitstream.unpack_chunked(blob)
    cells = meta.n_chunks * meta.lanes
    base = (bitstream._HEADER_V2.size
            + cells * bitstream._INDEX_V2C_DT.itemsize)
    positions = {base, len(blob) - 1}
    for rng in sweep(93, 60):
        positions.add(int(ints(rng, base, len(blob) - 1)))
    for pos in sorted(positions):
        mut = bytearray(blob)
        mut[pos] ^= 1 << int(pos % 8)
        with pytest.raises(ValueError, match=r"chunk \d+, lane \d+"):
            bitstream.unpack_chunked(bytes(mut))


def test_wrong_crc_cell_is_named(blobs):
    """Overwrite stored CRC cells with wrong values: the reader names the
    exact (chunk, lane) of every tampered cell."""
    blob = blobs["v2_crc"]
    _, _, meta = bitstream.unpack_chunked(blob)
    rec = bitstream._INDEX_V2C_DT.itemsize
    for rng in sweep(94, 12):
        cell = int(ints(rng, 0, meta.n_chunks * meta.lanes - 1))
        c, lane = divmod(cell, meta.lanes)
        off = bitstream._HEADER_V2.size + cell * rec + 12  # crc field
        mut = bytearray(blob)
        mut[off:off + 4] = bytes(x ^ 0x5A for x in mut[off:off + 4])
        with pytest.raises(ValueError,
                           match=rf"chunk {c}, lane {lane}"):
            bitstream.unpack_chunked(bytes(mut))


def test_uncorrupted_blobs_still_unpack(blobs):
    """Sanity: the fuzz fixtures themselves are healthy."""
    buf, start, meta = bitstream.unpack(blobs["v1"])
    assert meta.lanes == buf.shape[0]
    for name in ("v2_crc", "v2_nocrc"):
        buf, start, meta = bitstream.unpack_chunked(blobs[name])
        assert buf.shape[:2] == (meta.n_chunks, meta.lanes)


def test_index_offset_wrap_is_named(blobs):
    """Flip the HIGH byte of an index cell's u64 offset: the value must be
    rejected as an unsigned out-of-bounds offset, not cast to int64 (where
    it wraps negative, slips past a signed span check, and either crashes
    the payload gather with a raw IndexError or silently reads the wrong
    bytes)."""
    blob = blobs["v2_nocrc"]
    rec = bitstream._INDEX_V2_DT.itemsize
    for cell in (0, 2):
        off = bitstream._HEADER_V2.size + cell * rec + 7  # offset MSB
        for bit in (0, 7):
            mut = bytearray(blob)
            mut[off] ^= 1 << bit
            with pytest.raises(ValueError, match=r"chunk \d+, lane \d+"):
                bitstream.unpack_chunked(bytes(mut))


def test_overlapping_spans_refuse_giant_allocation(blobs):
    """A crafted index whose cells all alias the full payload (individually
    in bounds, collectively absurd) must be refused before the dense
    (n_chunks, lanes, cap) buffer is allocated."""
    blob = blobs["v2_nocrc"]
    _, _, meta = bitstream.unpack_chunked(blob)
    rec = bitstream._INDEX_V2_DT.itemsize
    cells = meta.n_chunks * meta.lanes
    base = bitstream._HEADER_V2.size + cells * rec
    payload_len = len(blob) - base
    mut = bytearray(blob)
    for cell in range(cells):   # every cell: offset 0, length = payload
        off = bitstream._HEADER_V2.size + cell * rec
        mut[off:off + 8] = (0).to_bytes(8, "little")
        mut[off + 8:off + 12] = payload_len.to_bytes(4, "little")
    with pytest.raises(ValueError, match="overlapping|inflated"):
        bitstream.unpack_chunked(bytes(mut))


def test_index_length_inflation_is_bounded(blobs):
    """Inflate one index length field: the reader must refuse with a named
    span error before trusting it (no giant allocation, no wrap)."""
    blob = blobs["v2_nocrc"]
    rec = bitstream._INDEX_V2_DT.itemsize
    for cell in (0, 3):
        off = bitstream._HEADER_V2.size + cell * rec + 8  # length field
        mut = bytearray(blob)
        mut[off:off + 4] = (0xFFFFFFF0).to_bytes(4, "little")
        with pytest.raises(ValueError, match=r"chunk \d+, lane \d+"):
            bitstream.unpack_chunked(bytes(mut))


# ---------------------------------------------------------------------------
# kernel-backend column: the zero-copy decode front door
# (``parse_chunked`` -> ``from_container``) under the same corruptions —
# mutated blobs surface the SAME named ValueErrors as the host reader, and
# a hostile index can never make the kernel read out of the slab
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["v1", "v2_crc", "v2_nocrc"])
def test_kernel_front_door_truncation_fuzz(blobs, name):
    """Every truncation the host reader rejects, ``parse_chunked`` rejects
    with the identical named error (shared validation, one source)."""
    blob, read = blobs[name], _reader(name)
    cuts = {0, 1, 3, 4, 7, len(blob) - 1}
    for rng in sweep(95, 25):
        cuts.add(int(ints(rng, 0, len(blob) - 1)))
    for cut in sorted(cuts):
        host = _must_only_value_error(read, blob[:cut])
        kern = _must_only_value_error(bitstream.parse_chunked, blob[:cut])
        assert host is not None and kern is not None, cut
        assert str(host) == str(kern), cut


@pytest.mark.parametrize("name", ["v1", "v2_crc", "v2_nocrc"])
def test_kernel_front_door_flip_fuzz(corpus, name):
    """One-byte flips: ``parse_chunked`` accepts/rejects exactly when the
    host reader does, raising the identical named ValueError on reject; on
    every accepted mutant the kernel decode from the packed slab returns
    the same symbols as the coder decode of the host-unpacked dense stream
    — garbage in equals garbage out, NEVER an out-of-bounds read."""
    from repro.kernels import ops
    blob, read = corpus["blobs"][name], _reader(name)
    tbl, t, chunk = corpus["tbl"], corpus["t"], corpus["chunk"]
    checked = 0
    for rng in sweep(96, 80):
        pos = int(ints(rng, 0, len(blob) - 1))
        bit = int(ints(rng, 0, 7))
        mut = bytearray(blob)
        mut[pos] ^= 1 << bit
        mut = bytes(mut)
        host = _must_only_value_error(read, mut)
        kern = _must_only_value_error(bitstream.parse_chunked, mut)
        assert (host is None) == (kern is None), (pos, bit)
        if host is not None:
            assert str(host) == str(kern), (pos, bit)
        elif checked < 4 and name != "v1":
            cs = bitstream.parse_chunked(mut)
            dense = bitstream.slab_to_chunked(cs)
            csym, _ = coder.decode_chunked(dense, t, tbl, chunk)
            ksym, _ = ops.rans_decode_chunked(
                n_symbols=t, tbl=tbl, chunk_size=chunk, from_container=cs)
            assert np.array_equal(np.asarray(csym), np.asarray(ksym)), (
                pos, bit)
            checked += 1
    if name != "v1":
        assert checked > 0, "sweep produced no accepted mutants to decode"


def test_kernel_span_clamp_never_reads_out_of_slab(corpus):
    """Defense in depth behind ``parse_chunked``: a ContainerSlab whose
    index was poisoned AFTER validation (offsets past the payload end,
    lengths past the window) must still decode without an OOB access
    (which interpret mode would raise on) — the host-side base clip plus
    the in-kernel span clamp turn every hostile (offset, length) into
    in-bounds reads of zero-padded windows.  Since the over-read bugfix
    the hostile windows are also *detectable*: the zero-injected refills
    raise the per-lane underflow counters, surfaced via
    ``exhausted_flags=True`` (the host entry would raise the named
    ``StreamExhaustedError`` instead of returning garbage)."""
    from repro.core.coder import StreamExhaustedError
    from repro.kernels import ops
    cs = bitstream.parse_chunked(corpus["blobs"]["v2_nocrc"])
    tbl, t, chunk = corpus["tbl"], corpus["t"], corpus["chunk"]
    s = cs.slab.shape[0]
    poisons = {
        "offset_past_end": cs._replace(
            offset=np.full_like(cs.offset, s + 1000)),
        "length_past_window": cs._replace(
            length=np.full_like(cs.length, cs.cap + 7)),
        "both_hostile": cs._replace(
            offset=np.full_like(cs.offset, s - 1),
            length=np.full_like(cs.length, cs.cap + 3)),
    }
    for name, bad in poisons.items():
        sym, _, under = ops.rans_decode_chunked(
            n_symbols=t, tbl=tbl, chunk_size=chunk, from_container=bad,
            exhausted_flags=True)
        assert np.asarray(sym).shape == corpus["syms"].shape, name
    # the fully-hostile offsets are not just clamped but FLAGGED — and the
    # raising host entry turns them into the named error
    with pytest.raises(StreamExhaustedError):
        ops.rans_decode_chunked(n_symbols=t, tbl=tbl, chunk_size=chunk,
                                from_container=poisons["offset_past_end"])
