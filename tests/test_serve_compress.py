"""End-to-end LM-driven compression (the paper's full pipeline) + serving."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import bitstream
from repro.data.pipeline import image_rows, synthetic_image, token_stream
from repro.models import init_model
from repro.serve.compress import (histogram_compress, histogram_decompress,
                                  lm_compress, lm_decompress)
from repro.serve.engine import generate, prefill

jax.config.update("jax_platforms", "cpu")

CFG = get_smoke_config("ras-pimc")
KEY = jax.random.PRNGKey(1)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, KEY)


def test_lm_compress_roundtrip_bit_exact(params):
    toks = jnp.asarray(token_stream(CFG.vocab_size, (4, 64), seed=3),
                       jnp.int32)
    stats = lm_compress(params, CFG, toks)
    dec, probes = lm_decompress(params, CFG, stats.enc, 64)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks))
    assert float(probes) > 0


def test_lm_compress_kernel_backend_bit_exact(params):
    """backend="kernel" feeds the teacher-forced (T, lanes, K) tables
    straight into the Pallas encode kernel: bytes identical to the coder
    backend, and the stream round-trips through lm_decompress."""
    toks = jnp.asarray(token_stream(CFG.vocab_size, (4, 48), seed=13),
                       jnp.int32)
    a = lm_compress(params, CFG, toks)
    b = lm_compress(params, CFG, toks, backend="kernel")
    for x, y in zip(a.enc, b.enc):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    dec, _ = lm_decompress(params, CFG, b.enc, 48)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks))
    with pytest.raises(ValueError, match="backend"):
        lm_compress(params, CFG, toks, backend="nope")


def test_lm_compress_chunked_kernel_backend_bit_exact(params):
    """The chunked serve path through the kernel's chunk grid axis."""
    from repro.serve.compress import (lm_compress_chunked,
                                      lm_decompress_chunked)
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 40), seed=14),
                       jnp.int32)
    a = lm_compress_chunked(params, CFG, toks, chunk_size=16)
    b = lm_compress_chunked(params, CFG, toks, chunk_size=16,
                            backend="kernel")
    for x, y in zip(a.chunks, b.chunks):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    dec, _ = lm_decompress_chunked(params, CFG, b.chunks, 40, 16)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks))


def test_lm_decompress_kernel_backend_bit_exact(params):
    """The FUSED serve decode: lm_decompress(backend="kernel") — one traced
    program of model step + SPC decode fast path + per-step Pallas kernel —
    round-trips lm_compress(backend="kernel") bit-exactly, with per-lane
    probe counters integer-identical to backend="coder" (all backends
    consume core.search, so the model-top-k candidate planes charge the
    canonical Fig. 4(b) accounting in-kernel)."""
    from repro.serve.compress import lm_decompress
    toks = jnp.asarray(token_stream(CFG.vocab_size, (4, 40), seed=15),
                       jnp.int32)
    stats = lm_compress(params, CFG, toks, backend="kernel")
    dc, ac, lc = lm_decompress(params, CFG, stats.enc, 40,
                               backend="coder", lane_probes=True)
    dk, ak, lk = lm_decompress(params, CFG, stats.enc, 40,
                               backend="kernel", lane_probes=True)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(dc), np.asarray(dk))
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lk))
    assert abs(float(ac) - float(ak)) < 1e-5
    # speculation pays off: the model's own top-k resolves most symbols in
    # ~1 probe, far under the log2(vocab) baseline
    assert float(ak) < np.ceil(np.log2(CFG.vocab_size))
    with pytest.raises(ValueError, match="backend"):
        lm_decompress(params, CFG, stats.enc, 40, backend="nope")


def test_lm_decompress_chunked_kernel_backend_bit_exact(params):
    """Chunked FUSED serve decode: one fused program per chunk with the
    model cache and token carried across chunk boundaries — must match the
    sequential coder path symbol-for-symbol and probe-for-probe, ragged
    tail included."""
    from repro.serve.compress import (lm_compress_chunked,
                                      lm_decompress_chunked)
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 40), seed=16),
                       jnp.int32)
    st = lm_compress_chunked(params, CFG, toks, chunk_size=16,
                             backend="kernel")   # ragged tail of 8
    dc, ac, lc = lm_decompress_chunked(params, CFG, st.chunks, 40, 16,
                                       backend="coder", lane_probes=True)
    dk, ak, lk = lm_decompress_chunked(params, CFG, st.chunks, 40, 16,
                                       backend="kernel", lane_probes=True)
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(dc), np.asarray(dk))
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lk))
    assert abs(float(ac) - float(ak)) < 1e-5
    with pytest.raises(ValueError, match="backend"):
        lm_decompress_chunked(params, CFG, st.chunks, 40, 16,
                              backend="nope")


@pytest.mark.slow
def test_lm_decompress_chunked_on_mesh(params):
    """Mesh placement of both kernel decode flavours: backend="two_pass"
    puts pass 2 on the ("chunks",) mesh via parallel.chunked.decode_chunked
    (candidate planes shard with the chunk slab); backend="kernel" (fused)
    shards its independent lane axis on a ("lanes",) mesh.  Symbols and
    probe averages match the no-mesh paths; mis-matched mesh kinds raise."""
    from repro.parallel.chunked import chunk_mesh, lane_mesh
    from repro.serve.compress import (lm_compress_chunked,
                                      lm_decompress_chunked)
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 32), seed=17),
                       jnp.int32)
    st = lm_compress_chunked(params, CFG, toks, chunk_size=16,
                             backend="kernel")   # 2 aligned chunks
    d0, a0 = lm_decompress_chunked(params, CFG, st.chunks, 32, 16,
                                   backend="two_pass")
    dm, am = lm_decompress_chunked(params, CFG, st.chunks, 32, 16,
                                   backend="two_pass", mesh=chunk_mesh())
    np.testing.assert_array_equal(np.asarray(dm), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(d0), np.asarray(dm))
    assert abs(float(a0) - float(am)) < 1e-5
    df, af = lm_decompress_chunked(params, CFG, st.chunks, 32, 16,
                                   backend="kernel", mesh=lane_mesh())
    np.testing.assert_array_equal(np.asarray(df), np.asarray(toks))
    assert abs(float(a0) - float(af)) < 1e-5
    with pytest.raises(ValueError, match="lane_probes"):
        lm_decompress_chunked(params, CFG, st.chunks, 32, 16,
                              backend="two_pass", mesh=chunk_mesh(),
                              lane_probes=True)
    with pytest.raises(ValueError, match="lanes"):
        lm_decompress_chunked(params, CFG, st.chunks, 32, 16,
                              backend="kernel", mesh=chunk_mesh())
    with pytest.raises(ValueError, match="mesh"):
        lm_decompress_chunked(params, CFG, st.chunks, 32, 16,
                              backend="coder", mesh=chunk_mesh())


def _teacher_tables_cands(params, cfg, toks, topk):
    """Independent reference: teacher-forced tables + top-k candidate planes
    rebuilt outside serve.compress's decode paths."""
    from repro.core import constants as C
    from repro.core.predictors import model_topk_candidates
    from repro.serve.compress import BOS, _step_tables
    from repro.serve.engine import teacher_forced_scan
    lanes, t = toks.shape
    inputs = jnp.concatenate(
        [jnp.full((lanes, 1), BOS, jnp.int32), toks[:, :-1]], axis=1)

    def per_step(lg, _):
        return (_step_tables(lg, cfg.vocab_size, C.PROB_BITS),
                model_topk_candidates(lg[:, :cfg.vocab_size], topk))

    _, (tables, cands) = teacher_forced_scan(params, cfg, inputs, t,
                                             step_fn=per_step)
    return tables, cands


def test_two_pass_lane_probes_are_kernel_pure(params):
    """Regression: backend="two_pass" lane_probes must come from the kernel
    replay ONLY — integer-identical to coder.decode(candidates=...) on the
    same tables/planes.  The historical bug accumulated pass-1 (pure-scan)
    counters into the reported telemetry, double-charging Fig. 4(b)."""
    from repro.core import coder
    toks = jnp.asarray(token_stream(CFG.vocab_size, (4, 40), seed=19),
                       jnp.int32)
    stats = lm_compress(params, CFG, toks)
    tables, cands = _teacher_tables_cands(params, CFG, toks, topk=4)
    rs, ra, rl = coder.decode(stats.enc, 40, tables, candidates=cands,
                              lane_probes=True)
    sym, avg, lane = lm_decompress(params, CFG, stats.enc, 40,
                                   backend="two_pass", lane_probes=True)
    np.testing.assert_array_equal(np.asarray(sym), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(sym))
    np.testing.assert_array_equal(np.asarray(rl), np.asarray(lane))
    assert abs(float(ra) - float(avg)) < 1e-6


def test_two_pass_chunked_lane_probes_are_kernel_pure(params):
    """Chunked analogue: pass 1 walks every chunk through the pure scan, so
    a purity bug there inflates counters chunk by chunk; the reported
    per-lane counters must equal coder.decode_chunked(candidates=...)."""
    from repro.core import coder
    from repro.serve.compress import (lm_compress_chunked,
                                      lm_decompress_chunked)
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 40), seed=20),
                       jnp.int32)
    st = lm_compress_chunked(params, CFG, toks, chunk_size=16)  # ragged 8
    tables, cands = _teacher_tables_cands(params, CFG, toks, topk=4)
    rs, ra, rl = coder.decode_chunked(st.chunks, 40, tables, 16,
                                      candidates=cands, lane_probes=True)
    sym, avg, lane = lm_decompress_chunked(params, CFG, st.chunks, 40, 16,
                                           backend="two_pass",
                                           lane_probes=True)
    np.testing.assert_array_equal(np.asarray(sym), np.asarray(toks))
    np.testing.assert_array_equal(np.asarray(rs), np.asarray(sym))
    np.testing.assert_array_equal(np.asarray(rl), np.asarray(lane))
    assert abs(float(ra) - float(avg)) < 1e-6


def test_lm_compress_chunked_overflow_parity(params):
    """An under-provisioned cap comes back truncated-but-flagged with the
    SAME per-(chunk, lane) overflow plane on both encode backends, and the
    flagged stream refuses to pack."""
    from repro.serve.compress import lm_compress_chunked
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 32), seed=18),
                       jnp.int32)
    a = lm_compress_chunked(params, CFG, toks, chunk_size=16, cap=6)
    b = lm_compress_chunked(params, CFG, toks, chunk_size=16, cap=6,
                            backend="kernel")
    assert np.asarray(a.chunks.overflow).any()
    for x, y in zip(a.chunks, b.chunks):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    with pytest.raises(ValueError, match="overflow"):
        bitstream.pack_chunked(*map(np.asarray, b.chunks), chunk_size=16,
                               n_symbols=32)


def test_lm_compress_respects_model_bound(params):
    """Coded bits/symbol ~ model cross entropy + quantization overhead."""
    toks = jnp.asarray(token_stream(CFG.vocab_size, (8, 128), seed=5),
                       jnp.int32)
    stats = lm_compress(params, CFG, toks)
    bound = float(stats.model_xent_bits)
    got = float(stats.bits_per_symbol)
    assert got >= bound - 0.05            # can't beat the model's entropy
    assert got <= bound + 1.5             # bounded SPC/quantization overhead


def test_lm_compress_across_lane_counts(params):
    """Multi-lane scaling never changes content (per-lane independence)."""
    t = 48
    base = token_stream(CFG.vocab_size, (8, t), seed=9)
    full = lm_compress(params, CFG, jnp.asarray(base, jnp.int32))
    # encode lanes 0..3 alone: identical per-lane payloads
    half = lm_compress(params, CFG, jnp.asarray(base[:4], jnp.int32))
    fb, fs, fl, _ = map(np.asarray, full.enc)
    hb, hs, hl, _ = map(np.asarray, half.enc)
    for i in range(4):
        a = fb[i, fs[i]:fs[i] + fl[i]].tobytes()
        b = hb[i, hs[i]:hs[i] + hl[i]].tobytes()
        assert a == b, f"lane {i} bitstream changed with lane count"


def test_histogram_compress_images():
    img = synthetic_image(32, 64, seed=1)
    rows = img.reshape(8, -1).astype(np.int64)
    enc, tbl = histogram_compress(rows, 256)
    from repro.core import coder
    dec, _ = coder.decode(coder.EncodedLanes(*enc), rows.shape[1], tbl)
    np.testing.assert_array_equal(np.asarray(dec), rows)
    # smooth images compress well below 8 bits/px even with a static table
    bits = float(np.asarray(enc.length).sum()) * 8 / rows.size
    assert bits < 6.0, bits


def test_histogram_decompress_backends_agree():
    """The serve static path decodes through the Pallas kernel by default;
    both backends share core/search.py so symbols and probe telemetry are
    identical."""
    from repro.core import coder
    from repro.core.predictors import NeighborAverage
    img = synthetic_image(32, 64, seed=7)
    rows = img.reshape(8, -1).astype(np.int64)
    enc, tbl = histogram_compress(rows, 256)
    t = rows.shape[1]
    for pred in (None, NeighborAverage(window=4, delta=8)):
        ks, kp = histogram_decompress(coder.EncodedLanes(*enc), t, tbl,
                                      predictor=pred, backend="kernel")
        cs, cp = histogram_decompress(coder.EncodedLanes(*enc), t, tbl,
                                      predictor=pred, backend="coder")
        np.testing.assert_array_equal(np.asarray(ks), rows)
        np.testing.assert_array_equal(np.asarray(ks), np.asarray(cs))
        assert abs(float(kp) - float(cp)) < 1e-6
    with pytest.raises(ValueError, match="backend"):
        histogram_decompress(coder.EncodedLanes(*enc), t, tbl,
                             backend="nope")


def test_container_integration(params):
    toks = jnp.asarray(token_stream(CFG.vocab_size, (4, 32), seed=11),
                       jnp.int32)
    stats = lm_compress(params, CFG, toks)
    blob = bitstream.pack(np.asarray(stats.enc.buf),
                          np.asarray(stats.enc.start),
                          np.asarray(stats.enc.length), n_symbols=32)
    buf, start, meta = bitstream.unpack(blob)
    from repro.core.coder import EncodedLanes
    enc2 = EncodedLanes(jnp.asarray(buf), jnp.asarray(start),
                        jnp.asarray(buf.shape[1] - start))
    dec, _ = lm_decompress(params, CFG, enc2, 32)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks))


def test_generate_shapes_and_determinism(params):
    prompt = jnp.asarray(token_stream(CFG.vocab_size, (2, 8), seed=2),
                         jnp.int32)
    out1 = generate(params, CFG, prompt, 12, max_len=32)
    out2 = generate(params, CFG, prompt, 12, max_len=32)
    assert out1.shape == (2, 12)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_prefill_matches_decode_logits(params):
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 10), seed=4),
                       jnp.int32)
    _, last = prefill(params, CFG, toks, max_len=16)
    assert last.shape == (2, CFG.vocab_padded)
    assert np.isfinite(np.asarray(last)).all()


def test_data_pipeline_determinism():
    a = token_stream(100, (4, 32), seed=5)
    b = token_stream(100, (4, 32), seed=5)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(a, token_stream(100, (4, 32), seed=6))
    img = synthetic_image(16, 16, seed=3)
    np.testing.assert_array_equal(img, synthetic_image(16, 16, seed=3))
    rows = image_rows(4, 64, seed=1)
    assert rows.min() >= 0 and rows.max() <= 255
