"""Golden-vector corpus generator (frozen container blobs).

The blobs checked in next to this script freeze the *wire format*: every
future refactor of the coder, the kernels or the container writers must
keep producing byte-identical blobs for these seeds and keep decoding the
stored bytes to the identical symbols (``tests/test_golden_vectors.py``
asserts both, on every decode backend).  Regenerate only on a deliberate,
versioned container change:

    PYTHONPATH=src python tests/golden_vectors/generate.py

Corpus axes: container v1 vs v2, v2 with and without per-(chunk, lane)
CRC32 checksums, static / per-position (T, K) / per-lane (T, lanes, K)
TableSets, aligned and ragged chunking.  Cases are deliberately tiny —
the point is coverage of the format, not of the coder (the differential
suites own that).
"""

from __future__ import annotations

import os

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))

# name, fmt, seed, k, lanes, t, chunk_size (v2), checksums (v2), tables
CASES = [
    dict(name="v1_static", fmt="v1", seed=41, k=64, lanes=4, t=64,
         tables="static"),
    dict(name="v2_static_crc", fmt="v2", seed=42, k=64, lanes=4, t=64,
         chunk_size=20, checksums=True, tables="static"),     # ragged tail 4
    dict(name="v2_perpos_nocrc", fmt="v2", seed=43, k=32, lanes=4, t=48,
         chunk_size=16, checksums=False, tables="perpos"),    # aligned
    dict(name="v2_perlane_crc", fmt="v2", seed=44, k=16, lanes=4, t=32,
         chunk_size=13, checksums=True, tables="perlane"),    # ragged tail 6
]


# stack golden vectors (core/stack.py): frozen flushed-stack streams for
# the push/pop interface — uniform, NonUniform statfun, serial-composed,
# and a bits-back schedule drawing on nonzero initial bits.  The test pops
# every stored stream back on BOTH backends (coder + per-step kernel).
STACK_CASES = [
    dict(name="stack_uniform", seed=51, lanes=4, cap=256, bits=6, t=24,
         init_bytes=0),
    dict(name="stack_nonuniform", seed=52, lanes=4, cap=256, k=16, t=24,
         init_bytes=0),
    dict(name="stack_serial", seed=53, lanes=4, cap=256, k=16, t=10,
         init_bytes=0),
    dict(name="stack_bitsback", seed=54, lanes=4, cap=256, k=16, kx=32,
         t=12, init_bytes=48),
]


def blob_path(case: dict) -> str:
    return os.path.join(HERE, case["name"] + ".ras")


def build_case(case: dict):
    """Deterministic (TableSet, symbols (lanes, t) np.int32) for a case."""
    import jax.numpy as jnp
    from repro.core import spc
    rng = np.random.default_rng(case["seed"])
    k, lanes, t = case["k"], case["lanes"], case["t"]
    if case["tables"] == "static":
        probs = rng.dirichlet(np.full(k, 0.5))
    elif case["tables"] == "perpos":
        probs = rng.dirichlet(np.full(k, 0.5), size=t)
    else:  # perlane
        probs = rng.dirichlet(np.full(k, 0.5), size=(t, lanes))
    tbl = spc.tables_from_probs(jnp.asarray(probs.astype(np.float32)))
    syms = rng.integers(0, k, (lanes, t)).astype(np.int32)
    return tbl, syms


def pack_case(case: dict) -> bytes:
    """Encode + pack a case exactly as the test re-derives it."""
    import jax.numpy as jnp
    from repro.core import bitstream, coder
    tbl, syms = build_case(case)
    if case["fmt"] == "v1":
        enc = coder.encode(jnp.asarray(syms), tbl)
        return bitstream.pack(*map(np.asarray, enc), n_symbols=case["t"])
    ch = coder.encode_chunked(jnp.asarray(syms), tbl, case["chunk_size"])
    return bitstream.pack_chunked(*map(np.asarray, ch),
                                  chunk_size=case["chunk_size"],
                                  n_symbols=case["t"],
                                  checksums=case["checksums"])


def _dirichlet_tables(rng, k: int, lanes: int | None = None):
    """Seeded quantized (freq, cdf) planes via the BF16 storage path."""
    import jax.numpy as jnp
    from repro.core import spc
    probs = rng.dirichlet(np.full(k, 0.5),
                          size=None if lanes is None else (lanes,))
    return spc.freq_cdf_from_probs(
        spc.store_bf16(jnp.asarray(probs, jnp.float32)))


def _nonuniform_codec(freq, cdf):
    """A genuinely statfun-driven codec (craystack's ``NonUniform``) over
    frozen quantized planes — NOT ``Categorical``, so the statfun entry
    point itself is pinned by the golden bytes."""
    from repro.core import search, stack
    k = freq.shape[-1]

    def enc_statfun(x):
        return stack._gather(cdf[..., :-1], x), stack._gather(freq, x)

    def dec_statfun(slot):
        return search.find_symbol(cdf, k, slot)[0]

    return stack.NonUniform(enc_statfun, dec_statfun)


def run_stack_case(case: dict, backend: str = "coder"):
    """Deterministically run a stack case's push schedule.

    Returns ``(st0, st, aux)``: initial stack, pushed stack, and an aux
    dict with the symbols + table planes the pop schedule needs.  The
    ``backend`` selects how encode-time *pops* run (bits-back case only) —
    both must land on identical bytes.
    """
    import jax.numpy as jnp
    from repro.core import stack
    rng = np.random.default_rng(case["seed"])
    lanes, cap, t = case["lanes"], case["cap"], case["t"]
    st0 = (stack.stack_init_bits(lanes, cap, n_bytes=case["init_bytes"],
                                 seed=case["seed"])
           if case["init_bytes"] else stack.stack_init(lanes, cap))
    st = st0
    if case["name"] == "stack_uniform":
        x = rng.integers(0, 1 << case["bits"], (lanes, t)).astype(np.int32)
        codec = stack.Uniform(case["bits"])
        for i in reversed(range(t)):     # LIFO: push reversed, pop forward
            st = codec.push(st, jnp.asarray(x[:, i]))
        return st0, st, {"x": x}
    if case["name"] == "stack_nonuniform":
        freq, cdf = _dirichlet_tables(rng, case["k"])
        x = rng.integers(0, case["k"], (lanes, t)).astype(np.int32)
        codec = _nonuniform_codec(freq, cdf)
        for i in reversed(range(t)):
            st = codec.push(st, jnp.asarray(x[:, i]))
        return st0, st, {"x": x, "freq": freq, "cdf": cdf}
    if case["name"] == "stack_serial":
        freq, cdf = _dirichlet_tables(rng, case["k"])
        xa = rng.integers(0, 1 << 4, (lanes, t)).astype(np.int32)
        xb = rng.integers(0, case["k"], (lanes, t)).astype(np.int32)
        xc = rng.integers(0, 1 << 6, (lanes, t)).astype(np.int32)
        codec = stack.serial([stack.Uniform(4),
                              stack.Categorical(freq, cdf),
                              stack.Uniform(6)])
        for i in reversed(range(t)):
            st = codec.push(st, tuple(jnp.asarray(v[:, i])
                                      for v in (xa, xb, xc)))
        return st0, st, {"x": (xa, xb, xc), "freq": freq, "cdf": cdf}
    # stack_bitsback: per step pop k ~ q (posterior, per-lane tables,
    # drawing on the initial bits), push x ~ p, push k ~ Uniform prior
    qf, qc = _dirichlet_tables(rng, case["k"], lanes=lanes)
    pf, pc = _dirichlet_tables(rng, case["kx"])
    x = rng.integers(0, case["kx"], (lanes, t)).astype(np.int32)
    bits = int(np.log2(case["k"]))
    q = stack.Categorical(qf, qc, backend=backend)
    p = stack.Categorical(pf, pc, backend=backend)
    u = stack.Uniform(bits)
    ks = []
    for i in range(t):
        st, k_i = q.pop(st)
        ks.append(np.asarray(k_i))
        st = p.push(st, jnp.asarray(x[:, i]))
        st = u.push(st, k_i)
    assert not np.asarray(st.underflow).any(), "bits-back case under-seeded"
    return st0, st, {"x": x, "k": np.stack(ks, axis=1), "bits": bits,
                     "tables": (qf, qc, pf, pc)}


def pop_stack_case(case: dict, st, aux, backend: str = "coder"):
    """Run the matching pop schedule; returns ``(state, symbols)`` with
    symbols shaped like the aux record (the test compares them exactly)."""
    import jax.numpy as jnp
    from repro.core import stack
    t = case["t"]
    if case["name"] == "stack_uniform":
        codec = stack.Uniform(case["bits"])
    elif case["name"] == "stack_nonuniform":
        codec = (stack.Categorical(aux["freq"], aux["cdf"], backend="kernel")
                 if backend == "kernel"
                 else _nonuniform_codec(aux["freq"], aux["cdf"]))
    elif case["name"] == "stack_serial":
        codec = stack.serial([stack.Uniform(4),
                              stack.Categorical(aux["freq"], aux["cdf"],
                                                backend=backend),
                              stack.Uniform(6)])
    else:  # stack_bitsback: exact reverse schedule restores the initial bits
        qf, qc, pf, pc = aux["tables"]
        q = stack.Categorical(qf, qc, backend=backend)
        p = stack.Categorical(pf, pc, backend=backend)
        u = stack.Uniform(aux["bits"])
        xs, ks = [], []
        for i in reversed(range(t)):
            st, k_i = u.pop(st)
            st, x_i = p.pop(st)
            st = q.push(st, k_i)
            xs.append(np.asarray(x_i))
            ks.append(np.asarray(k_i))
        return st, {"x": np.stack(xs[::-1], axis=1),
                    "k": np.stack(ks[::-1], axis=1)}
    xs = []
    for _ in range(t):
        st, x_i = codec.pop(st)
        xs.append(x_i)
    if case["name"] == "stack_serial":
        return st, tuple(np.stack([np.asarray(x[j]) for x in xs], axis=1)
                         for j in range(3))
    return st, np.stack([np.asarray(x) for x in xs], axis=1)


def pack_stack_case(case: dict) -> bytes:
    """Push schedule -> flushed stack -> v1 container bytes (the frozen
    wire artifact; ``stack_flush`` output is EncodedLanes-compatible)."""
    from repro.core import bitstream, stack
    _, st, _ = run_stack_case(case)
    enc = stack.stack_flush(st)
    return bitstream.pack(*map(np.asarray, enc), n_symbols=case["t"])


def main():
    import jax
    jax.config.update("jax_platforms", "cpu")
    for case in CASES:
        blob = pack_case(case)
        with open(blob_path(case), "wb") as f:
            f.write(blob)
        print(f"wrote {blob_path(case)} ({len(blob)} bytes)")
    for case in STACK_CASES:
        blob = pack_stack_case(case)
        with open(blob_path(case), "wb") as f:
            f.write(blob)
        print(f"wrote {blob_path(case)} ({len(blob)} bytes)")


if __name__ == "__main__":
    main()
