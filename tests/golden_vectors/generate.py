"""Golden-vector corpus generator (frozen container blobs).

The blobs checked in next to this script freeze the *wire format*: every
future refactor of the coder, the kernels or the container writers must
keep producing byte-identical blobs for these seeds and keep decoding the
stored bytes to the identical symbols (``tests/test_golden_vectors.py``
asserts both, on every decode backend).  Regenerate only on a deliberate,
versioned container change:

    PYTHONPATH=src python tests/golden_vectors/generate.py

Corpus axes: container v1 vs v2, v2 with and without per-(chunk, lane)
CRC32 checksums, static / per-position (T, K) / per-lane (T, lanes, K)
TableSets, aligned and ragged chunking.  Cases are deliberately tiny —
the point is coverage of the format, not of the coder (the differential
suites own that).
"""

from __future__ import annotations

import os

import numpy as np

HERE = os.path.dirname(os.path.abspath(__file__))

# name, fmt, seed, k, lanes, t, chunk_size (v2), checksums (v2), tables
CASES = [
    dict(name="v1_static", fmt="v1", seed=41, k=64, lanes=4, t=64,
         tables="static"),
    dict(name="v2_static_crc", fmt="v2", seed=42, k=64, lanes=4, t=64,
         chunk_size=20, checksums=True, tables="static"),     # ragged tail 4
    dict(name="v2_perpos_nocrc", fmt="v2", seed=43, k=32, lanes=4, t=48,
         chunk_size=16, checksums=False, tables="perpos"),    # aligned
    dict(name="v2_perlane_crc", fmt="v2", seed=44, k=16, lanes=4, t=32,
         chunk_size=13, checksums=True, tables="perlane"),    # ragged tail 6
]


def blob_path(case: dict) -> str:
    return os.path.join(HERE, case["name"] + ".ras")


def build_case(case: dict):
    """Deterministic (TableSet, symbols (lanes, t) np.int32) for a case."""
    import jax.numpy as jnp
    from repro.core import spc
    rng = np.random.default_rng(case["seed"])
    k, lanes, t = case["k"], case["lanes"], case["t"]
    if case["tables"] == "static":
        probs = rng.dirichlet(np.full(k, 0.5))
    elif case["tables"] == "perpos":
        probs = rng.dirichlet(np.full(k, 0.5), size=t)
    else:  # perlane
        probs = rng.dirichlet(np.full(k, 0.5), size=(t, lanes))
    tbl = spc.tables_from_probs(jnp.asarray(probs.astype(np.float32)))
    syms = rng.integers(0, k, (lanes, t)).astype(np.int32)
    return tbl, syms


def pack_case(case: dict) -> bytes:
    """Encode + pack a case exactly as the test re-derives it."""
    import jax.numpy as jnp
    from repro.core import bitstream, coder
    tbl, syms = build_case(case)
    if case["fmt"] == "v1":
        enc = coder.encode(jnp.asarray(syms), tbl)
        return bitstream.pack(*map(np.asarray, enc), n_symbols=case["t"])
    ch = coder.encode_chunked(jnp.asarray(syms), tbl, case["chunk_size"])
    return bitstream.pack_chunked(*map(np.asarray, ch),
                                  chunk_size=case["chunk_size"],
                                  n_symbols=case["t"],
                                  checksums=case["checksums"])


def main():
    import jax
    jax.config.update("jax_platforms", "cpu")
    for case in CASES:
        blob = pack_case(case)
        with open(blob_path(case), "wb") as f:
            f.write(blob)
        print(f"wrote {blob_path(case)} ({len(blob)} bytes)")


if __name__ == "__main__":
    main()
