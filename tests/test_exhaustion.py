"""Decoder over-read bugfix sweep + degenerate streams (ISSUE 9 satellites).

Before this fix a decode that ran past the end of a lane's byte window
silently re-read garbage and returned plausible-looking symbols.  Now every
refill past the window injects 0 and raises the lane's underflow flag, and
every HOST decode entry point turns the flag into a named
:class:`repro.core.coder.StreamExhaustedError`:

  * ``coder.decode`` / ``coder.decode_chunked``
  * ``kernels.ops.rans_decode`` / ``rans_decode_chunked``
  * ``parallel.chunked.decode_chunked`` (flags threaded out of shard_map)
  * ``serve.compress.histogram_decompress`` / ``lm_decompress``
  * the batch engine (the request retires with the error; co-batched
    requests are unaffected)

Traced callers opt into flag form with ``return_exhausted`` /
``exhausted_flags``.  The degenerate-stream sweep pins the boundary cases:
``n_symbols == 0`` (zero chunks AND the monolithic 4-flush-byte header-only
stream), single-symbol chunks, both through pack/unpack and both decode
backends.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream, coder, spc
from repro.core.coder import StreamExhaustedError
from repro.kernels import ops as kops

jax.config.update("jax_platforms", "cpu")

LANES = 4


def _tbl(k, seed):
    probs = np.random.default_rng(seed).dirichlet(np.full(k, 0.5))
    return spc.tables_from_probs(jnp.asarray(probs.astype(np.float32)))


def _syms(k, t, seed):
    return np.random.default_rng(seed).integers(
        0, k, (LANES, t)).astype(np.int32)


def _truncate(enc: coder.EncodedLanes, d: int) -> coder.EncodedLanes:
    """Drop the last ``d`` stream bytes of every lane (the bytes a decode
    reads LAST), keeping the right-aligned layout the readers expect."""
    buf, start = np.asarray(enc.buf), np.asarray(enc.start)
    cap = buf.shape[1]
    out = np.zeros_like(buf)
    for lane in range(buf.shape[0]):
        out[lane, start[lane] + d:] = buf[lane, start[lane]:cap - d]
    return coder.EncodedLanes(buf=jnp.asarray(out),
                              start=jnp.asarray(start + d),
                              length=jnp.asarray(cap - (start + d)))


def _truncate_chunked(ch: coder.ChunkedLanes, d: int) -> coder.ChunkedLanes:
    """Drop ``d`` tail bytes from every lane of the LAST chunk only."""
    buf = np.array(np.asarray(ch.buf))
    start = np.array(np.asarray(ch.start))
    length = np.array(np.asarray(ch.length))
    c, cap = buf.shape[0] - 1, buf.shape[2]
    for lane in range(buf.shape[1]):
        row = buf[c, lane].copy()
        buf[c, lane] = 0
        buf[c, lane, start[c, lane] + d:] = row[start[c, lane]:cap - d]
    start[c] += d
    length[c] -= d
    return coder.ChunkedLanes(buf=jnp.asarray(buf), start=jnp.asarray(start),
                              length=jnp.asarray(length))


# ---------------------------------------------------------------------------
# monolithic streams: over-read and truncation on both backends
# ---------------------------------------------------------------------------

def test_coder_overread_raises_named_error():
    tbl = _tbl(16, 0)
    enc = coder.encode(jnp.asarray(_syms(16, 12, 1)), tbl)
    sym, _ = coder.decode(enc, 12, tbl)          # exact read: fine
    with pytest.raises(StreamExhaustedError, match="lane indices"):
        coder.decode(enc, 16, tbl)               # 4 symbols past the end


def test_coder_truncated_stream_raises():
    tbl = _tbl(16, 2)
    enc = coder.encode(jnp.asarray(_syms(16, 12, 3)), tbl)
    with pytest.raises(StreamExhaustedError):
        coder.decode(_truncate(enc, 2), 12, tbl)


def test_coder_return_exhausted_flags_instead_of_raising():
    tbl = _tbl(16, 4)
    enc = coder.encode(jnp.asarray(_syms(16, 12, 5)), tbl)
    sym, _, under = coder.decode(_truncate(enc, 2), 12, tbl,
                                 return_exhausted=True)
    assert np.asarray(under).any()
    _, _, clean = coder.decode(enc, 12, tbl, return_exhausted=True)
    assert not np.asarray(clean).any()


def test_kernel_overread_and_truncation_raise():
    tbl = _tbl(16, 6)
    syms = _syms(16, 12, 7)
    enc = kops.rans_encode(jnp.asarray(syms), tbl)
    got, _ = kops.rans_decode(enc, 12, tbl)
    np.testing.assert_array_equal(np.asarray(got), syms)
    with pytest.raises(StreamExhaustedError):
        kops.rans_decode(enc, 16, tbl)
    with pytest.raises(StreamExhaustedError):
        kops.rans_decode(_truncate(enc, 2), 12, tbl)
    *_, under = kops.rans_decode(_truncate(enc, 2), 12, tbl,
                                 exhausted_flags=True)
    assert np.asarray(under).any()


# ---------------------------------------------------------------------------
# chunked streams
# ---------------------------------------------------------------------------

def test_chunked_truncated_tail_raises_both_backends():
    tbl = _tbl(16, 8)
    syms = _syms(16, 40, 9)
    ch = coder.encode_chunked(jnp.asarray(syms), tbl, 16)  # ragged tail 8
    bad = _truncate_chunked(ch, 2)
    with pytest.raises(StreamExhaustedError):
        coder.decode_chunked(bad, 40, tbl, 16)
    with pytest.raises(StreamExhaustedError):
        kops.rans_decode_chunked(bad, 40, tbl, 16)
    *_, under = kops.rans_decode_chunked(bad, 40, tbl, 16,
                                         exhausted_flags=True)
    assert np.asarray(under).any()


def test_parallel_decode_chunked_truncated_raises():
    from repro.parallel import chunked as pchunked
    tbl = _tbl(16, 10)
    syms = _syms(16, 64, 11)
    mesh = pchunked.chunk_mesh()
    ch = pchunked.encode_chunked(jnp.asarray(syms), tbl, 16, mesh=mesh)
    got, _ = pchunked.decode_chunked(ch, 64, tbl, 16, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(got), syms)
    with pytest.raises(StreamExhaustedError, match="parallel"):
        pchunked.decode_chunked(_truncate_chunked(ch, 2), 64, tbl, 16,
                                mesh=mesh)


# ---------------------------------------------------------------------------
# serve paths: histogram codec and the batch engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["coder", "kernel"])
def test_histogram_decompress_truncated_raises(backend):
    from repro.serve.compress import histogram_compress, histogram_decompress
    rows = _syms(64, 32, 12).astype(np.int64)
    enc, tbl = histogram_compress(rows, 64)
    got = histogram_decompress(enc, 32, tbl, backend=backend)
    np.testing.assert_array_equal(np.asarray(got[0]), rows)
    with pytest.raises(StreamExhaustedError):
        histogram_decompress(_truncate(enc, 2), 32, tbl, backend=backend)


def test_engine_retires_truncated_decompress_with_error():
    """A truncated container retires ITS request with StreamExhaustedError;
    a co-batched healthy request still completes byte-identically."""
    from repro.configs import get_smoke_config
    from repro.data.pipeline import token_stream
    from repro.models import init_model
    from repro.serve.compress import lm_compress_chunked
    from repro.serve.engine import BatchEngine

    cfg = get_smoke_config("ras-pimc")
    params = init_model(cfg, jax.random.PRNGKey(2))
    toks = np.asarray(token_stream(cfg.vocab_size, (LANES, 16), seed=13),
                      np.int32)
    stats = lm_compress_chunked(params, cfg, jnp.asarray(toks), chunk_size=8)
    ch = jax.tree.map(np.asarray, stats.chunks)
    good = bitstream.pack_chunked(ch.buf, ch.start, ch.length, ch.overflow,
                                  chunk_size=8, n_symbols=16)
    bad_ch = _truncate_chunked(stats.chunks, 2)
    bad = bitstream.pack_chunked(
        np.asarray(bad_ch.buf), np.asarray(bad_ch.start),
        np.asarray(bad_ch.length), None, chunk_size=8, n_symbols=16)

    eng = BatchEngine(params, cfg, slots=2, lanes=LANES, chunk_size=8,
                      max_len=32)
    r_bad = eng.submit_decompress(bad)
    r_ok = eng.submit_decompress(good)
    res = eng.run()
    assert not res[r_bad].ok
    assert isinstance(res[r_bad].error, StreamExhaustedError)
    assert "over-read" in str(res[r_bad].error)
    assert res[r_ok].ok, res[r_ok].error
    np.testing.assert_array_equal(np.asarray(res[r_ok].tokens), toks)


# ---------------------------------------------------------------------------
# degenerate streams: n_symbols == 0, header-only, single-symbol chunks
# ---------------------------------------------------------------------------

def test_empty_symbol_block_monolithic_header_only():
    """t = 0 monolithic: the stream is exactly the 4 flush bytes of the
    initial state, identical from the coder and the kernel path, packs and
    unpacks, and decodes to an empty block with no exhaustion."""
    tbl = _tbl(16, 14)
    empty = jnp.zeros((LANES, 0), jnp.int32)
    enc_c = coder.encode(empty, tbl)
    enc_k = kops.rans_encode(empty, tbl)
    np.testing.assert_array_equal(np.asarray(enc_c.length),
                                  np.full(LANES, 4))
    for field in ("buf", "start", "length"):
        np.testing.assert_array_equal(
            np.asarray(getattr(enc_c, field)),
            np.asarray(getattr(enc_k, field)), err_msg=field)
    blob = bitstream.pack(*map(np.asarray, enc_c), n_symbols=0)
    buf, start, meta = bitstream.unpack(blob)
    assert meta.n_symbols == 0
    enc_r = coder.EncodedLanes(jnp.asarray(buf), jnp.asarray(start),
                               jnp.asarray(buf.shape[1] - start))
    for enc, dec in ((enc_r, coder.decode), (enc_r, kops.rans_decode)):
        sym, _, under = dec(enc, 0, tbl, return_exhausted=True) \
            if dec is coder.decode else dec(enc, 0, tbl,
                                            exhausted_flags=True)
        assert sym.shape == (LANES, 0)
        assert not np.asarray(under).any()


def test_empty_symbol_block_chunked_zero_chunks():
    tbl = _tbl(16, 15)
    empty = jnp.zeros((LANES, 0), jnp.int32)
    ch_c = coder.encode_chunked(empty, tbl, 8)
    ch_k = kops.rans_encode_chunked(empty, tbl, 8)
    assert ch_c.buf.shape[0] == 0 and ch_k.buf.shape[0] == 0
    sym, _ = coder.decode_chunked(ch_c, 0, tbl, 8)
    assert sym.shape == (LANES, 0)
    sym_k, _ = kops.rans_decode_chunked(ch_c, 0, tbl, 8)
    assert sym_k.shape == (LANES, 0)
    blob = bitstream.pack_chunked(*map(np.asarray, ch_c), chunk_size=8,
                                  n_symbols=0)
    buf, start, meta = bitstream.unpack_chunked(blob)
    assert meta.n_symbols == 0 and meta.n_chunks == 0


@pytest.mark.parametrize("t", [1, 6])
def test_single_symbol_chunks_roundtrip_both_backends(t):
    """chunk_size = 1: every chunk is one symbol + a full flush header —
    the minimal-chunk corner of the interleaved construction."""
    tbl = _tbl(16, 16)
    syms = _syms(16, t, 17)
    ch = coder.encode_chunked(jnp.asarray(syms), tbl, 1)
    assert ch.buf.shape[0] == t
    got_c, _ = coder.decode_chunked(ch, t, tbl, 1)
    got_k, _ = kops.rans_decode_chunked(ch, t, tbl, 1)
    np.testing.assert_array_equal(np.asarray(got_c), syms)
    np.testing.assert_array_equal(np.asarray(got_k), syms)
    ch_k = kops.rans_encode_chunked(jnp.asarray(syms), tbl, 1)
    for field in ("buf", "start", "length"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ch, field)),
            np.asarray(getattr(ch_k, field)), err_msg=field)


def test_single_symbol_monolithic_roundtrip():
    tbl = _tbl(16, 18)
    syms = _syms(16, 1, 19)
    enc = coder.encode(jnp.asarray(syms), tbl)
    got, _ = coder.decode(enc, 1, tbl)
    np.testing.assert_array_equal(np.asarray(got), syms)
    got_k, _ = kops.rans_decode(enc, 1, tbl)
    np.testing.assert_array_equal(np.asarray(got_k), syms)
