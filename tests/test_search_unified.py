"""Unified decode datapath (ISSUE 2): one shared search core, every backend.

Acceptance pins:
  * ``kernels/rans_decode.py`` and ``kernels/ref.py`` contain no private
    CDF-search or predictor logic — both consume ``core/search.py`` and the
    ``core/predictors`` protocol (source-inspection guard below);
  * kernel vs ``coder.decode`` is byte-identical in symbols AND
    integer-identical in per-lane probe counters for static, adaptive
    (per-position shared and per-lane) and chunked streams, for each
    predictor family;
  * the canonical probe accounting of ``core/search.py`` (window verify
    charged once, skipped after a candidate hit) holds on both backends;
  * predictor edge cases (delta=0, window > T, empty context, degenerate
    candidate lists) stay bit-exact and fall back safely.
"""

import inspect

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import coder, search, spc
from repro.core.predictors import LastValue, NeighborAverage, ZeroPredictor
from repro.data.pipeline import candidate_planes
from repro.kernels import ops, rans_decode, ref

jax.config.update("jax_platforms", "cpu")


def _candidate_planes(syms, k, topk, hit_rate, seed):
    """jnp view of the shared model-top-k plane synthesizer (the benchmark
    sweep consumes the same one, so it measures what these tests pin)."""
    return jnp.asarray(candidate_planes(np.asarray(syms), k, topk,
                                        hit_rate, seed), jnp.int32)

PREDICTORS = [
    None,
    NeighborAverage(window=4, delta=8),
    NeighborAverage(window=2, delta=4),
    LastValue(delta=8),
    ZeroPredictor(delta=8),
]

_IDS = ["baseline", "navg4", "navg2", "last", "zero"]


def _assert_identical(dec_kernel, dec_coder, syms):
    gsym, gavg, glanes = dec_kernel
    wsym, wavg, wlanes = dec_coder
    np.testing.assert_array_equal(np.asarray(gsym), np.asarray(wsym))
    np.testing.assert_array_equal(np.asarray(gsym), np.asarray(syms))
    np.testing.assert_array_equal(np.asarray(glanes), np.asarray(wlanes))
    assert abs(float(gavg) - float(wavg)) < 1e-5


# ---------------------------------------------------------------------------
# cross-backend differentials: static / per-position / per-lane / chunked
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("predictor", PREDICTORS, ids=_IDS)
def test_static_differential(rans_case, predictor):
    tbl, syms = rans_case(70, k=64, lanes=8, t=64)
    enc = coder.encode(jnp.asarray(syms), tbl)
    got = ops.rans_decode(enc, 64, tbl, predictor=predictor,
                          lane_probes=True)
    want = ref.rans_decode_ref(enc, 64, tbl, predictor=predictor,
                               lane_probes=True)
    _assert_identical(got, want, syms)


@pytest.fixture(scope="module")
def perpos_case():
    rng = np.random.default_rng(71)
    k, lanes, t = 32, 4, 48
    probs = rng.dirichlet(np.ones(k) * 0.5, size=t).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))        # (T, K)
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    return tbl, syms


@pytest.fixture(scope="module")
def perlane_case():
    rng = np.random.default_rng(72)
    k, lanes, t = 16, 4, 32
    probs = rng.dirichlet(np.ones(k) * 0.5,
                          size=(t, lanes)).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))        # (T, lanes, K)
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    return tbl, syms


@pytest.mark.parametrize("predictor", PREDICTORS, ids=_IDS)
def test_adaptive_perpos_differential(perpos_case, predictor):
    """Per-position (T, K) tables decode in-kernel — the adaptive case the
    static-table kernel could never serve."""
    tbl, syms = perpos_case
    t = syms.shape[1]
    enc = coder.encode(syms, tbl)
    got = ops.rans_decode(enc, t, tbl, predictor=predictor, lane_probes=True)
    want = coder.decode(enc, t, tbl, predictor=predictor, lane_probes=True)
    _assert_identical(got, want, syms)


@pytest.mark.parametrize("predictor", [None, NeighborAverage(2, 4)],
                         ids=["baseline", "navg2"])
def test_adaptive_perlane_differential(perlane_case, predictor):
    """(T, lanes, K) TableSets — the serve.compress neural-prior layout."""
    tbl, syms = perlane_case
    t = syms.shape[1]
    enc = coder.encode(syms, tbl)
    got = ops.rans_decode(enc, t, tbl, predictor=predictor, lane_probes=True)
    want = coder.decode(enc, t, tbl, predictor=predictor, lane_probes=True)
    _assert_identical(got, want, syms)


@pytest.mark.parametrize("predictor", [None, NeighborAverage(4, 8),
                                       LastValue(8)],
                         ids=["baseline", "navg4", "last"])
def test_chunked_differential(perpos_case, predictor):
    """ops.rans_decode_chunked == coder.decode_chunked per lane and per
    chunk, ragged tail included (chunk_size 13 over T=48)."""
    tbl, syms = perpos_case
    t = syms.shape[1]
    ch = coder.encode_chunked(syms, tbl, 13)
    got = ops.rans_decode_chunked(ch, t, tbl, 13, predictor=predictor,
                                  lane_probes=True)
    want = coder.decode_chunked(ch, t, tbl, 13, predictor=predictor,
                                lane_probes=True)
    _assert_identical(got, want, syms)


@pytest.mark.parametrize("layout", ["static", "perpos", "perlane"])
def test_candidate_plane_differential(rans_case, perpos_case, perlane_case,
                                      layout):
    """(T, lanes, topk) model-top-k candidate planes decode identically on
    both backends — symbols AND per-lane probe counters — for every table
    layout (the kernel's in-kernel speculation vs the coder's scanned
    ``decode_get`` candidates)."""
    if layout == "static":
        tbl, syms = rans_case(80, k=64, lanes=8, t=64)
        syms = jnp.asarray(syms, jnp.int32)
    elif layout == "perpos":
        tbl, syms = perpos_case
    else:
        tbl, syms = perlane_case
    k, t = tbl.freq.shape[-1], syms.shape[1]
    cands = _candidate_planes(syms, k, topk=4, hit_rate=0.7, seed=81)
    enc = coder.encode(syms, tbl)
    got = ops.rans_decode(enc, t, tbl, candidates=cands, lane_probes=True)
    want = coder.decode(enc, t, tbl, candidates=cands, lane_probes=True)
    _assert_identical(got, want, syms)


def test_chunked_candidate_plane_differential(perpos_case):
    """Candidate planes ride the chunk grid axis (ragged tail included):
    kernel single-launch chunked decode == coder per chunk and per lane."""
    tbl, syms = perpos_case
    t = syms.shape[1]
    k = tbl.freq.shape[-1]
    cands = _candidate_planes(syms, k, topk=4, hit_rate=0.7, seed=82)
    ch = coder.encode_chunked(syms, tbl, 13)
    got = ops.rans_decode_chunked(ch, t, tbl, 13, candidates=cands,
                                  lane_probes=True)
    want = coder.decode_chunked(ch, t, tbl, 13, candidates=cands,
                                lane_probes=True)
    _assert_identical(got, want, syms)


def test_chunked_decode_is_one_pallas_call(perpos_case, monkeypatch):
    """The chunk axis is a grid dimension, not a host-side loop: a 4-chunk
    adaptive decode must launch exactly ONE pallas_call (the decode-side
    mirror of PR 3's encode assertion)."""
    tbl, syms = perpos_case
    calls = []
    real = rans_decode.pl.pallas_call

    def counting(*args, **kwargs):
        calls.append(kwargs.get("grid"))
        return real(*args, **kwargs)

    monkeypatch.setattr(rans_decode.pl, "pallas_call", counting)
    # fresh shapes so the jit cache cannot satisfy the call without tracing
    sub = syms[:, :45]
    tbl_sub = jax.tree.map(lambda a: a[:45], tbl)
    ch = coder.encode_chunked(sub, tbl_sub, 12)  # 3 full chunks + tail of 9
    got, _ = ops.rans_decode_chunked(ch, 45, tbl_sub, 12)
    assert len(calls) == 1, f"expected 1 pallas_call, saw {len(calls)}"
    assert calls[0][1] == 4                      # chunk grid axis
    np.testing.assert_array_equal(np.asarray(got), np.asarray(sub))


def test_t_blocked_decode_matches_single_block(perpos_case):
    """Blocking the T axis through VMEM (t_block < T) must not change a
    single bit or probe: decoder state carries across blocks in scratch."""
    tbl, syms = perpos_case
    t = syms.shape[1]
    enc = coder.encode(syms, tbl)
    pred = NeighborAverage(window=4, delta=8)
    whole = ops.rans_decode(enc, t, tbl, predictor=pred, lane_probes=True)
    for t_block in (7, 16, t):
        blocked = ops.rans_decode(enc, t, tbl, predictor=pred,
                                  t_block=t_block, lane_probes=True)
        _assert_identical(blocked, whole, syms)


# ---------------------------------------------------------------------------
# canonical probe accounting (core/search.py docstring rules)
# ---------------------------------------------------------------------------

def test_window_probe_skipped_on_candidate_hit(rans_case):
    """Rule 2: a lane resolved by candidate speculation does not pay the
    window verify — total cost of an oracle first candidate is exactly 1
    probe even when a window predictor is also active."""
    tbl, syms = rans_case(73, k=64, lanes=4, t=1)
    enc = coder.encode(jnp.asarray(syms), tbl)
    st = coder.decoder_init(coder.EncodedLanes(*enc))
    cand = jnp.asarray(syms[:, 0], jnp.int32)[:, None]
    mu = jnp.zeros((4,), jnp.int32)
    _, x, probes = coder.decode_get(st, enc.buf, tbl, candidates=cand,
                                    mu=mu, delta=4)
    np.testing.assert_array_equal(np.asarray(x), syms[:, 0])
    np.testing.assert_array_equal(np.asarray(probes), 1)


def test_bracket_miss_accounting_symmetry():
    """The window-verify probe is charged identically on hit and miss in
    both backends: force guaranteed misses (ZeroPredictor, delta=0, symbols
    far from zero) and pin per-lane integer equality."""
    rng = np.random.default_rng(74)
    k, lanes, t = 64, 8, 40
    probs = np.full(k, 1e-6)
    probs[40:] = 1.0                      # mass far from the zero anchor
    tbl = spc.tables_from_probs(jnp.asarray(probs / probs.sum(), jnp.float32))
    syms = jnp.asarray(rng.integers(40, k, (lanes, t)), jnp.int32)
    enc = coder.encode(syms, tbl)
    pred = ZeroPredictor(delta=0)
    got = ops.rans_decode(enc, t, tbl, predictor=pred, lane_probes=True)
    want = coder.decode(enc, t, tbl, predictor=pred, lane_probes=True)
    _assert_identical(got, want, syms)
    # every symbol missed the bracket: cost >= baseline (verify + search)
    base = coder.decode(enc, t, tbl, lane_probes=True)
    assert (np.asarray(got[2]) >= np.asarray(base[2])).all()


# ---------------------------------------------------------------------------
# Fig. 4(b) probe-count regression: speculation must keep paying off
# ---------------------------------------------------------------------------

def test_fig4b_speculation_probe_regression(rans_case):
    """Pins the Fig. 4(b) trajectory on a seeded stream.

    Baseline binary search over K=256 costs ~7 probes/symbol (paper: 7.00);
    model-top-k speculation with a realistic 80% top-1 hit rate must land
    in the paper's guided band (~3.15: hits pay 1 verify, misses pay the
    bounded penalty), and the per-lane counters must be integer-identical
    between coder and kernel on the monolithic AND the chunked
    single-launch path.  A perturbed accounting rule — an extra or missing
    probe anywhere — shifts the integer counters and fails this loudly.
    """
    k, t, topk = 256, 128, 4
    tbl, syms = rans_case(85, k=k, lanes=8, t=t)
    syms = jnp.asarray(syms, jnp.int32)
    cands = _candidate_planes(syms, k, topk=topk, hit_rate=0.8, seed=86)
    enc = coder.encode(syms, tbl)

    base = coder.decode(enc, t, tbl, lane_probes=True)
    spec = coder.decode(enc, t, tbl, candidates=cands, lane_probes=True)
    kspec = ops.rans_decode(enc, t, tbl, candidates=cands, lane_probes=True)
    _assert_identical(kspec, spec, syms)

    base_avg, spec_avg = float(base[1]), float(spec[1])
    # Fig. 4(b): ~7.00 baseline -> ~3.15 guided (bands, not exact floats —
    # the integer counters above are the exact pin)
    assert 6.0 <= base_avg <= 8.0, base_avg
    assert 2.5 <= spec_avg <= 4.5, spec_avg
    assert spec_avg < 0.55 * base_avg, (spec_avg, base_avg)

    # same contract on the chunked single-pallas_call path (ragged tail)
    ch = coder.encode_chunked(syms, tbl, 48)
    cspec = coder.decode_chunked(ch, t, tbl, 48, candidates=cands,
                                 lane_probes=True)
    kchunk = ops.rans_decode_chunked(ch, t, tbl, 48, candidates=cands,
                                     lane_probes=True)
    _assert_identical(kchunk, cspec, syms)
    cbase = coder.decode_chunked(ch, t, tbl, 48, lane_probes=True)
    assert float(cspec[1]) < 0.55 * float(cbase[1])


def test_fig4b_probe_count_monotone_in_hit_rate(rans_case):
    """More accurate speculation can only help: mean probes decrease
    monotonically with the candidate top-1 hit rate, identically on both
    backends (the regression guard for the speculation *trend*, not just
    one point)."""
    k, t = 64, 64
    tbl, syms = rans_case(87, k=k, lanes=4, t=t)
    syms = jnp.asarray(syms, jnp.int32)
    enc = coder.encode(syms, tbl)
    totals = []
    for hit_rate in (0.0, 0.5, 0.9):
        cands = _candidate_planes(syms, k, topk=4, hit_rate=hit_rate,
                                  seed=88)
        got = ops.rans_decode(enc, t, tbl, candidates=cands,
                              lane_probes=True)
        want = coder.decode(enc, t, tbl, candidates=cands, lane_probes=True)
        _assert_identical(got, want, syms)
        totals.append(int(np.asarray(got[2]).sum()))
    assert totals[0] > totals[1] > totals[2], totals


def test_topk0_plane_equals_no_speculation(rans_case):
    """topk=0 candidate planes are the explicit 'no speculation' sweep
    point: identical counters to passing no plane at all, on both
    backends."""
    tbl, syms = rans_case(89, k=64, lanes=4, t=32)
    syms = jnp.asarray(syms, jnp.int32)
    enc = coder.encode(syms, tbl)
    empty = jnp.zeros((32, 4, 0), jnp.int32)
    base = coder.decode(enc, 32, tbl, lane_probes=True)
    for got in (coder.decode(enc, 32, tbl, candidates=empty,
                             lane_probes=True),
                ops.rans_decode(enc, 32, tbl, candidates=empty,
                                lane_probes=True)):
        _assert_identical(got, base, syms)


# ---------------------------------------------------------------------------
# predictor edge cases: bit-exact, safe fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("predictor", [
    NeighborAverage(window=2, delta=0),       # delta=0: single-symbol bracket
    NeighborAverage(window=64, delta=8),      # window > T: mostly-empty ctx
    LastValue(delta=0),
], ids=["delta0", "window_gt_T", "last_delta0"])
def test_predictor_edge_configs_bit_exact(rans_case, predictor):
    tbl, syms = rans_case(75, k=64, lanes=4, t=16)
    enc = coder.encode(jnp.asarray(syms), tbl)
    got = ops.rans_decode(enc, 16, tbl, predictor=predictor,
                          lane_probes=True)
    want = coder.decode(enc, 16, tbl, predictor=predictor, lane_probes=True)
    _assert_identical(got, want, syms)


def test_all_empty_context_first_symbol(rans_case):
    """t=1: the context holds no decoded symbols yet (all -1 slots) — the
    neighbour average must fall back to the zero anchor and stay exact."""
    tbl, syms = rans_case(76, k=64, lanes=4, t=1)
    enc = coder.encode(jnp.asarray(syms), tbl)
    for pred in (NeighborAverage(4, 8), NeighborAverage(8, 0)):
        got, _, gl = ops.rans_decode(enc, 1, tbl, predictor=pred,
                                     lane_probes=True)
        want, _, wl = coder.decode(enc, 1, tbl, predictor=pred,
                                   lane_probes=True)
        np.testing.assert_array_equal(np.asarray(got), syms)
        np.testing.assert_array_equal(np.asarray(gl), np.asarray(wl))


def test_candidates_duplicates_and_out_of_alphabet(rans_case):
    """ModelTopK-style candidate lists with duplicate, out-of-alphabet and
    negative ids: every verify stays in-bounds (ids clip to [0, K)) and the
    decode falls back to the exact search."""
    k = 64
    tbl, syms = rans_case(77, k=k, lanes=4, t=1)
    enc = coder.encode(jnp.asarray(syms), tbl)
    st = coder.decoder_init(coder.EncodedLanes(*enc))
    wrong = (syms[:, 0] + 7) % k
    cands = jnp.stack([
        jnp.asarray(wrong, jnp.int32),
        jnp.asarray(wrong, jnp.int32),              # duplicate
        jnp.full((4,), k + 9, jnp.int32),           # out of alphabet
        jnp.full((4,), -3, jnp.int32),              # negative id
        jnp.full((4,), 10 ** 6, jnp.int32),         # absurdly large
    ], axis=1)
    _, x, probes = coder.decode_get(st, enc.buf, tbl, candidates=cands)
    np.testing.assert_array_equal(np.asarray(x), syms[:, 0])
    # all 5 candidate verifies paid (none can resolve unless clipping lands
    # on the true symbol), then the exact fallback search
    assert int(np.asarray(probes).min()) >= 5


def test_candidate_duplicate_of_truth_charges_once(rans_case):
    """A duplicated *correct* candidate resolves on the first copy; the
    second copy is free (rule 1: resolved lanes stop paying)."""
    tbl, syms = rans_case(78, k=64, lanes=4, t=1)
    enc = coder.encode(jnp.asarray(syms), tbl)
    st = coder.decoder_init(coder.EncodedLanes(*enc))
    truth = jnp.asarray(syms[:, 0], jnp.int32)
    cands = jnp.stack([truth, truth, truth], axis=1)
    _, x, probes = coder.decode_get(st, enc.buf, tbl, candidates=cands)
    np.testing.assert_array_equal(np.asarray(x), syms[:, 0])
    np.testing.assert_array_equal(np.asarray(probes), 1)


def test_predictor_configs_are_type_distinct_static_keys():
    """Predictor configs are static jit/trace-cache keys; bare-NamedTuple
    equality made ``LastValue(8) == ZeroPredictor(8)`` and let a decode
    traced with one serve the other's program (right symbols, wrong probe
    accounting — the cross-backend differential above only caught it when
    the two backends desynced).  The keys must be type-tagged."""
    assert LastValue(delta=8) != ZeroPredictor(delta=8)
    assert hash(LastValue(delta=8)) != hash(ZeroPredictor(delta=8))
    assert NeighborAverage(2, 4) != (2, 4)
    assert (2, 4) != NeighborAverage(2, 4)       # reflected op, tuple on LHS
    assert LastValue(delta=8) == LastValue(delta=8)
    assert hash(NeighborAverage(4, 8)) == hash(NeighborAverage(4, 8))


def test_zero_after_lastvalue_trace_order_stays_exact(rans_case):
    """The trace-order regression behind the key fix: LastValue first, then
    ZeroPredictor at identical shapes in the same process — the second trace
    must NOT reuse the first's program on either backend."""
    tbl, syms = rans_case(70, k=64, lanes=8, t=64)
    enc = coder.encode(jnp.asarray(syms), tbl)
    probes = {}
    for pred in (LastValue(delta=8), ZeroPredictor(delta=8)):
        got = ops.rans_decode(enc, 64, tbl, predictor=pred, lane_probes=True)
        want = ref.rans_decode_ref(enc, 64, tbl, predictor=pred,
                                   lane_probes=True)
        _assert_identical(got, want, syms)
        probes[type(pred).__name__] = np.asarray(got[2])
    # distinct programs: anchor-by-last and anchor-at-zero pay different
    # probe bills on this stream (equal bills would mean a shared trace)
    assert not np.array_equal(probes["LastValue"], probes["ZeroPredictor"])


# ---------------------------------------------------------------------------
# structural guard: no private search/predictor logic outside core/search.py
# ---------------------------------------------------------------------------

def test_kernel_and_ref_have_no_private_search_logic():
    ksrc = inspect.getsource(rans_decode)
    rsrc = inspect.getsource(ref)
    for src, name in ((ksrc, "kernels/rans_decode.py"),
                      (rsrc, "kernels/ref.py")):
        assert "_bsearch" not in src, f"{name} reimplements the CDF search"
        assert "go_right" not in src, f"{name} reimplements the CDF search"
    # the kernel consumes the shared core and the predictor protocol
    assert "from repro.core import search" in ksrc
    assert "predictor.predict" in ksrc and "predictor.update" in ksrc
    # ref delegates to the coder (itself a core.search consumer)
    assert "coder.decode" in rsrc
    # and the coder's own search lives in core/search.py only
    csrc = inspect.getsource(coder)
    assert "go_right" not in csrc
    assert "search.find_symbol" in csrc


def test_search_module_is_single_source_of_probe_rules():
    doc = search.__doc__
    for anchor in ("Sec. IV-C", "Fig. 2", "Fig. 4(b)",
                   "Canonical probe accounting"):
        assert anchor in doc
