"""Bit-exactness + property tests for the core rANS pipeline (T1/T2/T3/T4).

Property coverage (formerly hypothesis ``@given``) now runs as vendored
deterministic seeded sweeps — see ``tests/_prop.py``.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (barrett_div, bitstream, coder, constants as C,
                        decode_lut, golden, python_baseline, spc, umulhi32)
from repro.core.predictors import (LastValue, NeighborAverage, ZeroPredictor,
                                   model_topk_candidates)

from _prop import floats, ints, seeds, sweep


# ---------------------------------------------------------------------------
# arithmetic primitives
# ---------------------------------------------------------------------------

def test_umulhi32_exact():
    """200 random (a, b) pairs + corner anchors: exact high-32 product."""
    cases = [(int(ints(r, 0, 2**32 - 1)), int(ints(r, 0, 2**32 - 1)))
             for r in sweep(101, 200)]
    m = 2**32 - 1
    cases += [(0, 0), (0, m), (m, m), (1, m), (m, 1), (2**31, 2),
              (2**16, 2**16), (2**16 - 1, 2**16 + 1)]
    a = jnp.asarray([c[0] for c in cases], jnp.uint32)
    b = jnp.asarray([c[1] for c in cases], jnp.uint32)
    got = np.asarray(umulhi32(a, b))
    want = np.asarray([(x * y) >> 32 for x, y in cases], np.uint32)
    np.testing.assert_array_equal(got, want)


def test_barrett_division_exact():
    """200 random (f, s) pairs: Barrett mulhi-shift == floor division."""
    total = 1 << C.PROB_BITS
    cases = [(int(ints(r, 2, total)), int(ints(r, 0, 2**31 - 1)))
             for r in sweep(102, 200)]
    freq = jnp.asarray([[f, total - f] for f, _ in cases], jnp.uint32)
    tbl = spc.build_tables(freq)        # batched: fields (n, 2)
    s = jnp.asarray([s for _, s in cases], jnp.uint32)
    q = np.asarray(barrett_div(s, tbl.rcp[:, 0], tbl.rshift[:, 0]))
    want = np.asarray([s // f for f, s in cases], np.uint32)
    np.testing.assert_array_equal(q, want)


def test_barrett_edge_states():
    """Exhaustive boundary sweep: states near renorm thresholds, all shifts."""
    total = 1 << C.PROB_BITS
    freqs = [2, 3, 4, 5, 7, 8, 9, 255, 256, 257, 4095, 4096, 4097,
             total // 2, total - 1]
    for f in freqs:
        tbl = spc.build_tables(jnp.asarray([f, total - f], jnp.uint32))
        edge = [0, 1, f - 1, f, f + 1, 2**31 - 1, 2**31 - f,
                C.RANS_L, C.STATE_UPPER - 1]
        s = jnp.asarray(edge, jnp.uint32)
        q = barrett_div(s, tbl.rcp[0], tbl.rshift[0])
        np.testing.assert_array_equal(np.asarray(q), np.asarray(edge) // f)


# ---------------------------------------------------------------------------
# SPC: quantization + mass correction (paper Sec. IV-A)
# ---------------------------------------------------------------------------

def test_spc_mass_exact():
    """50 random (k, conc, seed) dirichlet draws: exact mass, f >= 1."""
    for r in sweep(103, 50):
        k = int(ints(r, 2, 300))
        conc = float(floats(r, 0.05, 5.0))
        probs = r.dirichlet(np.full(k, conc))
        f = np.asarray(spc.quantize_probs(jnp.asarray(probs, jnp.float32)))
        assert f.sum() == 1 << C.PROB_BITS, (k, conc)
        assert f.min() >= 1, (k, conc)


def test_spc_mass_pathological():
    total = 1 << C.PROB_BITS
    cases = [
        np.full(total, 1.0 / total),           # uniform at capacity
        np.r_[1.0, np.zeros(100)],             # single spike + zeros
        np.r_[np.full(50, 1e-9), [1.0]],       # tiny probs force f=1 floor
        np.full(3, 1 / 3),                     # rounding ties
    ]
    for p in cases:
        f = np.asarray(spc.quantize_probs(jnp.asarray(p, jnp.float32)))
        assert f.sum() == total, p[:4]
        assert f.min() >= 1


def test_spc_deterministic():
    rng = np.random.default_rng(7)
    p = jnp.asarray(rng.dirichlet(np.ones(64)), jnp.float32)
    f1 = np.asarray(spc.quantize_probs(p))
    f2 = np.asarray(jax.jit(spc.quantize_probs)(p))
    np.testing.assert_array_equal(f1, f2)


def _quantize_probs_four_sort(probs, prob_bits=C.PROB_BITS):
    """The original four-argsort mass correction: the reference the
    single-sort rewrite in :func:`spc.quantize_probs` must reproduce bit
    for bit (ascending ranks are positions; descending ranks follow from
    tie-run bookkeeping; inverse permutations become scatters)."""
    total = 1 << prob_bits
    k = probs.shape[-1]
    p = probs.astype(jnp.bfloat16).astype(jnp.float32)
    p = jnp.where(jnp.isfinite(p) & (p > 0), p, 0.0)
    scaled = p * jnp.float32(total)
    f0 = jnp.maximum(1, jnp.round(scaled)).astype(jnp.int32)
    delta = total - jnp.sum(f0, axis=-1, keepdims=True)
    resid = scaled - f0.astype(jnp.float32)
    order_desc = jnp.argsort(-resid, axis=-1, stable=True)
    rank_desc = jnp.argsort(order_desc, axis=-1, stable=True)
    f_pos = f0 + delta // k + (rank_desc < delta % k).astype(jnp.int32)
    need = (-delta).astype(jnp.int32)
    order_asc = jnp.argsort(resid, axis=-1, stable=True)
    cap_sorted = jnp.take_along_axis(f0 - 1, order_asc, axis=-1)
    cum_excl = jnp.cumsum(cap_sorted, axis=-1) - cap_sorted
    take_sorted = jnp.clip(need - cum_excl, 0, cap_sorted)
    rank_asc = jnp.argsort(order_asc, axis=-1, stable=True)
    take = jnp.take_along_axis(take_sorted, rank_asc, axis=-1)
    f_neg = f0 - take
    return jnp.where(delta >= 0, f_pos, f_neg).astype(jnp.uint32)


def test_spc_single_sort_matches_four_sort_reference():
    """quantize_probs (one stable sort + scatters) is bitwise the four-
    argsort largest-remainder rule, across adversarial tie patterns:
    all-equal rows (every element one tie run), near-uniform dirichlet
    (dense rounding ties), spiky distributions (deep waterfill), tiny
    k, and batched 3-d inputs."""
    rng = np.random.default_rng(23)
    cases = [np.full(256, 1.0 / 256), np.full(3, 1 / 3),
             np.r_[1.0, np.zeros(255)],
             np.r_[np.full(200, 1e-9), rng.dirichlet(np.ones(56))]]
    for r in sweep(104, 40):
        k = int(ints(r, 2, 400))
        conc = float(floats(r, 0.02, 8.0))
        cases.append(r.dirichlet(np.full(k, conc)))
    cases.append(rng.dirichlet(np.ones(64), size=(3, 5)))  # 3-d batch
    for p in cases:
        p = jnp.asarray(p, jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(spc.quantize_probs(p)),
            np.asarray(_quantize_probs_four_sort(p)))


def test_spc_batched_matches_single():
    rng = np.random.default_rng(3)
    p = rng.dirichlet(np.ones(32), size=5).astype(np.float32)
    fb = np.asarray(spc.quantize_probs(jnp.asarray(p)))
    for i in range(5):
        fi = np.asarray(spc.quantize_probs(jnp.asarray(p[i])))
        np.testing.assert_array_equal(fb[i], fi)


def test_decode_lut_matches_cdf():
    rng = np.random.default_rng(11)
    tbl = spc.tables_from_probs(jnp.asarray(rng.dirichlet(np.ones(40)),
                                            jnp.float32))
    lut = np.asarray(decode_lut(tbl))
    cdf = np.asarray(tbl.cdf)
    for slot in [0, 1, 5, 100, (1 << C.PROB_BITS) - 1]:
        x = int(np.searchsorted(cdf, slot, side="right") - 1)
        assert lut[slot] == x


# ---------------------------------------------------------------------------
# bit-exactness: golden == python baseline == JAX lanes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2])
def test_jax_encode_bit_exact_vs_golden(rans_case, seed):
    tbl, syms = rans_case(seed)
    f, cdf = np.asarray(tbl.freq), np.asarray(tbl.cdf)
    enc = coder.encode(jnp.asarray(syms), tbl)
    buf, start, length, _ = map(np.asarray, enc)
    for i in range(syms.shape[0]):
        ref = golden.encode(syms[i], f, cdf)
        got = buf[i, start[i]:start[i] + length[i]].tobytes()
        assert got == ref, f"lane {i} bitstream mismatch"


def test_python_baseline_bit_exact_vs_golden(rans_case):
    tbl, syms = rans_case(4, lanes=1)
    f, cdf = np.asarray(tbl.freq), np.asarray(tbl.cdf)
    ref = golden.encode(syms[0], f, cdf)
    pr = python_baseline.PyRans(f, cdf)
    assert pr.encode([int(x) for x in syms[0]]) == ref
    assert pr.decode(ref, syms.shape[1]) == [int(x) for x in syms[0]]


@pytest.mark.parametrize("seed", seeds(104, 15))
def test_roundtrip_property(rans_case, seed):
    """15 deterministic seeds (was a hypothesis @given over 31-bit seeds)."""
    tbl, syms = rans_case(seed, k=64, lanes=2, t=128)
    enc = coder.encode(jnp.asarray(syms), tbl)
    dec, _ = coder.decode(enc, syms.shape[1], tbl)
    np.testing.assert_array_equal(np.asarray(dec), syms)


def test_roundtrip_skewed_distributions():
    """near-deterministic + heavy-tail distributions stress f=1 and f=max."""
    k, lanes, t = 256, 4, 300
    rng = np.random.default_rng(5)
    p = np.full(k, 1e-9)
    p[7] = 1.0
    p /= p.sum()
    tbl = spc.tables_from_probs(jnp.asarray(p, jnp.float32))
    syms = np.where(rng.random((lanes, t)) < 0.98, 7,
                    rng.integers(0, k, (lanes, t)))
    enc = coder.encode(jnp.asarray(syms), tbl)
    dec, _ = coder.decode(enc, t, tbl)
    np.testing.assert_array_equal(np.asarray(dec), syms)
    # skewed stream must compress far below 1 byte/symbol
    assert float(np.asarray(enc.length).mean()) < 0.5 * t


def test_roundtrip_tiny_and_binary_alphabets():
    for k in (2, 3, 5):
        rng = np.random.default_rng(k)
        tbl = spc.tables_from_probs(
            jnp.asarray(rng.dirichlet(np.ones(k)), jnp.float32))
        syms = rng.integers(0, k, (2, 64))
        enc = coder.encode(jnp.asarray(syms), tbl)
        dec, _ = coder.decode(enc, 64, tbl)
        np.testing.assert_array_equal(np.asarray(dec), syms)


# ---------------------------------------------------------------------------
# per-position (neural prior) tables
# ---------------------------------------------------------------------------

def test_per_position_roundtrip_and_golden():
    rng = np.random.default_rng(9)
    k, lanes, t = 48, 2, 100
    probs = rng.dirichlet(np.ones(k) * 0.5, size=t).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))  # (T, K) tables
    syms = rng.integers(0, k, (lanes, t))
    enc = coder.encode(jnp.asarray(syms), tbl)
    buf, start, length, _ = map(np.asarray, enc)
    f, cdf = np.asarray(tbl.freq), np.asarray(tbl.cdf)
    for i in range(lanes):
        ref = golden.encode_per_position(syms[i], f, cdf)
        got = buf[i, start[i]:start[i] + length[i]].tobytes()
        assert got == ref
        back = golden.decode_per_position(ref, f, cdf)
        np.testing.assert_array_equal(back, syms[i])
    dec, _ = coder.decode(enc, t, tbl)
    np.testing.assert_array_equal(np.asarray(dec), syms)


# ---------------------------------------------------------------------------
# prediction-guided decoding (T3): exactness + probe accounting
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("predictor", [
    NeighborAverage(window=4, delta=8),
    NeighborAverage(window=2, delta=4),
    LastValue(delta=8),
    ZeroPredictor(delta=8),
])
def test_guided_decode_bit_exact(rans_case, predictor):
    tbl, syms = rans_case(12, k=256, lanes=3, t=200)
    enc = coder.encode(jnp.asarray(syms), tbl)
    base, base_probes = coder.decode(enc, syms.shape[1], tbl)
    guided, probes = coder.decode(enc, syms.shape[1], tbl,
                                  predictor=predictor)
    np.testing.assert_array_equal(np.asarray(guided), np.asarray(base))
    np.testing.assert_array_equal(np.asarray(guided), syms)
    assert float(probes) > 0


def test_guided_decode_reduces_probes_on_smooth_data():
    """Fig. 4(b): neighbour-average speculation must cut probes on
    spatially-correlated (image-like) symbols."""
    rng = np.random.default_rng(21)
    k, lanes, t = 256, 8, 512
    # smooth random walk clipped to [0, 255] — image-row-like
    steps = rng.integers(-3, 4, (lanes, t))
    syms = np.clip(128 + np.cumsum(steps, axis=1), 0, k - 1)
    counts = np.bincount(syms.ravel(), minlength=k)
    tbl = spc.tables_from_counts_np(counts)
    tbl = jax.tree.map(jnp.asarray, tbl)
    enc = coder.encode(jnp.asarray(syms), tbl)
    base, base_probes = coder.decode(enc, t, tbl)
    guided, probes = coder.decode(enc, t, tbl,
                                  predictor=NeighborAverage(4, 8))
    np.testing.assert_array_equal(np.asarray(guided), syms)
    assert float(probes) < 0.75 * float(base_probes), (
        float(probes), float(base_probes))


def test_candidate_speculation_single_probe_when_right(rans_case):
    """Model-top-k path: a correct first candidate costs exactly 1 probe."""
    tbl, syms = rans_case(31, k=64, lanes=4, t=1)
    enc = coder.encode(jnp.asarray(syms), tbl)
    st = coder.decoder_init(coder.EncodedLanes(*enc))
    cand = jnp.asarray(syms[:, 0], jnp.int32)[:, None]  # oracle candidate
    _, x, probes = coder.decode_get(st, enc.buf, tbl, candidates=cand)
    np.testing.assert_array_equal(np.asarray(x), syms[:, 0])
    np.testing.assert_array_equal(np.asarray(probes), 1)


def test_candidate_speculation_fallback_is_exact(rans_case):
    tbl, syms = rans_case(32, k=64, lanes=4, t=1)
    enc = coder.encode(jnp.asarray(syms), tbl)
    st = coder.decoder_init(coder.EncodedLanes(*enc))
    wrong = jnp.asarray((syms[:, 0] + 7) % 64, jnp.int32)[:, None]
    _, x, probes = coder.decode_get(st, enc.buf, tbl, candidates=wrong)
    np.testing.assert_array_equal(np.asarray(x), syms[:, 0])
    assert int(np.asarray(probes).min()) >= 2  # failed verify + search


def test_model_topk_candidates_shape():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(5, 100)),
                         jnp.float32)
    c = model_topk_candidates(logits, 4)
    assert c.shape == (5, 4)
    np.testing.assert_array_equal(np.asarray(c[:, 0]),
                                  np.argmax(np.asarray(logits), -1))


# ---------------------------------------------------------------------------
# container
# ---------------------------------------------------------------------------

def test_container_roundtrip(rans_case):
    tbl, syms = rans_case(40, k=100, lanes=5, t=150)
    enc = coder.encode(jnp.asarray(syms), tbl)
    blob = bitstream.pack(*map(np.asarray, enc), n_symbols=syms.shape[1])
    buf, start, meta = bitstream.unpack(blob)
    assert meta.lanes == 5 and meta.n_symbols == 150
    enc2 = coder.EncodedLanes(jnp.asarray(buf), jnp.asarray(start),
                              jnp.asarray(buf.shape[1] - start))
    dec, _ = coder.decode(enc2, 150, tbl)
    np.testing.assert_array_equal(np.asarray(dec), syms)
    assert bitstream.compressed_size(np.asarray(enc.length)) == len(blob)


def test_container_rejects_garbage():
    with pytest.raises(ValueError):
        bitstream.unpack(b"NOPE" + b"\x00" * 32)


# ---------------------------------------------------------------------------
# §Perf paths: records-based encode (TPU layout) and O(1) LUT decode
# ---------------------------------------------------------------------------

def test_encode_records_bit_exact(rans_case):
    tbl, syms = rans_case(51, k=128, lanes=4, t=200)
    a = coder.encode(jnp.asarray(syms), tbl)
    b = coder.encode_records(jnp.asarray(syms), tbl)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_encode_records_per_position_bit_exact():
    rng = np.random.default_rng(8)
    k, lanes, t = 32, 3, 64
    probs = rng.dirichlet(np.ones(k), size=t).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))
    syms = rng.integers(0, k, (lanes, t))
    a = coder.encode(jnp.asarray(syms), tbl)
    b = coder.encode_records(jnp.asarray(syms), tbl)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_decode_lut_matches_bsearch(rans_case):
    tbl, syms = rans_case(52, k=200, lanes=4, t=150)
    enc = coder.encode(jnp.asarray(syms), tbl)
    a, _ = coder.decode(enc, syms.shape[1], tbl)
    b, probes = coder.decode(enc, syms.shape[1], tbl, use_lut=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(b), syms)
    assert abs(float(probes) - 1.0) < 1e-6  # exactly one probe per symbol
