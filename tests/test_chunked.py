"""Chunked streaming codec: bit-exactness, containers, sharding, serving.

Acceptance pins (ISSUE 1): every chunk's byte stream equals a standalone
``coder.encode`` of that chunk; roundtrips hold for chunk sizes
{1, 17, T, T+1} including ragged tails; v1 blobs still unpack; and the
shard_map placement (single-device mesh) matches the vmap path
symbol-for-symbol.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream, coder, spc
from repro.parallel import chunked as pchunked

T = 131           # prime-ish so every chunk size below exercises a ragged tail


@pytest.fixture(scope="module")
def case(rans_case):
    tbl, syms = rans_case(60, k=64, lanes=3, t=T)
    return tbl, jnp.asarray(syms, jnp.int32)


@pytest.fixture(scope="module")
def per_position_case():
    rng = np.random.default_rng(61)
    k, lanes = 32, 3
    probs = rng.dirichlet(np.ones(k), size=T).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))
    syms = jnp.asarray(rng.integers(0, k, (lanes, T)), jnp.int32)
    return tbl, syms


# ---------------------------------------------------------------------------
# bit-exactness: chunk == standalone encode; roundtrip across chunk sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_size", [1, 17, T, T + 1])
def test_chunks_equal_standalone_encode(case, chunk_size):
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, chunk_size)
    cap = ch.buf.shape[-1]
    assert ch.buf.shape[0] == coder.num_chunks(T, chunk_size)
    for c, n in enumerate(coder.chunk_lengths(T, chunk_size)):
        t0 = c * chunk_size
        std = coder.encode(syms[:, t0:t0 + n], tbl, cap=cap)
        got = coder.chunk_encoded(ch, c)
        np.testing.assert_array_equal(np.asarray(got.buf),
                                      np.asarray(std.buf))
        np.testing.assert_array_equal(np.asarray(got.start),
                                      np.asarray(std.start))
        np.testing.assert_array_equal(np.asarray(got.length),
                                      np.asarray(std.length))


@pytest.mark.parametrize("chunk_size", [1, 17, T, T + 1])
def test_chunked_roundtrip(case, chunk_size):
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, chunk_size)
    dec, probes = coder.decode_chunked(ch, T, tbl, chunk_size)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))
    assert float(probes) > 0


@pytest.mark.parametrize("chunk_size", [17, 64])
def test_chunked_roundtrip_per_position(per_position_case, chunk_size):
    """Neural-prior layout: per-position tables split chunk-major."""
    tbl, syms = per_position_case
    ch = coder.encode_chunked(syms, tbl, chunk_size)
    dec, _ = coder.decode_chunked(ch, T, tbl, chunk_size)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))
    # chunk bytes == standalone encode against the matching table slice
    cap = ch.buf.shape[-1]
    for c, n in enumerate(coder.chunk_lengths(T, chunk_size)):
        t0 = c * chunk_size
        tbl_c = jax.tree.map(lambda a: a[t0:t0 + n], tbl)
        std = coder.encode(syms[:, t0:t0 + n], tbl_c, cap=cap)
        got = coder.chunk_encoded(ch, c)
        np.testing.assert_array_equal(np.asarray(got.buf),
                                      np.asarray(std.buf))


def test_chunked_lut_decode(case):
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, 17)
    dec, probes = coder.decode_chunked(ch, T, tbl, 17, use_lut=True)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))
    assert abs(float(probes) - 1.0) < 1e-6


# ---------------------------------------------------------------------------
# containers: v2 roundtrip + v1 back-compat
# ---------------------------------------------------------------------------

def test_container_v2_roundtrip(case):
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, 17)
    blob = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=17,
                                  n_symbols=T)
    buf, start, meta = bitstream.unpack_chunked(blob)
    assert (meta.lanes, meta.n_symbols, meta.chunk_size) == (3, T, 17)
    assert meta.n_chunks == coder.num_chunks(T, 17)
    ch2 = coder.ChunkedLanes(jnp.asarray(buf), jnp.asarray(start),
                             jnp.asarray(buf.shape[-1] - start))
    dec, _ = coder.decode_chunked(ch2, T, tbl, 17)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))
    assert bitstream.compressed_size_chunked(
        np.asarray(ch.length)) == len(blob)


def test_container_v1_still_unpacks(case):
    """Back-compat: pre-chunking archives read via both entry points."""
    tbl, syms = case
    enc = coder.encode(syms, tbl)
    blob = bitstream.pack(*map(np.asarray, enc), n_symbols=T)
    # the classic v1 reader
    buf, start, meta = bitstream.unpack(blob)
    enc2 = coder.EncodedLanes(jnp.asarray(buf), jnp.asarray(start),
                              jnp.asarray(buf.shape[1] - start))
    dec, _ = coder.decode(enc2, T, tbl)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))
    # the chunked reader presents a v1 blob as one chunk
    cbuf, cstart, cmeta = bitstream.unpack_chunked(blob)
    assert cmeta.n_chunks == 1 and cmeta.n_symbols == T
    ch = coder.ChunkedLanes(jnp.asarray(cbuf), jnp.asarray(cstart),
                            jnp.asarray(cbuf.shape[-1] - cstart))
    dec2, _ = coder.decode_chunked(ch, T, tbl, cmeta.chunk_size)
    np.testing.assert_array_equal(np.asarray(dec2), np.asarray(syms))


def test_container_v2_checksum_detects_corruption(case):
    """Default v2 blobs carry per-(chunk, lane) CRC32s; a flipped payload
    byte fails unpack with an error naming the corrupt cell."""
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, 17)
    blob = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=17,
                                  n_symbols=T)
    # locate the payload start and cell (chunk 1, lane 1)'s first byte
    lanes, cells = 3, coder.num_chunks(T, 17) * 3
    base = bitstream._HEADER_V2.size + cells * bitstream._INDEX_V2C_DT.itemsize
    lengths = np.asarray(ch.length).reshape(-1)
    cell = 1 * lanes + 1
    off = base + int(lengths[:cell].sum())
    corrupt = bytearray(blob)
    corrupt[off] ^= 0xFF
    with pytest.raises(ValueError, match="chunk 1, lane 1"):
        bitstream.unpack_chunked(bytes(corrupt))
    # the pristine blob still unpacks and roundtrips
    buf, start, meta = bitstream.unpack_chunked(blob)
    ch2 = coder.ChunkedLanes(jnp.asarray(buf), jnp.asarray(start),
                             jnp.asarray(buf.shape[-1] - start))
    dec, _ = coder.decode_chunked(ch2, T, tbl, 17)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))


def test_container_v2_checksumless_still_unpacks(case):
    """flags == 0 blobs (the pre-checksum v2 layout) remain readable, and
    corruption passes silently there — the integrity bit is opt-out."""
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, 17)
    blob = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=17,
                                  n_symbols=T, checksums=False)
    assert len(blob) == bitstream.compressed_size_chunked(
        np.asarray(ch.length), checksums=False)
    assert len(blob) < bitstream.compressed_size_chunked(
        np.asarray(ch.length))
    buf, start, meta = bitstream.unpack_chunked(blob)
    ch2 = coder.ChunkedLanes(jnp.asarray(buf), jnp.asarray(start),
                             jnp.asarray(buf.shape[-1] - start))
    dec, _ = coder.decode_chunked(ch2, T, tbl, 17)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))


@pytest.mark.parametrize("predictor", [None, "navg"])
def test_chunked_decode_predictor_matches_monolithic_symbols(case, predictor):
    """decode_chunked with a predictor: bit-exact symbols; probe totals
    match the kernel path (tested cross-backend in test_search_unified)."""
    from repro.core.predictors import NeighborAverage
    pred = NeighborAverage(4, 8) if predictor else None
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, 17)
    dec, probes = coder.decode_chunked(ch, T, tbl, 17, predictor=pred)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))
    assert float(probes) > 0


def test_unpack_rejects_v2_blob(case):
    tbl, syms = case
    ch = coder.encode_chunked(syms, tbl, 17)
    blob = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=17,
                                  n_symbols=T)
    with pytest.raises(ValueError, match="unpack_chunked"):
        bitstream.unpack(blob)
    with pytest.raises(ValueError):
        bitstream.unpack_chunked(b"NOPE" + b"\x00" * 32)


# ---------------------------------------------------------------------------
# shard_map placement: differential vs the vmap path
# ---------------------------------------------------------------------------

def test_shard_map_matches_vmap_single_device(case):
    """Single-device ("chunks",) mesh: shard_map == vmap, symbol-for-symbol
    and byte-for-byte."""
    tbl, syms = case
    mesh = pchunked.chunk_mesh()
    # chunk count divisible by mesh size -> the shard_map path is taken
    chunk_size = 17
    assert pchunked._usable(mesh, T // chunk_size)
    a = coder.encode_chunked(syms, tbl, chunk_size)
    b = pchunked.encode_chunked(syms, tbl, chunk_size, mesh=mesh)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    da, pa = coder.decode_chunked(a, T, tbl, chunk_size)
    db, pb = pchunked.decode_chunked(b, T, tbl, chunk_size, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(da), np.asarray(db))
    np.testing.assert_array_equal(np.asarray(da), np.asarray(syms))
    assert abs(float(pa) - float(pb)) < 1e-6


def test_shard_map_per_position(per_position_case):
    tbl, syms = per_position_case
    mesh = pchunked.chunk_mesh()
    ch = pchunked.encode_chunked(syms, tbl, 17, mesh=mesh)
    ref = coder.encode_chunked(syms, tbl, 17)
    for x, y in zip(ch, ref):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    dec, _ = pchunked.decode_chunked(ch, T, tbl, 17, mesh=mesh)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))


def test_shard_map_kernel_backend_matches_coder(case):
    """backend="kernel" routes every chunk through the Pallas decode kernel
    (interpret mode) under the same shard_map placement — byte- and
    probe-identical to the coder backend, ragged tail included."""
    from repro.core.predictors import NeighborAverage
    tbl, syms = case
    mesh = pchunked.chunk_mesh()
    ch = coder.encode_chunked(syms, tbl, 17)
    for pred in (None, NeighborAverage(4, 8)):
        a, pa = pchunked.decode_chunked(ch, T, tbl, 17, mesh=mesh,
                                        backend="kernel", predictor=pred)
        b, pb = pchunked.decode_chunked(ch, T, tbl, 17, mesh=mesh,
                                        backend="coder", predictor=pred)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(syms))
        assert abs(float(pa) - float(pb)) < 1e-5
    # the no-mesh kernel fallback (ops.rans_decode_chunked) agrees too
    c, pc = pchunked.decode_chunked(ch, T, tbl, 17, mesh=None,
                                    backend="kernel")
    np.testing.assert_array_equal(np.asarray(c), np.asarray(syms))
    with pytest.raises(ValueError, match="backend"):
        pchunked.decode_chunked(ch, T, tbl, 17, backend="nope")


@pytest.mark.slow
def test_shard_map_candidate_planes_parity(case):
    """Model-top-k candidate planes shard with the chunk slab (ISSUE 5
    satellite): ``parallel.decode_chunked(candidates=...)`` matches
    ``coder.decode_chunked(candidates=...)`` in symbols AND probe
    accounting on both backends, mesh and no-mesh, ragged tail included —
    and speculation actually cuts the probe count."""
    tbl, syms = case
    rng = np.random.default_rng(62)
    lanes, topk = syms.shape[0], 4
    # ~80% top-1 hits: candidate row 0 is the true symbol, else decoys
    truth = np.asarray(syms).T                              # (T, lanes)
    cands = rng.integers(0, 64, (T, lanes, topk))
    hit = rng.random((T, lanes)) < 0.8
    cands[..., 0] = np.where(hit, truth, cands[..., 0])
    cands = jnp.asarray(cands, jnp.int32)
    mesh = pchunked.chunk_mesh()
    ch = coder.encode_chunked(syms, tbl, 17)
    want, wp = coder.decode_chunked(ch, T, tbl, 17, candidates=cands)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(syms))
    base, bp = coder.decode_chunked(ch, T, tbl, 17)
    assert float(wp) < 0.75 * float(bp)     # speculation pays
    for backend in ("coder", "kernel"):
        for m in (mesh, None):
            got, gp = pchunked.decode_chunked(ch, T, tbl, 17, mesh=m,
                                              backend=backend,
                                              candidates=cands)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(syms))
            assert abs(float(gp) - float(wp)) < 1e-5, (backend, m)
    # topk == 0 planes disable speculation (baseline probe accounting)
    empty = jnp.zeros((T, lanes, 0), jnp.int32)
    got0, gp0 = pchunked.decode_chunked(ch, T, tbl, 17, mesh=mesh,
                                        candidates=empty)
    np.testing.assert_array_equal(np.asarray(got0), np.asarray(syms))
    assert abs(float(gp0) - float(bp)) < 1e-5
    with pytest.raises(ValueError, match="candidate planes"):
        pchunked.decode_chunked(ch, T, tbl, 17, mesh=mesh,
                                candidates=cands[:, :1])


def test_shard_map_candidate_planes_per_position(per_position_case):
    """Candidate rows and per-position table rows ride the same chunk-major
    sharding — probe parity holds for the neural-prior layout too."""
    tbl, syms = per_position_case
    rng = np.random.default_rng(63)
    lanes, topk = syms.shape[0], 2
    truth = np.asarray(syms).T
    cands = rng.integers(0, 32, (T, lanes, topk))
    hit = rng.random((T, lanes)) < 0.8
    cands[..., 0] = np.where(hit, truth, cands[..., 0])
    cands = jnp.asarray(cands, jnp.int32)
    mesh = pchunked.chunk_mesh()
    ch = coder.encode_chunked(syms, tbl, 17)
    want, wp = coder.decode_chunked(ch, T, tbl, 17, candidates=cands)
    for backend in ("coder", "kernel"):
        got, gp = pchunked.decode_chunked(ch, T, tbl, 17, mesh=mesh,
                                          backend=backend, candidates=cands)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(syms))
        assert abs(float(gp) - float(wp)) < 1e-5, backend


def test_sharded_fallback_paths(case):
    """None mesh and indivisible chunk counts silently take the vmap path."""
    tbl, syms = case
    a = pchunked.encode_chunked(syms, tbl, T + 1, mesh=None)
    ref = coder.encode_chunked(syms, tbl, T + 1)
    for x, y in zip(a, ref):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    dec, _ = pchunked.decode_chunked(a, T, tbl, T + 1, mesh=None)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))


# ---------------------------------------------------------------------------
# serve wiring: LM pipeline over chunked streams
# ---------------------------------------------------------------------------

def test_lm_chunked_roundtrip_bit_exact():
    from repro.configs import get_smoke_config
    from repro.data.pipeline import token_stream
    from repro.models import init_model
    from repro.serve.compress import (lm_compress_chunked,
                                      lm_decompress_chunked)
    cfg = get_smoke_config("ras-pimc")
    params = init_model(cfg, jax.random.PRNGKey(1))
    t, chunk = 40, 16                      # 2 full chunks + ragged tail of 8
    toks = jnp.asarray(token_stream(cfg.vocab_size, (2, t), seed=3),
                       jnp.int32)
    stats = lm_compress_chunked(params, cfg, toks, chunk_size=chunk)
    assert stats.chunks.buf.shape[0] == coder.num_chunks(t, chunk)
    dec, probes = lm_decompress_chunked(params, cfg, stats.chunks, t, chunk)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks))
    assert float(probes) > 0
    assert float(stats.bits_per_symbol) >= float(stats.model_xent_bits) - 0.05
