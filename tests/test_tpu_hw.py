"""Real-hardware kernel tier (``-m tpu``): interpret=False on a TPU.

Every other suite runs the Pallas kernels in interpret mode (this container
is CPU-only).  These tests compile the same kernels for real hardware
(``interpret=False``) and re-pin the cross-backend bit-exactness contract
there — run them on a TPU host with

    JAX_PLATFORMS=tpu pytest tests/test_tpu_hw.py -m tpu

(target this file alone: several other suites pin the CPU backend at import
time, and collection imports every module).  They skip (not fail) anywhere
else, so the tier is a no-op on CPU CI and a readiness gate on hardware.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import coder, spc
from repro.kernels import ops

pytestmark = [
    pytest.mark.tpu,
    pytest.mark.skipif(jax.default_backend() != "tpu",
                       reason="real-TPU tier: needs a TPU backend "
                              "(interpret=False)"),
]


def _case(seed, k, lanes, t):
    rng = np.random.default_rng(seed)
    tbl = spc.tables_from_probs(
        jnp.asarray(rng.dirichlet(np.ones(k) * 0.5), jnp.float32))
    return tbl, jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)


def test_encode_kernel_compiled_bit_exact():
    tbl, syms = _case(400, k=256, lanes=128, t=256)
    got = ops.rans_encode(syms, tbl, interpret=False)
    want = coder.encode(syms, tbl)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_encode_kernel_compiled_chunked_adaptive():
    rng = np.random.default_rng(401)
    k, lanes, t = 64, 128, 192
    probs = rng.dirichlet(np.ones(k) * 0.5, size=t).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs))
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    got = ops.rans_encode_chunked(syms, tbl, 80, t_block=16,
                                  interpret=False)
    want = coder.encode_chunked(syms, tbl, 80)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_decode_kernel_compiled_roundtrip():
    tbl, syms = _case(402, k=256, lanes=128, t=256)
    enc = coder.encode(syms, tbl)
    dec, _ = ops.rans_decode(enc, 256, tbl, interpret=False)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))


def test_spc_kernel_compiled_matches_ref():
    rng = np.random.default_rng(403)
    probs = jnp.asarray(rng.dirichlet(np.ones(256), size=8), jnp.float32)
    got = np.asarray(ops.spc_quantize_tables(probs, interpret=False).freq)
    want = np.asarray(spc.quantize_probs(probs))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("chunk", [None, 80])
def test_ring_scatter_compiled_matches_onehot(chunk):
    """The banked byte-ring encode datapath compiled for real hardware is
    byte-identical to the one-hot row scatter it replaced (both compiled —
    the cross-scatter contract must survive the Mosaic lowering, not just
    the interpreter)."""
    tbl, syms = _case(404, k=256, lanes=128, t=256)
    if chunk is None:
        ring = ops.rans_encode(syms, tbl, interpret=False)
        onehot = ops.rans_encode(syms, tbl, scatter="onehot",
                                 interpret=False)
    else:
        ring = ops.rans_encode_chunked(syms, tbl, chunk, interpret=False)
        onehot = ops.rans_encode_chunked(syms, tbl, chunk, scatter="onehot",
                                         interpret=False)
    for g, w in zip(ring, onehot):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_zero_copy_slab_decode_compiled_roundtrip():
    """The zero-copy container decode (scalar-prefetch index planes +
    in-kernel window DMA) compiled for real hardware round-trips the
    packed v2 container bit-exactly against the dense-slab kernel."""
    from repro.core import bitstream
    tbl, syms = _case(405, k=256, lanes=128, t=256)
    ch = ops.rans_encode_chunked(syms, tbl, 80, interpret=False)
    blob = bitstream.pack_chunked(*map(np.asarray, ch), chunk_size=80,
                                  n_symbols=256)
    cs = bitstream.parse_chunked(blob)
    dense, _, lp_d = ops.rans_decode_chunked(ch, 256, tbl, 80,
                                             lane_probes=True,
                                             interpret=False)
    slab, _, lp_s = ops.rans_decode_chunked(
        n_symbols=256, tbl=tbl, chunk_size=80, lane_probes=True,
        interpret=False, from_container=cs)
    np.testing.assert_array_equal(np.asarray(slab), np.asarray(syms))
    np.testing.assert_array_equal(np.asarray(slab), np.asarray(dense))
    np.testing.assert_array_equal(np.asarray(lp_s), np.asarray(lp_d))
