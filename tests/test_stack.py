"""Push/pop stack interface over the lane coder (DESIGN.md §12).

The stack's contract has three legs, each pinned here:

  * **inverse-ness** — push-then-pop and pop-then-push restore the state
    bit-exactly (s, ptr AND buffer bytes), for every codec constructor
    (``Uniform`` / ``NonUniform`` / ``Categorical`` / ``from_tableset``)
    and combinator (``serial`` / ``substack`` / array codecs);
  * **coder equivalence** — ``stack_init + push_symbols + stack_flush``
    lands byte-identical streams to the batch ``coder.encode`` (shared
    single-source cores), and the kernel pop backend evolves the stack
    byte-identically to the pure-JAX pop;
  * **explicit initial bits + detectable exhaustion** — a pop from an
    empty stack *flags* per-lane underflow (never silently recycles
    bytes), ``stack_init_bits`` seeds drawable entropy, and the bits-back
    VAE round trip restores the initial bits exactly.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import coder, constants as C, spc, stack

jax.config.update("jax_platforms", "cpu")

LANES, CAP = 4, 512


def _tables(k, seed, lanes=None, t=None):
    rng = np.random.default_rng(seed)
    size = tuple(d for d in (t, lanes) if d is not None) or None
    probs = rng.dirichlet(np.full(k, 0.5), size=size)
    return spc.freq_cdf_from_probs(
        spc.store_bf16(jnp.asarray(probs, jnp.float32)))


def _syms(k, t, seed):
    return np.random.default_rng(seed).integers(
        0, k, (LANES, t)).astype(np.int32)


def _state_equal(a: stack.StackState, b: stack.StackState,
                 full_buf: bool = False):
    """Bit-equality of the live stack: s, ptr and the stream bytes at
    ``buf[lane, ptr:]``.  Bytes below ``ptr`` are dead (pops never zero
    them), so they only must match when a re-push overwrote them
    (``full_buf=True`` — the pop-then-push bits-back direction)."""
    np.testing.assert_array_equal(np.asarray(a.s), np.asarray(b.s))
    np.testing.assert_array_equal(np.asarray(a.ptr), np.asarray(b.ptr))
    ab, bb = np.asarray(a.buf), np.asarray(b.buf)
    if full_buf:
        np.testing.assert_array_equal(ab, bb)
        return
    for lane, p in enumerate(np.asarray(a.ptr)):
        np.testing.assert_array_equal(ab[lane, max(int(p), 0):],
                                      bb[lane, max(int(p), 0):])


# ---------------------------------------------------------------------------
# inverse-ness per codec
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["coder", "kernel"])
def test_categorical_push_then_pop_is_identity(backend):
    freq, cdf = _tables(16, 0)
    codec = stack.Categorical(freq, cdf, backend=backend)
    st0 = stack.stack_init(LANES, CAP)
    x = jnp.asarray(_syms(16, 1, seed=1)[:, 0])
    st = codec.push(st0, x)
    st, got = codec.pop(st)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    _state_equal(st, st0)
    assert not np.asarray(st.underflow).any()


@pytest.mark.parametrize("backend", ["coder", "kernel"])
def test_categorical_pop_then_push_is_identity(backend):
    """The bits-back primitive: pop a symbol against one distribution from
    seeded initial bits, push it back against the SAME distribution — the
    stack (including the byte buffer) must return bit-for-bit."""
    freq, cdf = _tables(16, 2, lanes=LANES)     # per-lane tables
    codec = stack.Categorical(freq, cdf, backend=backend)
    st0 = stack.stack_init_bits(LANES, CAP, n_bytes=32, seed=3)
    st, x = codec.pop(st0)
    assert not np.asarray(st.underflow).any()
    st = codec.push(st, x)
    _state_equal(st, st0, full_buf=True)


def test_uniform_roundtrip_and_validation():
    codec = stack.Uniform(6)
    st0 = stack.stack_init(LANES, CAP)
    xs = _syms(1 << 6, 8, seed=4)
    st = st0
    for i in reversed(range(8)):
        st = codec.push(st, jnp.asarray(xs[:, i]))
    for i in range(8):
        st, got = codec.pop(st)
        np.testing.assert_array_equal(np.asarray(got), xs[:, i])
    _state_equal(st, st0)
    with pytest.raises(ValueError, match="Uniform bits"):
        stack.Uniform(0)
    with pytest.raises(ValueError, match="Uniform bits"):
        stack.Uniform(C.PROB_BITS + 1)
    with pytest.raises(ValueError, match="backend"):
        stack.Categorical(*_tables(8, 0), backend="gpu")


def test_nonuniform_statfun_matches_categorical():
    """A NonUniform built from a table's statfuns must land the identical
    bytes as the Categorical over the same table (shared barrett_planes)."""
    from repro.core import search
    freq, cdf = _tables(16, 5)

    def enc_statfun(x):
        return stack._gather(cdf[..., :-1], x), stack._gather(freq, x)

    def dec_statfun(slot):
        return search.find_symbol(cdf, 16, slot)[0]

    nu = stack.NonUniform(enc_statfun, dec_statfun)
    cat = stack.Categorical(freq, cdf)
    xs = _syms(16, 6, seed=6)
    st_a = st_b = stack.stack_init(LANES, CAP)
    for i in reversed(range(6)):
        st_a = nu.push(st_a, jnp.asarray(xs[:, i]))
        st_b = cat.push(st_b, jnp.asarray(xs[:, i]))
    _state_equal(st_a, st_b)
    for i in range(6):
        st_a, ga = nu.pop(st_a)
        st_b, gb = cat.pop(st_b)
        np.testing.assert_array_equal(np.asarray(ga), np.asarray(gb))
    _state_equal(st_a, st_b)


def test_serial_roundtrip_and_arity_check():
    freq, cdf = _tables(16, 7)
    codec = stack.serial([stack.Uniform(4), stack.Categorical(freq, cdf)])
    st0 = stack.stack_init(LANES, CAP)
    xa, xb = _syms(16, 1, seed=8)[:, 0], _syms(16, 1, seed=9)[:, 0]
    st = codec.push(st0, (jnp.asarray(xa), jnp.asarray(xb)))
    st, (ga, gb) = codec.pop(st)
    np.testing.assert_array_equal(np.asarray(ga), xa)
    np.testing.assert_array_equal(np.asarray(gb), xb)
    _state_equal(st, st0)
    with pytest.raises(ValueError, match="serial push"):
        codec.push(st0, (jnp.asarray(xa),))


def test_substack_leaves_other_lanes_untouched():
    freq, cdf = _tables(16, 10)
    idx = jnp.asarray([0, 2])
    codec = stack.substack(stack.Categorical(freq, cdf), idx)
    st0 = stack.stack_init_bits(LANES, CAP, n_bytes=16, seed=11)
    x = jnp.asarray([3, 9], jnp.int32)
    st = codec.push(st0, x)
    for lane in (1, 3):                      # untouched lanes: bit-for-bit
        np.testing.assert_array_equal(np.asarray(st.buf[lane]),
                                      np.asarray(st0.buf[lane]))
        assert int(st.s[lane]) == int(st0.s[lane])
        assert int(st.ptr[lane]) == int(st0.ptr[lane])
    st, got = codec.pop(st)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    _state_equal(st, st0)


# ---------------------------------------------------------------------------
# coder equivalence + array codecs over every table layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("layout", ["static", "perpos", "perlane"])
def test_push_symbols_flush_matches_batch_coder(layout):
    """stack_init + push_symbols + stack_flush == coder.encode, byte for
    byte — the stack IS the batch encoder when used batch-wise."""
    t, k = 20, 16
    rng = np.random.default_rng(12)
    size = (None if layout == "static"
            else (t,) if layout == "perpos" else (t, LANES))
    probs = rng.dirichlet(np.full(k, 0.5), size=size)
    tbl = spc.tables_from_probs(jnp.asarray(probs.astype(np.float32)))
    syms = _syms(k, t, seed=13)
    enc_ref = coder.encode(jnp.asarray(syms), tbl)
    st = stack.stack_init(LANES, CAP)
    st = stack.push_symbols(st, jnp.asarray(syms), tbl.freq, tbl.cdf)
    enc = stack.stack_flush(st)
    ref_buf, ref_start = np.asarray(enc_ref.buf), np.asarray(enc_ref.start)
    got_buf, got_start = np.asarray(enc.buf), np.asarray(enc.start)
    for lane in range(LANES):
        np.testing.assert_array_equal(got_buf[lane, got_start[lane]:],
                                      ref_buf[lane, ref_start[lane]:])


@pytest.mark.parametrize("layout", ["static", "perpos", "perlane"])
@pytest.mark.parametrize("backend", ["coder", "kernel"])
def test_array_codec_roundtrip_all_layouts(layout, backend):
    t, k = 12, 16
    freq, cdf = _tables(k, 14, t=t if layout != "static" else None,
                        lanes=LANES if layout == "perlane" else None)
    syms = _syms(k, t, seed=15)
    st0 = stack.stack_init_bits(LANES, CAP, n_bytes=8, seed=16)
    st = stack.push_symbols(st0, jnp.asarray(syms), freq, cdf)
    st, got = stack.pop_symbols(st, t, freq, cdf, backend=backend)
    np.testing.assert_array_equal(np.asarray(got), syms)
    _state_equal(st, st0)
    with pytest.raises(ValueError, match="backend"):
        stack.pop_symbols(st, t, freq, cdf, backend="tpu")


def test_kernel_and_coder_pops_evolve_identical_stacks():
    freq, cdf = _tables(32, 17)
    st = stack.stack_init(LANES, CAP)
    st = stack.push_symbols(st, jnp.asarray(_syms(32, 16, seed=18)),
                            freq, cdf)
    st_c, sym_c = stack.pop_symbols(st, 16, freq, cdf, backend="coder")
    st_k, sym_k = stack.pop_symbols(st, 16, freq, cdf, backend="kernel")
    np.testing.assert_array_equal(np.asarray(sym_c), np.asarray(sym_k))
    _state_equal(st_c, st_k)
    np.testing.assert_array_equal(np.asarray(st_c.underflow),
                                  np.asarray(st_k.underflow))


def test_from_tableset_equals_categorical():
    tbl = spc.tables_from_probs(jnp.asarray(
        np.random.default_rng(19).dirichlet(np.full(16, 0.5)), jnp.float32))
    x = jnp.asarray(_syms(16, 1, seed=20)[:, 0])
    st0 = stack.stack_init(LANES, CAP)
    a = stack.from_tableset(tbl).push(st0, x)
    b = stack.Categorical(tbl.freq, tbl.cdf).push(st0, x)
    _state_equal(a, b)


# ---------------------------------------------------------------------------
# initial bits, exhaustion, flush/open
# ---------------------------------------------------------------------------

def test_empty_stack_pop_flags_underflow():
    """A pop with no entropy to draw on FLAGS — stream exhaustion is
    detectable at the stack level, never a silent byte recycle."""
    codec = stack.Categorical(*_tables(16, 21))
    st, _x = codec.pop(stack.stack_init(LANES, CAP))
    assert np.asarray(st.underflow).all()


def test_initial_bits_are_deterministic_and_sized():
    a = stack.stack_init_bits(LANES, CAP, n_bytes=24, seed=5)
    b = stack.stack_init_bits(LANES, CAP, n_bytes=24, seed=5)
    _state_equal(a, b)
    np.testing.assert_array_equal(np.asarray(stack.stack_bytes(a)),
                                  np.full(LANES, 24 + 4))
    assert (np.asarray(a.s) >= C.RANS_L).all()
    with pytest.raises(ValueError, match="exceeds stack cap"):
        stack.stack_init_bits(LANES, 16, n_bytes=32)


def test_flush_open_roundtrip_and_truncated_header_flags():
    st = stack.stack_init_bits(LANES, CAP, n_bytes=16, seed=22)
    enc = stack.stack_flush(st)
    st_r = stack.stack_open(enc)
    _state_equal(st_r, st)
    assert not np.asarray(st_r.underflow).any()
    # a header cut short (stream shorter than the 4 state bytes) flags
    short = coder.EncodedLanes(buf=enc.buf,
                               start=jnp.full((LANES,), CAP - 2, jnp.int32),
                               length=jnp.full((LANES,), 2, jnp.int32))
    assert np.asarray(stack.stack_open(short).underflow).all()


# ---------------------------------------------------------------------------
# observation codecs + the bits-back VAE round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["coder", "kernel"])
def test_observation_codecs_roundtrip(backend):
    rng = np.random.default_rng(23)
    edges, _ = stack.std_gaussian_bins(16)
    mu = jnp.asarray(rng.normal(0, 1, LANES), jnp.float32)
    sig = jnp.asarray(rng.uniform(0.5, 2.0, LANES), jnp.float32)
    g = stack.DiagGaussian(mu, sig, edges, backend=backend)
    dl = stack.DiscretizedLogistic(mu * 0.1, mu * 0.0 - 2.0, 256,
                                   backend=backend)
    st0 = stack.stack_init_bits(LANES, CAP, n_bytes=32, seed=24)
    kz = jnp.asarray(rng.integers(0, 16, LANES), jnp.int32)
    px = jnp.asarray(rng.integers(0, 256, LANES), jnp.int32)
    st = g.push(st0, kz)
    st = dl.push(st, px)
    st, got_px = dl.pop(st)
    st, got_kz = g.pop(st)
    np.testing.assert_array_equal(np.asarray(got_px), np.asarray(px))
    np.testing.assert_array_equal(np.asarray(got_kz), np.asarray(kz))
    _state_equal(st, st0)


def test_gaussian_bins_uniform_prior_mass():
    """N(0,1) over its own equal-mass quantile bins is exactly uniform —
    the identity that lets the VAE's top prior ride the exact Uniform
    codec instead of a quantized table."""
    edges, _ = stack.std_gaussian_bins(16)
    mass = stack.gaussian_bin_probs(jnp.zeros(()), jnp.ones(()), edges)
    np.testing.assert_allclose(np.asarray(mass), np.full(16, 1 / 16),
                               atol=1e-6)


@pytest.mark.parametrize("backend", ["coder", "kernel"])
def test_vae_bitsback_roundtrip_small(backend):
    """End-to-end Bit-Swap on a barely-trained tiny VAE: pixels bit-exact,
    initial stack restored bit-for-bit, no underflow — correctness is
    independent of model quality."""
    from repro.models import vae
    cfg = vae.VAEConfig(d_x=16, d_h=16)
    rng = np.random.default_rng(25)
    params, _ = vae.train_vae(
        cfg, lambda i: np.random.default_rng(i).integers(
            0, cfg.x_bins, (LANES, cfg.d_x)),
        steps=3, lr=1e-3, seed=0)
    x = jnp.asarray(rng.integers(0, cfg.x_bins, (LANES, cfg.d_x)),
                    jnp.int32)
    st0 = stack.stack_init_bits(LANES, 2048, n_bytes=64, seed=26)
    st = vae.bb_encode(st0, params, x, cfg, backend=backend)
    assert not np.asarray(st.underflow).any()
    st_d, x_d = vae.bb_decode(st, params, cfg, backend=backend)
    np.testing.assert_array_equal(np.asarray(x_d), np.asarray(x))
    _state_equal(st_d, st0)
    assert not np.asarray(st_d.underflow).any()
