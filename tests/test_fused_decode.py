"""Fused serve-decode differentials (DESIGN.md §9).

Two layers of evidence that the fused path is bit-exact:

  * **per-step kernel vs pure coder** — ``kernels.rans_decode.rans_decode_step``
    (the symbol-pop primitive inside the fused ``lax.scan``) driven over the
    frozen golden-vector corpus: static / per-position / per-lane tables,
    v1 monolithic and v2 chunked blobs with ragged tails.  Symbols AND
    per-lane probe counters must be integer-identical to ``coder.decode``;
  * **three-backend serve sweep** — ``lm_decompress[_chunked]`` with
    ``backend`` in {coder, kernel (fused), two_pass} on the same bitstream,
    with and without model-top-k speculation (``topk=0`` exercises the
    no-candidate kernel specialization), ragged chunk tails included.
"""

import importlib.util
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.core import coder, constants as C
from repro.data.pipeline import token_stream
from repro.kernels.rans_decode import rans_decode_step
from repro.models import init_model

jax.config.update("jax_platforms", "cpu")

_GEN_PATH = os.path.join(os.path.dirname(__file__), "golden_vectors",
                         "generate.py")
_spec = importlib.util.spec_from_file_location("golden_generate", _GEN_PATH)
golden = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(golden)

CFG = get_smoke_config("ras-pimc")
KEY = jax.random.PRNGKey(1)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, KEY)


def _step_decode_stream(enc, t, tbl, t0=0, prob_bits=C.PROB_BITS):
    """Drive the per-step kernel over a monolithic stream via lax.scan —
    the same shape the fused serve program uses, minus the model."""
    if tbl.freq.ndim == 1:            # static: one table for every step
        fseq = jnp.broadcast_to(tbl.freq, (t,) + tbl.freq.shape)
        cseq = jnp.broadcast_to(tbl.cdf, (t,) + tbl.cdf.shape)
    else:                             # (T, K) per-position / (T, lanes, K)
        fseq = tbl.freq[t0:t0 + t]
        cseq = tbl.cdf[t0:t0 + t]
    dec = coder.decoder_init(enc)
    buf_t = enc.buf.T

    def body(carry, xs):
        s, ptr = carry
        f, c = xs
        s, ptr, sym, p, _ = rans_decode_step(buf_t, s, ptr, f, c,
                                             prob_bits=prob_bits)
        return (s, ptr), (sym, p)

    (_, _), (sym, probes) = jax.lax.scan(body, (dec.s, dec.ptr),
                                         (fseq, cseq))
    return sym.T, jnp.sum(probes, axis=0)


@pytest.mark.parametrize("case", golden.CASES,
                         ids=[c["name"] for c in golden.CASES])
def test_step_kernel_decodes_golden_corpus(case):
    """The fused path's symbol-pop primitive decodes every frozen golden
    vector with symbols and probe counters identical to the pure coder,
    across every table layout and both container formats."""
    tbl, syms = golden.build_case(case)
    t = case["t"]
    if case["fmt"] == "v1":
        enc = coder.encode(jnp.asarray(syms), tbl)
        ref_sym, _, ref_lane = coder.decode(enc, t, tbl, lane_probes=True)
        got, lane = _step_decode_stream(enc, t, tbl)
    else:
        cs = case["chunk_size"]
        ch = coder.encode_chunked(jnp.asarray(syms), tbl, cs)
        ref_sym, _, ref_lane = coder.decode_chunked(ch, t, tbl, cs,
                                                    lane_probes=True)
        outs, lane = [], jnp.zeros((case["lanes"],), jnp.int32)
        for c, n in enumerate(coder.chunk_lengths(t, cs)):
            sym_c, lane_c = _step_decode_stream(
                coder.chunk_encoded(ch, c), n, tbl, t0=c * cs)
            outs.append(sym_c)
            lane = lane + lane_c
        got = jnp.concatenate(outs, axis=1)
    np.testing.assert_array_equal(np.asarray(got), syms)
    np.testing.assert_array_equal(np.asarray(ref_sym), syms)
    np.testing.assert_array_equal(np.asarray(lane), np.asarray(ref_lane))


@pytest.mark.parametrize("topk", [0, 4])
def test_serve_three_backend_sweep(params, topk):
    """coder vs fused vs two-pass on one bitstream: bit-exact symbols and
    integer-identical per-lane probe counters (topk=0 = no speculation —
    the kernels' no-candidate specialization)."""
    from repro.serve.compress import lm_compress, lm_decompress
    toks = jnp.asarray(token_stream(CFG.vocab_size, (4, 40), seed=21),
                       jnp.int32)
    enc = lm_compress(params, CFG, toks, backend="kernel").enc
    res = {b: lm_decompress(params, CFG, enc, 40, topk=topk, backend=b,
                            lane_probes=True)
           for b in ("coder", "kernel", "two_pass")}
    for b, (sym, _, lane) in res.items():
        np.testing.assert_array_equal(np.asarray(sym), np.asarray(toks),
                                      err_msg=f"backend={b}")
        np.testing.assert_array_equal(
            np.asarray(lane), np.asarray(res["coder"][2]),
            err_msg=f"backend={b} probe counters diverge (topk={topk})")


@pytest.mark.parametrize("topk", [0, 4])
def test_serve_three_backend_sweep_chunked_ragged(params, topk):
    """The chunked analogue with a ragged tail (40 symbols, chunk 16): the
    fused path re-initializes coder state per chunk while carrying the model
    cache, the two-pass path replays the chunk grid in one kernel launch —
    both must land on the coder's exact symbols and counters."""
    from repro.serve.compress import (lm_compress_chunked,
                                      lm_decompress_chunked)
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 40), seed=22),
                       jnp.int32)
    st = lm_compress_chunked(params, CFG, toks, chunk_size=16,
                             backend="kernel")
    res = {b: lm_decompress_chunked(params, CFG, st.chunks, 40, 16,
                                    topk=topk, backend=b, lane_probes=True)
           for b in ("coder", "kernel", "two_pass")}
    for b, (sym, _, lane) in res.items():
        np.testing.assert_array_equal(np.asarray(sym), np.asarray(toks),
                                      err_msg=f"backend={b}")
        np.testing.assert_array_equal(
            np.asarray(lane), np.asarray(res["coder"][2]),
            err_msg=f"backend={b} probe counters diverge (topk={topk})")
