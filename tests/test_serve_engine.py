"""Serve engine (prefill / generate) regression tests.

The engine drove the model zoo since the seed but was only shape/determinism
tested, so a position off-by-one in ``generate`` rotted silently: prefill
consumes prompt positions ``[0, s)``, yet the generation scan consumed the
first sampled token at position ``s + 1`` — cache slot ``s`` was never
written and every subsequent step attended over a zero row.  The manual
per-step rollout below pins the position contract exactly; the round-trip
test pins the engine into the compression stack (one shared cache
evolution — ``serve.engine.teacher_forced_scan`` backs both).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import token_stream
from repro.models import init_model
from repro.models.transformer import decode_step, init_cache
from repro.serve.engine import generate, prefill, teacher_forced_scan

jax.config.update("jax_platforms", "cpu")

CFG = get_smoke_config("ras-pimc")
KEY = jax.random.PRNGKey(2)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, KEY)


def _manual_greedy(params, cfg, prompt, n_new, max_len):
    """Explicit per-step greedy rollout: the position-contract reference.

    Returns (tokens (B, n_new), logits (B, n_new, Vpad)) — the logits that
    produced each token, computed with an unrolled python loop where every
    ``decode_step`` position is written out literally.
    """
    b, s = prompt.shape
    cache = init_cache(cfg, b, max_len)
    lg = None
    for t in range(s):
        lg, cache = decode_step(params, cache, prompt[:, t][:, None], t, cfg)
    out, lgs = [], []
    for i in range(n_new):
        lgs.append(lg)
        nxt = jnp.argmax(lg[:, :cfg.vocab_size], -1).astype(jnp.int32)
        out.append(nxt)
        if i + 1 < n_new:
            lg, cache = decode_step(params, cache, nxt[:, None], s + i, cfg)
    return jnp.stack(out, axis=1), jnp.stack(lgs, axis=1)


def test_generate_matches_manual_rollout(params):
    """generate == the explicit rollout, token for token AND logit for logit.

    This is the regression the old shape-only tests missed: the first
    generated token must be consumed at position ``s`` (the slot right
    after the prompt), not ``s + 1``.  The logits assertion is the teeth —
    on a smoke-sized model the off-by-one perturbs every post-first-step
    logit by ~3e-2 (slot ``s`` left as an attended-over zero row) without
    necessarily flipping any argmax, so token equality alone would pass on
    the broken code.
    """
    prompt = jnp.asarray(token_stream(CFG.vocab_size, (2, 12), seed=5),
                         jnp.int32)
    out, lgs = generate(params, CFG, prompt, 8, max_len=32,
                        return_logits=True)
    ref, ref_lgs = _manual_greedy(params, CFG, prompt, 8, 32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_allclose(np.asarray(lgs), np.asarray(ref_lgs),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_generate_matches_manual_rollout_windowed():
    """Same contract on a ring-buffered (windowed/recurrent) cache, where a
    skipped slot additionally corrupts the ring arithmetic."""
    cfg = get_smoke_config("recurrentgemma-2b")
    params = init_model(cfg, KEY)
    prompt = jnp.asarray(token_stream(cfg.vocab_size, (2, 10), seed=6),
                         jnp.int32)
    out, lgs = generate(params, cfg, prompt, 6, max_len=24,
                        return_logits=True)
    ref, ref_lgs = _manual_greedy(params, cfg, prompt, 6, 24)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_allclose(np.asarray(lgs), np.asarray(ref_lgs),
                               rtol=1e-5, atol=1e-5)


def test_teacher_forced_scan_backs_prefill(params):
    """prefill is the shared teacher-forced scan's last step, and the
    step_fn hook maps per-step logits without disturbing the cache."""
    toks = jnp.asarray(token_stream(CFG.vocab_size, (3, 9), seed=7),
                       jnp.int32)
    cache_a, last = prefill(params, CFG, toks, max_len=16)
    cache_b, all_lg = teacher_forced_scan(params, CFG, toks, 16)
    np.testing.assert_array_equal(np.asarray(last), np.asarray(all_lg[-1]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), cache_a, cache_b)
    _, picked = teacher_forced_scan(
        params, CFG, toks, 16,
        step_fn=lambda lg, t: jnp.argmax(lg[:, :CFG.vocab_size], -1))
    np.testing.assert_array_equal(
        np.asarray(picked[-1]),
        np.asarray(jnp.argmax(last[:, :CFG.vocab_size], -1)))


def test_generate_then_fused_compress_roundtrip(params):
    """Engine output round-trips through the serve compression stack: the
    tokens generate produced compress and fused-decode bit-exactly (the
    engine and compressor share one cache evolution via
    teacher_forced_scan, so this is a true end-to-end serving loop)."""
    from repro.serve.compress import lm_compress, lm_decompress
    prompt = jnp.asarray(token_stream(CFG.vocab_size, (2, 8), seed=8),
                         jnp.int32)
    out = generate(params, CFG, prompt, 8, max_len=16)
    toks = jnp.concatenate([prompt, out], axis=1)
    stats = lm_compress(params, CFG, toks)
    dec, _ = lm_decompress(params, CFG, stats.enc, toks.shape[1],
                           backend="kernel")
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks))


def test_ring_cache_wrap_matches_sliding_window(params):
    """The shared-cache wrap contract, pinned logit-level: a cache of
    ``max_len=W`` driven past W positions IS sliding-window-W attention.
    The docstring promised "(possibly ring-buffered)" since the seed but
    nothing ever exercised seq > max_len — an off-by-one in the age mask
    would have rotted silently.  Also asserts the test has teeth: the
    windowed logits genuinely differ from full-context attention."""
    from dataclasses import replace
    from repro.models.transformer import forward, logits as lm_logits
    W, S = 8, 24
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, S), seed=11),
                       jnp.int32)
    _, ring_lg = teacher_forced_scan(params, CFG, toks, W)  # rings at W
    ring_lg = jnp.stack(list(ring_lg), axis=0) if isinstance(ring_lg, list) \
        else ring_lg                                        # (S, B, V)
    cfg_w = replace(CFG, sliding_window=W)
    x, _ = forward(params, toks, cfg_w)
    full_w = lm_logits(params["tok"], x, cfg_w)             # (B, S, V)
    np.testing.assert_allclose(np.asarray(ring_lg),
                               np.asarray(jnp.swapaxes(full_w, 0, 1)),
                               atol=2e-4, rtol=2e-4)
    # teeth: past t >= W the window must change the distribution
    x_full, _ = forward(params, toks, CFG)
    full = lm_logits(params["tok"], x_full, CFG)
    assert np.max(np.abs(np.asarray(full - full_w))[:, W:]) > 1e-2


def test_ring_cache_length_invariance(params):
    """Ring length is NOT part of the model function below capacity: the
    same stream decoded under different cache lengths produces bit-exact
    identical logits (the tiled attention reduction makes every float a
    function of slot content, never of ring extent).  This is what lets
    the batched engine serve a request under its shared ``max_len`` cache
    byte-identically to the single-request scan at ``t_len``."""
    from repro.models.transformer import decode_step, init_cache
    toks = jnp.asarray(token_stream(CFG.vocab_size, (2, 12), seed=13),
                       jnp.int32)

    def roll(ml):
        cache = init_cache(CFG, 2, ml)
        out = []
        for t in range(12):
            lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, CFG)
            out.append(np.asarray(lg))
        return np.stack(out)

    a = roll(12)
    for ml in (16, 33, 64):
        np.testing.assert_array_equal(a, roll(ml))


def test_prefill_chunk_bitwise_matches_decode_steps(params):
    """The batched-prefill fast path IS the sequential step path, bit for
    bit: one ``prefill_chunk`` over S teacher-forced positions (starting
    mid-stream, pos0 > 0) produces the identical logits and cache as S
    ``decode_step`` calls.  This is the identity that lets the engine
    dispatch compress-only cycles through one fused pass — the attend
    core runs at query extent 1 either way (a multi-query einsum rounds
    ~1 ulp differently than S single-query ones)."""
    from repro.models.transformer import can_prefill, prefill_chunk
    assert can_prefill(CFG)
    b, s, warm, max_len = 4, 8, 3, 16
    toks = jnp.asarray(token_stream(CFG.vocab_size, (b, warm + s), seed=9),
                       jnp.int32)

    cache = init_cache(CFG, b, max_len)
    for t in range(warm):
        _, cache = decode_step(params, cache, toks[:, t:t + 1], t, CFG)
    seq_cache, lgs = cache, []
    for t in range(warm, warm + s):
        lg, seq_cache = decode_step(params, seq_cache, toks[:, t:t + 1], t,
                                    CFG)
        lgs.append(lg)

    pos0 = jnp.full((b,), warm, jnp.int32)
    pf_lgs, pf_cache = prefill_chunk(params, cache, toks[:, warm:], pos0,
                                     jnp.full((b,), s, jnp.int32), CFG)
    np.testing.assert_array_equal(np.stack([np.asarray(x) for x in lgs], 1),
                                  np.asarray(pf_lgs))
    for a, bb in zip(jax.tree.leaves(seq_cache), jax.tree.leaves(pf_cache)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(bb))


def test_prefill_chunk_ragged_live_rows_exact(params):
    """Rows with ``n_valid < S`` freeze after their live steps; every live
    (row, position) logit still equals the all-rows-live sequential
    reference bitwise (same batch extent — rows are data-independent, so
    a neighbor's freeze must not perturb a live row by even one ulp;
    frozen positions are discarded by the engine and excluded here)."""
    from repro.models.transformer import prefill_chunk
    b, s, max_len = 4, 8, 16
    toks = jnp.asarray(token_stream(CFG.vocab_size, (b, s), seed=11),
                       jnp.int32)
    cache, ref = init_cache(CFG, b, max_len), []
    for t in range(s):
        lg, cache = decode_step(params, cache, toks[:, t:t + 1], t, CFG)
        ref.append(np.asarray(lg))
    ref = np.stack(ref, axis=1)                    # (b, s, Vpad)
    nv = np.asarray([s, 5, 1, 0], np.int32)
    pf_lgs, _ = prefill_chunk(params, init_cache(CFG, b, max_len), toks,
                              jnp.zeros((b,), jnp.int32), jnp.asarray(nv),
                              CFG)
    pf_lgs = np.asarray(pf_lgs)
    for i in range(b):
        np.testing.assert_array_equal(pf_lgs[i, :nv[i]], ref[i, :nv[i]])
