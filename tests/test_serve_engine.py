"""Serve engine (prefill / generate) regression tests.

The engine drove the model zoo since the seed but was only shape/determinism
tested, so a position off-by-one in ``generate`` rotted silently: prefill
consumes prompt positions ``[0, s)``, yet the generation scan consumed the
first sampled token at position ``s + 1`` — cache slot ``s`` was never
written and every subsequent step attended over a zero row.  The manual
per-step rollout below pins the position contract exactly; the round-trip
test pins the engine into the compression stack (one shared cache
evolution — ``serve.engine.teacher_forced_scan`` backs both).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import token_stream
from repro.models import init_model
from repro.models.transformer import decode_step, init_cache
from repro.serve.engine import generate, prefill, teacher_forced_scan

jax.config.update("jax_platforms", "cpu")

CFG = get_smoke_config("ras-pimc")
KEY = jax.random.PRNGKey(2)


@pytest.fixture(scope="module")
def params():
    return init_model(CFG, KEY)


def _manual_greedy(params, cfg, prompt, n_new, max_len):
    """Explicit per-step greedy rollout: the position-contract reference.

    Returns (tokens (B, n_new), logits (B, n_new, Vpad)) — the logits that
    produced each token, computed with an unrolled python loop where every
    ``decode_step`` position is written out literally.
    """
    b, s = prompt.shape
    cache = init_cache(cfg, b, max_len)
    lg = None
    for t in range(s):
        lg, cache = decode_step(params, cache, prompt[:, t][:, None], t, cfg)
    out, lgs = [], []
    for i in range(n_new):
        lgs.append(lg)
        nxt = jnp.argmax(lg[:, :cfg.vocab_size], -1).astype(jnp.int32)
        out.append(nxt)
        if i + 1 < n_new:
            lg, cache = decode_step(params, cache, nxt[:, None], s + i, cfg)
    return jnp.stack(out, axis=1), jnp.stack(lgs, axis=1)


def test_generate_matches_manual_rollout(params):
    """generate == the explicit rollout, token for token AND logit for logit.

    This is the regression the old shape-only tests missed: the first
    generated token must be consumed at position ``s`` (the slot right
    after the prompt), not ``s + 1``.  The logits assertion is the teeth —
    on a smoke-sized model the off-by-one perturbs every post-first-step
    logit by ~3e-2 (slot ``s`` left as an attended-over zero row) without
    necessarily flipping any argmax, so token equality alone would pass on
    the broken code.
    """
    prompt = jnp.asarray(token_stream(CFG.vocab_size, (2, 12), seed=5),
                         jnp.int32)
    out, lgs = generate(params, CFG, prompt, 8, max_len=32,
                        return_logits=True)
    ref, ref_lgs = _manual_greedy(params, CFG, prompt, 8, 32)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_allclose(np.asarray(lgs), np.asarray(ref_lgs),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_generate_matches_manual_rollout_windowed():
    """Same contract on a ring-buffered (windowed/recurrent) cache, where a
    skipped slot additionally corrupts the ring arithmetic."""
    cfg = get_smoke_config("recurrentgemma-2b")
    params = init_model(cfg, KEY)
    prompt = jnp.asarray(token_stream(cfg.vocab_size, (2, 10), seed=6),
                         jnp.int32)
    out, lgs = generate(params, cfg, prompt, 6, max_len=24,
                        return_logits=True)
    ref, ref_lgs = _manual_greedy(params, cfg, prompt, 6, 24)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    np.testing.assert_allclose(np.asarray(lgs), np.asarray(ref_lgs),
                               rtol=1e-5, atol=1e-5)


def test_teacher_forced_scan_backs_prefill(params):
    """prefill is the shared teacher-forced scan's last step, and the
    step_fn hook maps per-step logits without disturbing the cache."""
    toks = jnp.asarray(token_stream(CFG.vocab_size, (3, 9), seed=7),
                       jnp.int32)
    cache_a, last = prefill(params, CFG, toks, max_len=16)
    cache_b, all_lg = teacher_forced_scan(params, CFG, toks, 16)
    np.testing.assert_array_equal(np.asarray(last), np.asarray(all_lg[-1]))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), cache_a, cache_b)
    _, picked = teacher_forced_scan(
        params, CFG, toks, 16,
        step_fn=lambda lg, t: jnp.argmax(lg[:, :CFG.vocab_size], -1))
    np.testing.assert_array_equal(
        np.asarray(picked[-1]),
        np.asarray(jnp.argmax(last[:, :CFG.vocab_size], -1)))


def test_generate_then_fused_compress_roundtrip(params):
    """Engine output round-trips through the serve compression stack: the
    tokens generate produced compress and fused-decode bit-exactly (the
    engine and compressor share one cache evolution via
    teacher_forced_scan, so this is a true end-to-end serving loop)."""
    from repro.serve.compress import lm_compress, lm_decompress
    prompt = jnp.asarray(token_stream(CFG.vocab_size, (2, 8), seed=8),
                         jnp.int32)
    out = generate(params, CFG, prompt, 8, max_len=16)
    toks = jnp.concatenate([prompt, out], axis=1)
    stats = lm_compress(params, CFG, toks)
    dec, _ = lm_decompress(params, CFG, stats.enc, toks.shape[1],
                           backend="kernel")
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(toks))
