"""Model-zoo correctness: per-arch smoke steps + algorithm equivalences."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import (decode_step, forward, init_cache, init_model,
                          loss_fn)
from repro.models.attention import _blockwise_attn, _naive_attn
from repro.models.config import ModelConfig
from repro.models.moe import make_moe_defs, moe_capacity, moe_dense
from repro.models.param import init_params
from repro.models.ssm import ssd_chunked, ssd_sequential

jax.config.update("jax_platforms", "cpu")

KEY = jax.random.PRNGKey(0)

# frontier-scale archs: their smoke configs still dominate suite wall time,
# so they run in the slow tier (pytest.ini deselects `slow` by default; CI's
# slow-model-tier job and `-m slow` cover them).
_SLOW_ARCHS = {"llama3-405b", "llama-3.2-vision-11b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS
            else a for a in archs]


def _batch(cfg, b=2, s=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s))),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "vlm":
        batch["memory"] = jnp.asarray(
            rng.normal(size=(b, cfg.memory_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.is_encdec:
        batch["enc_inputs"] = jnp.asarray(
            rng.normal(size=(b, cfg.memory_tokens, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


# ---------------------------------------------------------------------------
# per-arch smoke: one forward/train step, output shapes, no NaNs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_smoke_forward_and_grad_step(arch):
    cfg = get_smoke_config(arch)
    params = init_model(cfg, KEY)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(np.isfinite(np.asarray(g)).all() for g in leaves)
    x, _ = forward(params, batch["tokens"], cfg,
                   memory=batch.get("memory"),
                   enc_inputs=batch.get("enc_inputs"))
    assert x.shape == (2, 16, cfg.d_model)


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_smoke_decode_steps(arch):
    cfg = get_smoke_config(arch)
    params = init_model(cfg, KEY)
    batch = _batch(cfg)
    mem = batch.get("memory", batch.get("enc_inputs"))
    if cfg.is_encdec:
        from repro.models.transformer import encode_memory
        mem = encode_memory(params, batch["enc_inputs"], cfg)
    cache = init_cache(cfg, 2, 32)
    tok = batch["tokens"][:, :1]
    for pos in range(3):
        lg, cache = decode_step(params, cache, tok, jnp.int32(pos), cfg,
                                memory=mem)
        assert lg.shape == (2, cfg.vocab_padded)
        assert np.isfinite(np.asarray(lg)).all()
        tok = jnp.argmax(lg[:, :cfg.vocab_size], -1)[:, None]


# ---------------------------------------------------------------------------
# prefill/decode consistency: the serving path must reproduce teacher-forced
# forward logits (this is what makes LM-driven decompression bit-exact).
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", _arch_params([
    "qwen3-4b", "mixtral-8x22b", "mamba2-130m", "recurrentgemma-2b",
    "seamless-m4t-large-v2", "llama-3.2-vision-11b"]))
def test_prefill_decode_consistency(arch):
    cfg = get_smoke_config(arch)
    if cfg.n_experts:
        # uncap MoE capacity: prefill ranks tokens jointly and may drop some
        # that per-step decode would keep — a property of capacity dispatch,
        # not an inconsistency (serve/compress.py therefore feeds the rANS
        # coder from the *decode* path on both sides).
        cfg = cfg.with_(capacity_factor=16.0)
    params = init_model(cfg, KEY)
    b, s = 2, 12
    batch = _batch(cfg, b, s, seed=3)
    mem = batch.get("memory")
    if cfg.is_encdec:
        from repro.models.transformer import encode_memory
        mem = encode_memory(params, batch["enc_inputs"], cfg)
    x, _ = forward(params, batch["tokens"], cfg, memory=mem)
    from repro.models.layers import logits as logits_fn
    full = np.asarray(logits_fn(params["tok"], x, cfg))   # (B,S,V)

    cache = init_cache(cfg, b, s)
    got = []
    for t in range(s):
        lg, cache = decode_step(params, cache, batch["tokens"][:, t:t + 1],
                                jnp.int32(t), cfg, memory=mem)
        got.append(np.asarray(lg))
    got = np.stack(got, axis=1)
    np.testing.assert_allclose(got, full, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# algorithm equivalences
# ---------------------------------------------------------------------------

def test_ssd_chunked_matches_sequential():
    rng = np.random.default_rng(5)
    b, s, h, p, n = 2, 64, 4, 8, 16
    x = jnp.asarray(rng.normal(size=(b, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    bm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    cm = jnp.asarray(rng.normal(size=(b, s, 1, n)), jnp.float32)
    for chunk in (8, 16, 64):
        got = np.asarray(ssd_chunked(x, dt, a, bm, cm, chunk))
        want = np.asarray(ssd_sequential(x, dt, a, bm, cm))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 7), (False, 0)])
def test_blockwise_attention_matches_naive(causal, window):
    rng = np.random.default_rng(11)
    b, s, h, dh = 2, 33, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, dh)), jnp.float32)
    want = np.asarray(_naive_attn(q, k, v, causal, window))
    for blk in (8, 16, 64):
        got = np.asarray(_blockwise_attn(q, k, v, causal, window, blk))
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_moe_capacity_matches_dense_when_uncapped():
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      n_experts=4, topk_experts=2, capacity_factor=8.0,
                      tp=1, dtype="float32")
    p = init_params(make_moe_defs(cfg), KEY)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8, 32)),
                    jnp.float32)
    yd, aux_d = moe_dense(p, x, cfg)
    yc, aux_c = moe_capacity(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yd),
                               rtol=1e-5, atol=1e-5)
    assert abs(float(aux_d) - float(aux_c)) < 1e-6


def test_moe_capacity_drops_are_bounded():
    """With a tight capacity factor output differs but stays finite."""
    cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=2, d_ff=64, vocab_size=64,
                      n_experts=4, topk_experts=2, capacity_factor=0.5,
                      tp=1, dtype="float32")
    p = init_params(make_moe_defs(cfg), KEY)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 16, 32)),
                    jnp.float32)
    y, _ = moe_capacity(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()


def test_head_padding_preserves_function():
    """tp-padded q heads (zero-init) must not change the forward output."""
    base = get_smoke_config("qwen1.5-4b")
    cfg1 = base.with_(tp=1)
    cfg8 = base.with_(tp=8)   # 4 heads -> padded to 8
    assert cfg8.n_heads_padded == 8 and cfg1.n_heads_padded == 4
    p1 = init_model(cfg1, KEY)
    p8 = init_model(cfg8, KEY)
    batch = _batch(cfg1)
    x1, _ = forward(p1, batch["tokens"], cfg1)
    x8, _ = forward(p8, batch["tokens"], cfg8)
    assert x8.shape == x1.shape
    assert np.isfinite(np.asarray(x8)).all()


def test_sliding_window_masks_past():
    """A token far outside the window must not influence attention output."""
    cfg = get_smoke_config("mixtral-8x22b").with_(sliding_window=4,
                                                  n_experts=0,
                                                  block_pattern=("attn",))
    params = init_model(cfg, KEY)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (1, 16))
    t2 = toks.copy()
    t2[0, 0] = (t2[0, 0] + 17) % cfg.vocab_size  # mutate far-past token
    x1, _ = forward(params, jnp.asarray(toks), cfg)
    x2, _ = forward(params, jnp.asarray(t2), cfg)
    # receptive field = n_layers * window = 8; beyond that position 0 is
    # invisible, while positions inside it must differ.
    np.testing.assert_allclose(np.asarray(x1[0, 9:]), np.asarray(x2[0, 9:]),
                               rtol=1e-5, atol=1e-6)
    assert np.abs(np.asarray(x1[0, 1]) - np.asarray(x2[0, 1])).max() > 1e-4
