"""Golden-vector container back-compat (ISSUE 4 satellite).

Frozen blobs under ``tests/golden_vectors/`` pin the wire format across
refactors:

  * **pack identity** — re-encoding each case's seeded symbols through the
    current coder + container writers must reproduce the stored blob
    byte-for-byte (v1, v2, v2+checksums; static/adaptive/chunked tables);
  * **decode identity** — unpacking the *stored bytes* and decoding them on
    every backend (pure-JAX coder AND Pallas kernel, monolithic AND
    chunked single-``pallas_call`` grid) must return the seeded symbols
    exactly;
  * **loud failure** — the suite itself verifies it would catch a
    perturbation: a flipped payload byte in a checksummed blob raises a
    named-cell error, and a single-symbol change produces different
    container bytes (the deliberate-mutation check of the acceptance
    criteria).
"""

import importlib.util
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bitstream, coder
from repro.kernels import ops

jax.config.update("jax_platforms", "cpu")

_GEN_PATH = os.path.join(os.path.dirname(__file__), "golden_vectors",
                         "generate.py")
_spec = importlib.util.spec_from_file_location("golden_generate", _GEN_PATH)
golden = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(golden)

_IDS = [c["name"] for c in golden.CASES]


def _stored(case):
    with open(golden.blob_path(case), "rb") as f:
        return f.read()


@pytest.mark.parametrize("case", golden.CASES, ids=_IDS)
def test_pack_is_byte_identical_to_golden(case):
    """The current writers reproduce the frozen blob bit-for-bit."""
    assert golden.pack_case(case) == _stored(case), (
        f"{case['name']}: container bytes drifted from the golden vector — "
        "either the wire format changed (version it + regenerate) or the "
        "coder/SPC produced a different stream (a bit-exactness break)")


@pytest.mark.parametrize("case", golden.CASES, ids=_IDS)
def test_stored_blob_decodes_on_every_backend(case):
    """unpack(stored bytes) -> symbol-identical decode, coder AND kernel."""
    tbl, syms = golden.build_case(case)
    blob = _stored(case)
    if case["fmt"] == "v1":
        buf, start, meta = bitstream.unpack(blob)
        assert meta.n_symbols == case["t"] and meta.lanes == case["lanes"]
        enc = coder.EncodedLanes(jnp.asarray(buf), jnp.asarray(start),
                                 jnp.asarray(buf.shape[1] - start))
        got_c, _, lp_c = coder.decode(enc, case["t"], tbl, lane_probes=True)
        got_k, _, lp_k = ops.rans_decode(enc, case["t"], tbl,
                                         lane_probes=True)
    else:
        buf, start, meta = bitstream.unpack_chunked(blob)
        assert (meta.n_symbols, meta.chunk_size) == (case["t"],
                                                     case["chunk_size"])
        ch = coder.ChunkedLanes(jnp.asarray(buf), jnp.asarray(start),
                                jnp.asarray(buf.shape[2] - start))
        got_c, _, lp_c = coder.decode_chunked(ch, case["t"], tbl,
                                              case["chunk_size"],
                                              lane_probes=True)
        got_k, _, lp_k = ops.rans_decode_chunked(ch, case["t"], tbl,
                                                 case["chunk_size"],
                                                 lane_probes=True)
    np.testing.assert_array_equal(np.asarray(got_c), syms)
    np.testing.assert_array_equal(np.asarray(got_k), syms)
    np.testing.assert_array_equal(np.asarray(lp_c), np.asarray(lp_k))


@pytest.mark.parametrize("case", golden.CASES, ids=_IDS)
def test_fused_kernel_encode_repacks_golden(case):
    """Re-encoding each case through the FUSED kernel datapath
    (``ops.rans_encode[_chunked]`` — in-kernel byte compaction, no
    host-side ``compact_records``) and packing reproduces the frozen blob
    byte-for-byte: the fused path lands on the identical wire format."""
    tbl, syms = golden.build_case(case)
    if case["fmt"] == "v1":
        enc = ops.rans_encode(jnp.asarray(syms), tbl)
        blob = bitstream.pack(*map(np.asarray, enc), n_symbols=case["t"])
    else:
        ch = ops.rans_encode_chunked(jnp.asarray(syms), tbl,
                                     case["chunk_size"])
        blob = bitstream.pack_chunked(*map(np.asarray, ch),
                                      chunk_size=case["chunk_size"],
                                      n_symbols=case["t"],
                                      checksums=case["checksums"])
    assert blob == _stored(case), (
        f"{case['name']}: fused kernel encode drifted from the golden "
        "container bytes")


def test_v1_blob_unpacks_through_chunked_reader():
    """Back-compat: v1 golden blob presents as a single-chunk v2 stream."""
    case = golden.CASES[0]
    assert case["fmt"] == "v1"
    tbl, syms = golden.build_case(case)
    buf, start, meta = bitstream.unpack_chunked(_stored(case))
    assert meta.n_chunks == 1 and meta.n_symbols == case["t"]
    ch = coder.ChunkedLanes(jnp.asarray(buf), jnp.asarray(start),
                            jnp.asarray(buf.shape[2] - start))
    got, _ = coder.decode_chunked(ch, case["t"], tbl, meta.chunk_size)
    np.testing.assert_array_equal(np.asarray(got), syms)


# ---------------------------------------------------------------------------
# stack golden vectors (core/stack.py): frozen flushed-stack streams for
# the push/pop interface — uniform, NonUniform statfun, serial-composed,
# and a bits-back schedule with nonzero initial bits (DESIGN.md §12)
# ---------------------------------------------------------------------------

_STACK_IDS = [c["name"] for c in golden.STACK_CASES]


@pytest.mark.parametrize("case", golden.STACK_CASES, ids=_STACK_IDS)
def test_stack_pack_is_byte_identical_to_golden(case):
    """Re-running each push schedule through the live stack + stack_flush
    reproduces the frozen container bytes bit-for-bit."""
    assert golden.pack_stack_case(case) == _stored(case), (
        f"{case['name']}: flushed stack bytes drifted from the golden "
        "vector — the push path (barrett_planes/encode_step/_emit_backward) "
        "no longer lands the same stream")


def test_stack_bitsback_kernel_pops_same_bytes():
    """The bits-back schedule's encode-time pops routed through the Pallas
    per-step kernel must land the identical frozen bytes."""
    case = next(c for c in golden.STACK_CASES
                if c["name"] == "stack_bitsback")
    from repro.core import bitstream, stack
    _, st, _ = golden.run_stack_case(case, backend="kernel")
    blob = bitstream.pack(*map(np.asarray, stack.stack_flush(st)),
                          n_symbols=case["t"])
    assert blob == _stored(case)


@pytest.mark.parametrize("backend", ["coder", "kernel"])
@pytest.mark.parametrize("case", golden.STACK_CASES, ids=_STACK_IDS)
def test_stored_stack_blob_pops_on_every_backend(case, backend):
    """``stack_open(unpack(stored bytes))`` -> the pop schedule recovers
    the seeded symbols exactly, on the pure-JAX coder AND the Pallas
    per-step kernel backend."""
    from repro.core import stack
    st0, st_ref, aux = golden.run_stack_case(case)
    buf, start, meta = bitstream.unpack(_stored(case))
    enc = coder.EncodedLanes(jnp.asarray(buf), jnp.asarray(start),
                             jnp.asarray(buf.shape[1] - start))
    st = stack.stack_open(enc)
    assert not np.asarray(st.underflow).any()
    np.testing.assert_array_equal(np.asarray(st.s), np.asarray(st_ref.s))
    st, got = golden.pop_stack_case(case, st, aux, backend=backend)
    if case["name"] == "stack_bitsback":
        np.testing.assert_array_equal(got["x"], aux["x"])
        np.testing.assert_array_equal(got["k"], aux["k"])
        # the bits-back identity: the reverse schedule re-pushes the
        # posterior bins, restoring the *initial* stack's state exactly
        np.testing.assert_array_equal(np.asarray(st.s), np.asarray(st0.s))
    elif case["name"] == "stack_serial":
        for g, x in zip(got, aux["x"]):
            np.testing.assert_array_equal(g, x)
    else:
        np.testing.assert_array_equal(got, aux["x"])
    assert not np.asarray(st.underflow).any()


def test_stack_overpop_of_stored_blob_flags_underflow():
    """Popping past the end of a frozen stack stream must raise the
    per-lane underflow flag — exhaustion is detectable, never silent."""
    from repro.core import stack
    case = next(c for c in golden.STACK_CASES
                if c["name"] == "stack_uniform")
    _, _, aux = golden.run_stack_case(case)
    buf, start, _ = bitstream.unpack(_stored(case))
    enc = coder.EncodedLanes(jnp.asarray(buf), jnp.asarray(start),
                             jnp.asarray(buf.shape[1] - start))
    st = stack.stack_open(enc)
    codec = stack.Uniform(case["bits"])
    for _ in range(case["t"]):
        st, _x = codec.pop(st)
    assert not np.asarray(st.underflow).any()
    for _ in range(24):                    # drain well past the stream end
        st, _x = codec.pop(st)
    assert np.asarray(st.underflow).all()


# ---------------------------------------------------------------------------
# deliberate-mutation checks: the suite must fail loudly when perturbed
# ---------------------------------------------------------------------------

def test_flipped_payload_byte_is_caught():
    """A checksummed golden blob with one payload byte flipped raises a
    named-cell error instead of silently mis-decoding."""
    case = next(c for c in golden.CASES
                if c["fmt"] == "v2" and c["checksums"])
    blob = bytearray(_stored(case))
    blob[-1] ^= 0xFF                       # last payload byte
    with pytest.raises(ValueError, match=r"chunk \d+, lane \d+"):
        bitstream.unpack_chunked(bytes(blob))


def test_symbol_perturbation_changes_container_bytes():
    """Changing ONE symbol must change the packed bytes — proof the pack
    identity above has teeth."""
    case = golden.CASES[0]
    tbl, syms = golden.build_case(case)
    mut = syms.copy()
    mut[0, 0] = (mut[0, 0] + 1) % case["k"]
    enc = coder.encode(jnp.asarray(mut), tbl)
    blob = bitstream.pack(*map(np.asarray, enc), n_symbols=case["t"])
    assert blob != _stored(case)


def test_truncated_golden_blob_raises_named_error():
    for case in golden.CASES:
        blob = _stored(case)
        with pytest.raises(ValueError, match="truncated|not a RAS"):
            (bitstream.unpack if case["fmt"] == "v1"
             else bitstream.unpack_chunked)(blob[:len(blob) // 2])
