"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp ref oracles.

Sweeps shapes/dtypes per the kernel-testing contract and asserts exact
integer equality (rANS is bit-exact — allclose degenerates to equality).
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import coder, constants as C, spc
from repro.core.predictors import NeighborAverage
from repro.kernels import ops, ref

jax.config.update("jax_platforms", "cpu")


def _case(seed, k, lanes, t, conc=0.5, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    probs = rng.dirichlet(np.full(k, conc)).astype(np.float32)
    tbl = spc.tables_from_probs(jnp.asarray(probs, dtype))
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    return tbl, syms


# ---------------------------------------------------------------------------
# rans_encode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,lanes,t,lane_block", [
    (256, 128, 64, 128),
    (64, 256, 33, 128),     # multi-block grid, odd T
    (17, 128, 128, 64),     # non-pow2 alphabet, smaller block
    (2, 128, 16, 128),      # binary alphabet
])
def test_encode_kernel_bit_exact(k, lanes, t, lane_block):
    tbl, syms = _case(k * 7 + t, k, lanes, t)
    got = ops.rans_encode(syms, tbl, lane_block=lane_block)
    want = ref.rans_encode_ref(syms, tbl)
    np.testing.assert_array_equal(np.asarray(got.start),
                                  np.asarray(want.start))
    np.testing.assert_array_equal(np.asarray(got.buf), np.asarray(want.buf))
    np.testing.assert_array_equal(np.asarray(got.length),
                                  np.asarray(want.length))


@pytest.mark.parametrize("in_dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_encode_kernel_prob_dtypes(in_dtype):
    tbl, syms = _case(5, 32, 128, 40, dtype=in_dtype)
    got = ops.rans_encode(syms, tbl)
    want = ref.rans_encode_ref(syms, tbl)
    np.testing.assert_array_equal(np.asarray(got.buf), np.asarray(want.buf))


def test_encode_kernel_skewed():
    k, lanes, t = 256, 128, 100
    rng = np.random.default_rng(2)
    p = np.full(k, 1e-8)
    p[3] = 1.0
    tbl = spc.tables_from_probs(jnp.asarray(p / p.sum(), jnp.float32))
    syms = jnp.asarray(
        np.where(rng.random((lanes, t)) < 0.97, 3,
                 rng.integers(0, k, (lanes, t))), jnp.int32)
    got = ops.rans_encode(syms, tbl)
    want = ref.rans_encode_ref(syms, tbl)
    np.testing.assert_array_equal(np.asarray(got.buf), np.asarray(want.buf))


# ---------------------------------------------------------------------------
# rans_decode kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k,lanes,t,use_pred", [
    (256, 128, 64, False),
    (256, 128, 64, True),
    (40, 256, 50, True),
    (2, 128, 31, False),
])
def test_decode_kernel_roundtrip(k, lanes, t, use_pred):
    tbl, syms = _case(k + lanes + t, k, lanes, t)
    enc = coder.encode(syms, tbl)
    got, _ = ops.rans_decode(enc, t, tbl, use_pred=use_pred)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(syms))


def test_decode_kernel_probes_match_core():
    """The kernel's probe accounting must equal the core decoder's (the
    Fig. 4(b) metric is implementation-independent)."""
    k, lanes, t = 256, 128, 128
    rng = np.random.default_rng(9)
    steps = rng.integers(-3, 4, (lanes, t))
    syms = np.clip(128 + np.cumsum(steps, axis=1), 0, k - 1)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(
        np.bincount(syms.ravel(), minlength=k)))
    enc = coder.encode(jnp.asarray(syms), tbl)
    for use_pred in (False, True):
        got, g_avg, g_lanes = ops.rans_decode(enc, t, tbl, use_pred=use_pred,
                                              lane_probes=True)
        want, w_avg, w_lanes = ref.rans_decode_ref(enc, t, tbl,
                                                   use_pred=use_pred,
                                                   lane_probes=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # canonical accounting (core/search.py): integer-identical per lane
        np.testing.assert_array_equal(np.asarray(g_lanes),
                                      np.asarray(w_lanes))
        assert abs(float(g_avg) - float(w_avg)) < 1e-5
    # prediction must help on this correlated data
    _, base = ops.rans_decode(enc, t, tbl, use_pred=False)
    _, guided = ops.rans_decode(enc, t, tbl, use_pred=True)
    assert float(guided) < 0.75 * float(base)


# ---------------------------------------------------------------------------
# chunked payloads: kernel == coder per chunk (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("chunk_size", [32, 70, 71])   # ragged / exact / one
def test_encode_kernel_chunked_bit_exact(chunk_size):
    """ops.rans_encode_chunked (fused kernel: chunk grid axis + in-kernel
    byte compaction) must be byte-identical to coder.encode_chunked."""
    k, lanes, t = 64, 128, 70
    tbl, syms = _case(99, k, lanes, t)
    got = ops.rans_encode_chunked(syms, tbl, chunk_size)
    want = coder.encode_chunked(syms, tbl, chunk_size)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_encode_kernel_on_chunk_payloads():
    """Standalone kernel encode of each chunk slice == the chunk's cell
    (the chunk-aware cap keeps the fused streams' layout aligned)."""
    k, lanes, t, chunk_size = 64, 128, 70, 32
    tbl, syms = _case(98, k, lanes, t)
    ch = coder.encode_chunked(syms, tbl, chunk_size)
    cap = ch.buf.shape[-1]
    for c, n in enumerate(coder.chunk_lengths(t, chunk_size)):
        sl = syms[:, c * chunk_size:c * chunk_size + n]
        std = ops.rans_encode(sl, tbl, cap=cap)
        got = coder.chunk_encoded(ch, c)
        np.testing.assert_array_equal(np.asarray(std.buf),
                                      np.asarray(got.buf))
        np.testing.assert_array_equal(np.asarray(std.start),
                                      np.asarray(got.start))


@pytest.mark.parametrize("use_pred", [False, True])
def test_decode_kernel_on_chunk_payloads(use_pred):
    """Kernel decode of every chunk matches the core decoder's symbols AND
    probe accounting (the Fig. 4(b) metric survives chunking)."""
    k, lanes, t, chunk_size = 256, 128, 96, 40
    rng = np.random.default_rng(77)
    steps = rng.integers(-3, 4, (lanes, t))
    syms = np.clip(128 + np.cumsum(steps, axis=1), 0, k - 1)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(
        np.bincount(syms.ravel(), minlength=k)))
    ch = coder.encode_chunked(jnp.asarray(syms), tbl, chunk_size)
    for c, n in enumerate(coder.chunk_lengths(t, chunk_size)):
        enc_c = coder.chunk_encoded(ch, c)
        got, g_avg, g_lanes = ops.rans_decode(enc_c, n, tbl,
                                              use_pred=use_pred,
                                              lane_probes=True)
        want, w_avg, w_lanes = ref.rans_decode_ref(enc_c, n, tbl,
                                                   use_pred=use_pred,
                                                   lane_probes=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        np.testing.assert_array_equal(
            np.asarray(got), syms[:, c * chunk_size:c * chunk_size + n])
        np.testing.assert_array_equal(np.asarray(g_lanes),
                                      np.asarray(w_lanes), f"chunk {c}")
        assert abs(float(g_avg) - float(w_avg)) < 1e-5, f"chunk {c} probes"
    # the one-shot chunked wrapper mirrors rans_encode_chunked
    pred = NeighborAverage(window=4, delta=8) if use_pred else None
    got_all, _ = ops.rans_decode_chunked(ch, t, tbl, chunk_size,
                                         predictor=pred)
    np.testing.assert_array_equal(np.asarray(got_all), syms)


# ---------------------------------------------------------------------------
# spc_quantize kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,conc", [
    (8, 256, 0.3),
    (16, 64, 2.0),
    (8, 300, 0.1),   # non-pow2 K
])
def test_spc_kernel_matches_ref(b, k, conc):
    rng = np.random.default_rng(b * k)
    probs = jnp.asarray(rng.dirichlet(np.full(k, conc), size=b), jnp.float32)
    got = np.asarray(ops.spc_quantize_tables(probs).freq)
    want = np.asarray(ref.spc_quantize_ref(probs))
    np.testing.assert_array_equal(got, want)
    assert (got.sum(-1) == 1 << C.PROB_BITS).all()


def test_spc_kernel_pathological_rows():
    total = 1 << C.PROB_BITS
    k = 128
    rows = np.stack([
        np.full(k, 1.0 / k),
        np.r_[1.0, np.zeros(k - 1)],
        np.r_[np.full(k - 1, 1e-9), [1.0]],
        np.full(k, 1 / 3),                # unnormalized on purpose
    ] * 2)
    got = np.asarray(ops.spc_quantize_tables(
        jnp.asarray(rows, jnp.float32), batch_block=8).freq)
    want = np.asarray(ref.spc_quantize_ref(jnp.asarray(rows, jnp.float32)))
    np.testing.assert_array_equal(got, want)
    assert (got.sum(-1) == total).all() and got.min() >= 1


def test_spc_kernel_end_to_end_coding():
    """Kernel-built tables must drive a bit-exact encode/decode roundtrip."""
    rng = np.random.default_rng(77)
    k, lanes, t = 64, 128, 64
    probs = jnp.asarray(rng.dirichlet(np.ones(k), size=8), jnp.float32)
    tbl_all = ops.spc_quantize_tables(probs)
    tbl = jax.tree.map(lambda a: a[0], tbl_all)
    syms = jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)
    enc = ops.rans_encode(syms, tbl)
    dec, _ = ops.rans_decode(enc, t, tbl)
    np.testing.assert_array_equal(np.asarray(dec), np.asarray(syms))
