"""Fig. 4(b): decoder CDF-search cost — baseline binary search vs
prediction-guided decoding (paper: 7.00 -> 3.15 avg steps, ~55% fewer).

    PYTHONPATH=src python -m benchmarks.bench_search [--out BENCH_search.json]

Workload: spatially-correlated image-like rows (the paper's image
workloads); predictor: neighbour average with the paper's +-8 window.

Unified probe telemetry: both decode backends — the pure-JAX lane coder and
the Pallas decode kernel (interpret mode on CPU) — consume
``repro.core.search``, so the Fig. 4(b) numbers reported here come from the
*same canonical counters* regardless of which backend ran the decode.  The
sweep decodes with both, asserts the per-lane counters are integer-identical,
and reports once per point.
"""

from __future__ import annotations

import argparse
import json

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, spc
from repro.core.predictors import NeighborAverage
from repro.data.pipeline import image_rows
from repro.kernels import ops


POINTS = (
    # paper's Fig. 3 window (+-8) and its dichotomous refinement (+-4);
    # the refined window with a short (last-2) context is our best point.
    ("baseline", None),
    ("pm8", NeighborAverage(window=4, delta=8)),
    ("pm4_refined", NeighborAverage(window=2, delta=4)),
)


def run(lanes: int = 64, t: int = 2048, seed: int = 0,
        check_kernel: bool = True) -> list[dict]:
    rows = image_rows(lanes, t, seed=seed)
    counts = np.bincount(rows.ravel(), minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    enc = coder.encode(jnp.asarray(rows, jnp.int32), tbl)

    points = []
    for name, pred in POINTS:
        sym, avg, per_lane = coder.decode(enc, t, tbl, predictor=pred,
                                          lane_probes=True)
        assert np.array_equal(np.asarray(sym), rows)
        point = {"name": name, "lanes": lanes, "n_symbols": t,
                 "avg_steps": float(avg),
                 "probe_total": int(np.asarray(per_lane).sum()),
                 "backends_agree": None}
        if check_kernel:
            ksym, kavg, kper = ops.rans_decode(enc, t, tbl, predictor=pred,
                                               lane_probes=True)
            same = (np.array_equal(np.asarray(ksym), rows)
                    and np.array_equal(np.asarray(kper),
                                       np.asarray(per_lane)))
            assert same, f"{name}: kernel/coder probe counters diverge"
            point["backends_agree"] = True
        points.append(point)
    return points


def main(emit):
    pts = {p["name"]: p for p in run(t=1024)}
    base = pts["baseline"]["avg_steps"]
    emit("fig4b_search_steps_baseline", base, "paper: 7.00")
    emit("fig4b_search_steps_guided_pm8", pts["pm8"]["avg_steps"],
         f"paper window +-8; reduction={1 - pts['pm8']['avg_steps']/base:.1%}")
    emit("fig4b_search_steps_guided_pm4", pts["pm4_refined"]["avg_steps"],
         f"paper: 3.15 (+-4 refined); "
         f"reduction={1 - pts['pm4_refined']['avg_steps']/base:.1%}"
         " (paper ~55%)")
    emit("fig4b_backend_agreement",
         float(all(p["backends_agree"] for p in pts.values())),
         "1.0 = kernel and coder probe counters integer-identical")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_search.json")
    args = ap.parse_args()
    pts = run()
    with open(args.out, "w") as f:
        json.dump(pts, f, indent=2)
    base = pts[0]["avg_steps"]
    for p in pts:
        print(f"{p['name']}: {p['avg_steps']:.3f} steps/symbol "
              f"(reduction {1 - p['avg_steps']/base:.1%}, "
              f"backends_agree={p['backends_agree']})")
    print(f"wrote {len(pts)} points -> {args.out}")
