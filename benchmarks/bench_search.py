"""Fig. 4(b): decoder CDF-search cost — baseline binary search vs
prediction-guided decoding (paper: 7.00 -> 3.15 avg steps, ~55% fewer).

    PYTHONPATH=src python -m benchmarks.bench_search \
        [--out BENCH_search.json] [--decode-out BENCH_decode.json]

Workload: spatially-correlated image-like rows (the paper's image
workloads); predictor: neighbour average with the paper's +-8 window.

Unified probe telemetry: both decode backends — the pure-JAX lane coder and
the Pallas decode kernel (interpret mode on CPU) — consume
``repro.core.search``, so the Fig. 4(b) numbers reported here come from the
*same canonical counters* regardless of which backend ran the decode.  The
sweep decodes with both, asserts the per-lane counters are integer-identical,
and reports once per point.

``--decode-out`` additionally runs the decode-backend sweep: coder vs
kernel x static/adaptive/chunked table layouts x model-top-k candidate
speculation topk in {0, 4} — symbol and probe identity asserted at every
point, mean probes reported per point (BENCH_decode.json).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, spc
from repro.core.predictors import NeighborAverage
from repro.data.pipeline import candidate_planes, image_rows
from repro.kernels import ops


POINTS = (
    # paper's Fig. 3 window (+-8) and its dichotomous refinement (+-4);
    # the refined window with a short (last-2) context is our best point.
    ("baseline", None),
    ("pm8", NeighborAverage(window=4, delta=8)),
    ("pm4_refined", NeighborAverage(window=2, delta=4)),
)


def run(lanes: int = 64, t: int = 2048, seed: int = 0,
        check_kernel: bool = True) -> list[dict]:
    rows = image_rows(lanes, t, seed=seed)
    counts = np.bincount(rows.ravel(), minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    enc = coder.encode(jnp.asarray(rows, jnp.int32), tbl)

    points = []
    for name, pred in POINTS:
        sym, avg, per_lane = coder.decode(enc, t, tbl, predictor=pred,
                                          lane_probes=True)
        assert np.array_equal(np.asarray(sym), rows)
        point = {"name": name, "lanes": lanes, "n_symbols": t,
                 "avg_steps": float(avg),
                 "probe_total": int(np.asarray(per_lane).sum()),
                 "backends_agree": None}
        if check_kernel:
            ksym, kavg, kper = ops.rans_decode(enc, t, tbl, predictor=pred,
                                               lane_probes=True)
            same = (np.array_equal(np.asarray(ksym), rows)
                    and np.array_equal(np.asarray(kper),
                                       np.asarray(per_lane)))
            assert same, f"{name}: kernel/coder probe counters diverge"
            point["backends_agree"] = True
        points.append(point)
    return points


def _decode_stream_hbm_bytes(n_chunks: int, lanes: int, cap: int,
                             payload_bytes: int, index_bytes: int) -> dict:
    """Analytic decode-side HBM stream traffic: host-gather vs zero-copy.

    Host-gather reference (``bitstream.unpack_chunked``): the packed payload
    is read once on the host, right-aligned into a dense
    ``(n_chunks, lanes, cap)`` stream slab that is then written to device
    and read back by the kernel — every encoded byte crosses memory ~3x
    and every *pad* byte of the dense slab crosses twice.  Zero-copy
    (``from_container``): the slab ships once as-is plus the small
    (offset, length) index planes; the kernel DMAs each window straight
    out of it (DESIGN.md §10).
    """
    dense = n_chunks * lanes * cap
    return {
        "hostgather_stream_hbm_bytes": payload_bytes + 2 * dense,
        "zerocopy_stream_hbm_bytes": payload_bytes + index_bytes,
    }


def run_decode_sweep(lanes: int = 8, t: int = 256, seed: int = 1,
                     chunk_size: int = 48, topks=(0, 4),
                     hit_rate: float = 0.8) -> list[dict]:
    """Decode-backend sweep: coder vs kernel x table layout x topk.

    Every point decodes the same stream on both backends and asserts
    byte-identical symbols + integer-identical per-lane probe counters;
    the emitted rows carry one mean-probe number per point (they are the
    same counters on both backends by construction).

    Chunked points additionally round-trip the stream through the v2
    container and decode it a third time ZERO-COPY from the packed slab
    (``from_container``), asserting symbol/probe identity with the dense
    kernel decode, and report the decode-side bytes-moved ledger
    (``{hostgather,zerocopy}_stream_hbm_bytes`` — the PR 5 encode ledger's
    decode mirror, DESIGN.md §10).
    """
    from repro.core import bitstream
    rng = np.random.default_rng(seed)
    k = 256
    rows = image_rows(lanes, t, seed=seed)
    static_tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(
        np.bincount(rows.ravel(), minlength=k)))
    perpos_tbl = spc.tables_from_probs(jnp.asarray(
        rng.dirichlet(np.full(k, 0.4), size=t), jnp.float32))
    syms = jnp.asarray(rows, jnp.int32)

    layouts = {
        "static": (static_tbl, False),
        "adaptive": (perpos_tbl, False),
        "chunked": (perpos_tbl, True),
    }
    points = []
    for layout, (tbl, chunked) in layouts.items():
        if chunked:
            stream = coder.encode_chunked(syms, tbl, chunk_size)
        else:
            stream = coder.encode(syms, tbl)
        for topk in topks:
            cands = (jnp.asarray(candidate_planes(rows, k, topk, hit_rate,
                                                  seed + 7), jnp.int32)
                     if topk else None)
            if chunked:
                csym, cavg, cl = coder.decode_chunked(
                    stream, t, tbl, chunk_size, candidates=cands,
                    lane_probes=True)
                ksym, kavg, kl = ops.rans_decode_chunked(
                    stream, t, tbl, chunk_size, candidates=cands,
                    lane_probes=True)
            else:
                csym, cavg, cl = coder.decode(stream, t, tbl,
                                              candidates=cands,
                                              lane_probes=True)
                ksym, kavg, kl = ops.rans_decode(stream, t, tbl,
                                                 candidates=cands,
                                                 lane_probes=True)
            assert np.array_equal(np.asarray(csym), np.asarray(ksym))
            assert np.array_equal(np.asarray(csym), rows)
            assert np.array_equal(np.asarray(cl), np.asarray(kl)), (
                f"{layout} topk={topk}: probe counters diverge")
            ledger = {"hostgather_stream_hbm_bytes": None,
                      "zerocopy_stream_hbm_bytes": None,
                      "stream_hbm_bytes_saved": None,
                      "container_zero_copy_identical": None}
            if chunked:
                blob = bitstream.pack_chunked(
                    np.asarray(stream.buf), np.asarray(stream.start),
                    np.asarray(stream.length), np.asarray(stream.overflow),
                    chunk_size=chunk_size, n_symbols=t)
                cs = bitstream.parse_chunked(blob)
                zsym, zavg, zl = ops.rans_decode_chunked(
                    n_symbols=t, tbl=tbl, chunk_size=chunk_size,
                    candidates=cands, lane_probes=True, from_container=cs)
                assert np.array_equal(np.asarray(zsym), np.asarray(ksym)), (
                    f"{layout} topk={topk}: zero-copy symbols diverge")
                assert np.array_equal(np.asarray(zl), np.asarray(kl)), (
                    f"{layout} topk={topk}: zero-copy probes diverge")
                n_chunks, cap = stream.buf.shape[0], stream.buf.shape[2]
                payload = int(np.asarray(stream.length).sum())
                index = cs.offset.size * 12      # (offset u64, length u32)
                ledger.update(_decode_stream_hbm_bytes(
                    n_chunks, lanes, cap, payload, index))
                ledger["stream_hbm_bytes_saved"] = (
                    ledger["hostgather_stream_hbm_bytes"]
                    - ledger["zerocopy_stream_hbm_bytes"])
                ledger["container_zero_copy_identical"] = True
            points.append({
                "layout": layout, "topk": topk, "lanes": lanes,
                "n_symbols": t, "hit_rate": hit_rate if topk else None,
                "avg_probes": float(np.asarray(cl).sum()) / (lanes * t),
                "backends_agree": True,
                **ledger,
            })
    return points


def run_serve_sweep(lanes: int = 4, t: int = 128, seed: int = 2,
                    topk: int = 4, reps: int = 3) -> list[dict]:
    """Serve-decode latency: the fused single-program path vs the retained
    references (DESIGN.md §9).

    One LM-compressed stream, decoded by all three ``lm_decompress``
    backends — ``kernel`` (the fused program: model step + SPC fast path +
    per-step Pallas kernel in ONE ``lax.scan``), ``two_pass`` (pure-JAX
    collect scan + whole-stream kernel replay) and ``coder`` (pure JAX end
    to end).  Symbols and per-lane probe counters are asserted
    integer-identical across backends before any latency is reported;
    best-of-``reps`` wall time per point, warmup excluded.
    """
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.serve.compress import lm_compress, lm_decompress
    cfg = get_smoke_config("ras-pimc")
    params = init_model(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(
        np.asarray(image_rows(lanes, t, seed=seed)) % cfg.vocab_size,
        jnp.int32)
    stats = lm_compress(params, cfg, toks, backend="kernel")

    points, ref_lane = [], None
    for backend in ("kernel", "two_pass", "coder"):
        def call():
            sym, _, lane = lm_decompress(params, cfg, stats.enc, t,
                                         topk=topk, backend=backend,
                                         lane_probes=True)
            jax.block_until_ready(sym)
            return sym, lane

        sym, lane = call()                      # warmup + differential gate
        assert np.array_equal(np.asarray(sym), np.asarray(toks)), backend
        if ref_lane is None:
            ref_lane = np.asarray(lane)
        else:
            assert np.array_equal(ref_lane, np.asarray(lane)), (
                f"{backend}: probe counters diverge from fused path")
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            call()
            times.append(time.perf_counter() - t0)
        best = min(times)
        points.append({"backend": backend, "lanes": lanes, "n_symbols": t,
                       "topk": topk, "best_s": best,
                       "us_per_symbol": best * 1e6 / (lanes * t),
                       "backends_agree": True})
    return points


def main(emit):
    pts = {p["name"]: p for p in run(t=1024)}
    base = pts["baseline"]["avg_steps"]
    emit("fig4b_search_steps_baseline", base, "paper: 7.00")
    emit("fig4b_search_steps_guided_pm8", pts["pm8"]["avg_steps"],
         f"paper window +-8; reduction={1 - pts['pm8']['avg_steps']/base:.1%}")
    emit("fig4b_search_steps_guided_pm4", pts["pm4_refined"]["avg_steps"],
         f"paper: 3.15 (+-4 refined); "
         f"reduction={1 - pts['pm4_refined']['avg_steps']/base:.1%}"
         " (paper ~55%)")
    emit("fig4b_backend_agreement",
         float(all(p["backends_agree"] for p in pts.values())),
         "1.0 = kernel and coder probe counters integer-identical")
    dec = {(p["layout"], p["topk"]): p for p in run_decode_sweep(t=128)}
    spec, nospec = dec[("static", 4)], dec[("static", 0)]
    emit("decode_sweep_speculation_probes", spec["avg_probes"],
         f"model-top-4 candidates; no-spec={nospec['avg_probes']:.2f}, "
         f"reduction={1 - spec['avg_probes']/nospec['avg_probes']:.1%}")
    srv = {p["backend"]: p for p in run_serve_sweep(t=96)}
    fused, twop = srv["kernel"], srv["two_pass"]
    emit("serve_decode_us_per_symbol_fused", fused["us_per_symbol"],
         f"two_pass={twop['us_per_symbol']:.1f}us "
         f"coder={srv['coder']['us_per_symbol']:.1f}us; fused speedup over "
         f"two-pass = {twop['best_s']/fused['best_s']:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_search.json")
    ap.add_argument("--decode-out", default="BENCH_decode.json")
    args = ap.parse_args()
    pts = run()
    with open(args.out, "w") as f:
        json.dump(pts, f, indent=2)
    base = pts[0]["avg_steps"]
    for p in pts:
        print(f"{p['name']}: {p['avg_steps']:.3f} steps/symbol "
              f"(reduction {1 - p['avg_steps']/base:.1%}, "
              f"backends_agree={p['backends_agree']})")
    print(f"wrote {len(pts)} points -> {args.out}")
    dpts = run_decode_sweep()
    for p in dpts:
        print(f"{p['layout']} topk={p['topk']}: "
              f"{p['avg_probes']:.3f} probes/symbol")
    spts = run_serve_sweep()
    for p in spts:
        print(f"serve backend={p['backend']}: "
              f"{p['us_per_symbol']:.1f} us/symbol")
    fused = next(p for p in spts if p["backend"] == "kernel")
    twop = next(p for p in spts if p["backend"] == "two_pass")
    print(f"fused speedup over two-pass: "
          f"{twop['best_s']/fused['best_s']:.2f}x")
    with open(args.decode_out, "w") as f:
        json.dump(dpts + spts, f, indent=2)
    print(f"wrote {len(dpts) + len(spts)} points -> {args.decode_out}")
