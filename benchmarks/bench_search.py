"""Fig. 4(b): decoder CDF-search cost — baseline binary search vs
prediction-guided decoding (paper: 7.00 -> 3.15 avg steps, ~55% fewer).

Workload: spatially-correlated image-like rows (the paper's image
workloads); predictor: neighbour average with the paper's +-8 window.
"""

from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, spc
from repro.core.predictors import NeighborAverage
from repro.data.pipeline import image_rows


def run(lanes: int = 64, t: int = 2048, seed: int = 0):
    rows = image_rows(lanes, t, seed=seed)
    counts = np.bincount(rows.ravel(), minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    enc = coder.encode(jnp.asarray(rows, jnp.int32), tbl)

    base_sym, base_probes = coder.decode(enc, t, tbl)
    assert np.array_equal(np.asarray(base_sym), rows)
    out = {"baseline_steps": float(base_probes)}
    # paper's Fig. 3 window (+-8) and its dichotomous refinement (+-4);
    # the refined window with a short (last-2) context is our best point.
    for name, window, delta in (("pm8", 4, 8), ("pm4_refined", 2, 4)):
        sym, probes = coder.decode(
            enc, t, tbl, predictor=NeighborAverage(window=window,
                                                   delta=delta))
        assert np.array_equal(np.asarray(sym), rows)
        out[name] = float(probes)
    return out


def main(emit):
    r = run()
    base = r["baseline_steps"]
    emit("fig4b_search_steps_baseline", base, "paper: 7.00")
    emit("fig4b_search_steps_guided_pm8", r["pm8"],
         f"paper window +-8; reduction={1 - r['pm8']/base:.1%}")
    emit("fig4b_search_steps_guided_pm4", r["pm4_refined"],
         f"paper: 3.15 (+-4 refined); reduction={1 - r['pm4_refined']/base:.1%}"
         " (paper ~55%)")
