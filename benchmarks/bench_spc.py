"""SPC conversion cost (Sec. IV-A: single-pass BF16->fixed-point off the
critical path): batched quantization throughput + table-build latency,
pure-JAX vs the Pallas SPC kernel.

    PYTHONPATH=src python -m benchmarks.bench_spc [--out BENCH_spc.json]

Both sides build full TableSets from the same probability batch; the
frequency planes are asserted integer-identical before any latency is
reported (the kernel runs the Pallas interpreter on CPU — its wall-clock
here tracks the interpreter, the identity seal is the point).
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import spc


def _timed(fn, arg):
    out = fn(arg)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    out = fn(arg)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return time.perf_counter() - t0, out


def run(batch: int = 256, k: int = 256, seed: int = 0,
        kernel: bool = True) -> dict:
    rng = np.random.default_rng(seed)
    probs = jnp.asarray(rng.dirichlet(np.full(k, 0.5), size=batch),
                        jnp.float32)

    dt, tbl = _timed(jax.jit(lambda p: spc.tables_from_probs(p)), probs)
    out = {
        "batch": batch, "k": k,
        "us_per_table": dt / batch * 1e6,
        "tables_per_s": batch / dt,
        "kernel_us_per_table": None,
        "kernel_freq_identical": None,
    }
    if kernel:
        from repro.kernels import ops
        kdt, ktbl = _timed(lambda p: ops.spc_quantize_tables(p), probs)
        assert np.array_equal(np.asarray(tbl.freq), np.asarray(ktbl.freq)), (
            "kernel SPC frequency planes diverge from the pure-JAX SPC")
        out.update({
            "kernel_us_per_table": kdt / batch * 1e6,
            "kernel_freq_identical": True,
        })
    return out


def main(emit):
    r = run()
    emit("spc_convert_us_per_table", r["us_per_table"],
         f"{r['tables_per_s']:.0f} tables/s (K={r['k']}, incl. mass "
         f"correction)")
    emit("spc_convert_kernel_us_per_table", r["kernel_us_per_table"],
         f"Pallas SPC kernel (INTERPRET; freq planes "
         f"identical={r['kernel_freq_identical']})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_spc.json")
    args = ap.parse_args()
    r = run()
    print(f"pure-JAX: {r['us_per_table']:.1f} us/table "
          f"({r['tables_per_s']:.0f} tables/s); kernel: "
          f"{r['kernel_us_per_table']:.1f} us/table, "
          f"freq-identical={r['kernel_freq_identical']}")
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
    print(f"wrote -> {args.out}")
