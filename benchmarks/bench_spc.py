"""SPC conversion cost (Sec. IV-A: single-pass BF16->fixed-point off the
critical path): batched quantization throughput + table-build latency."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import spc


def run(batch: int = 256, k: int = 256, seed: int = 0):
    rng = np.random.default_rng(seed)
    probs = jnp.asarray(rng.dirichlet(np.full(k, 0.5), size=batch),
                        jnp.float32)
    fn = jax.jit(lambda p: spc.tables_from_probs(p))
    tbl = fn(probs)
    jax.block_until_ready(tbl.freq)
    t0 = time.perf_counter()
    tbl = fn(probs)
    jax.block_until_ready(tbl.freq)
    dt = time.perf_counter() - t0
    return {"us_per_table": dt / batch * 1e6,
            "tables_per_s": batch / dt}


def main(emit):
    r = run()
    emit("spc_convert_us_per_table", r["us_per_table"],
         f"{r['tables_per_s']:.0f} tables/s (K=256, incl. mass correction)")
