"""Chunked streaming codec throughput vs. chunk size and lane count.

    PYTHONPATH=src python -m benchmarks.bench_chunked [--out BENCH_chunked.json]

Sweeps the chunk-size x lane-count grid through encode_chunked /
decode_chunked (the shard_map placement when more than one device is
visible, the vmap path otherwise) and reports Msym/s plus the per-chunk
flush overhead in bits/symbol.  Standalone runs emit ``BENCH_chunked.json``
(a list of point records); ``main(emit)`` plugs into benchmarks.run.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, spc
from repro.data.pipeline import image_rows
from repro.parallel import chunked as pchunked


def _time(fn, *args):
    out = fn(*args)                      # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def run(t: int = 2048, chunk_sizes=(128, 512, 2048), lane_counts=(8, 64, 256),
        seed: int = 0) -> list[dict]:
    counts = np.bincount(image_rows(8, 4096, seed=seed).ravel(),
                         minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    mesh = pchunked.chunk_mesh() if len(jax.devices()) > 1 else None
    points = []
    for lanes in lane_counts:
        rows = jnp.asarray(image_rows(lanes, t, seed=seed), jnp.int32)
        mono = coder.encode(rows, tbl)
        mono_bits = float(np.asarray(mono.length).sum()) * 8 / (lanes * t)
        for cs in chunk_sizes:
            enc = pchunked.encode_chunked(rows, tbl, cs, mesh=mesh)
            dt_enc = _time(
                lambda r: pchunked.encode_chunked(r, tbl, cs, mesh=mesh),
                rows)
            dt_dec = _time(
                lambda e: pchunked.decode_chunked(e, t, tbl, cs,
                                                  mesh=mesh)[0], enc)
            bits = float(np.asarray(enc.length).sum()) * 8 / (lanes * t)
            points.append({
                "name": f"chunked_l{lanes}_c{cs}",
                "lanes": lanes,
                "chunk_size": cs,
                "n_symbols": t,
                "n_chunks": coder.num_chunks(t, cs),
                "encode_Msym_s": lanes * t / dt_enc / 1e6,
                "decode_Msym_s": lanes * t / dt_dec / 1e6,
                "bits_per_symbol": bits,
                "flush_overhead_bits": bits - mono_bits,
                "devices": len(jax.devices()),
            })
    return points


def main(emit):
    for p in run(t=1024, chunk_sizes=(128, 1024), lane_counts=(8, 64)):
        emit(f"{p['name']}_enc_Msym_s", p["encode_Msym_s"],
             f"decode {p['decode_Msym_s']:.1f} Msym/s, "
             f"+{p['flush_overhead_bits']:.3f} bits flush overhead")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_chunked.json")
    args = ap.parse_args()
    pts = run()
    with open(args.out, "w") as f:
        json.dump(pts, f, indent=2)
    for p in pts:
        print(f"{p['name']}: enc {p['encode_Msym_s']:.1f} "
              f"dec {p['decode_Msym_s']:.1f} Msym/s "
              f"({p['bits_per_symbol']:.3f} bits/sym)")
    print(f"wrote {len(pts)} points -> {args.out}")
