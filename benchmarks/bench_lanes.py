"""Multi-lane scaling (paper Sec. III: 'a simple multi-lane fabric ...
scales throughput'): encode+decode throughput vs lane count, on BOTH
coder backends and through the v2 container round trip.

    PYTHONPATH=src python -m benchmarks.bench_lanes [--out BENCH_lanes.json]

Per lane count the sweep encodes one chunked stream with the pure-JAX lane
coder and the fused Pallas kernel (asserted byte-identical), packs it into
the v2 container, and decodes it back two ways — the coder backend from the
host-unpacked dense slab and the kernel backend ZERO-COPY from the packed
payload (``from_container``) — asserting symbol identity throughout.
Kernel timings run the Pallas *interpreter* on CPU (see bench_speed), so
the scaling curve that matters for the paper claim is the coder one; the
kernel columns are the tracked bit-exactness seal + shape baseline.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import bitstream, coder, spc
from repro.data.pipeline import image_rows


def _timed(fn, *args):
    out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    t0 = time.perf_counter()
    out = fn(*args)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return time.perf_counter() - t0, out


def run(t: int = 1024, lane_counts=(8, 32, 128), chunk_size: int = 256,
        seed: int = 0, kernel: bool = True) -> list[dict]:
    counts = np.bincount(image_rows(8, 4096, seed=seed).ravel(),
                         minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    points = []
    for lanes in lane_counts:
        rows = image_rows(lanes, t, seed=seed)
        syms = jnp.asarray(rows, jnp.int32)

        enc_dt, ch = _timed(
            jax.jit(lambda s: coder.encode_chunked(s, tbl, chunk_size)),
            syms)
        dec_dt, (dec, _) = _timed(
            jax.jit(lambda c: coder.decode_chunked(c, t, tbl, chunk_size)),
            ch)
        assert np.array_equal(np.asarray(dec), rows)

        point = {
            "lanes": int(lanes), "n_symbols": t, "chunk_size": chunk_size,
            "coder_encode_Msym_s": lanes * t / enc_dt / 1e6,
            "coder_decode_Msym_s": lanes * t / dec_dt / 1e6,
            "kernel_encode_Msym_s": None,
            "kernel_decode_zero_copy_Msym_s": None,
            "container_bytes": None,
            "backends_byte_identical": None,
        }

        if kernel:
            from repro.kernels import ops
            kenc_dt, kch = _timed(
                lambda s: ops.rans_encode_chunked(s, tbl, chunk_size), syms)
            for a, b in zip(ch, kch):
                assert np.array_equal(np.asarray(a), np.asarray(b)), (
                    f"lanes={lanes}: kernel/coder streams diverge")
            blob = bitstream.pack_chunked(
                np.asarray(kch.buf), np.asarray(kch.start),
                np.asarray(kch.length), np.asarray(kch.overflow),
                chunk_size=chunk_size, n_symbols=t)
            cs = bitstream.parse_chunked(blob)
            kdec_dt, (kdec, _) = _timed(
                lambda c: ops.rans_decode_chunked(
                    n_symbols=t, tbl=tbl, chunk_size=chunk_size,
                    from_container=c), cs)
            assert np.array_equal(np.asarray(kdec), rows), (
                f"lanes={lanes}: zero-copy container decode diverges")
            point.update({
                "kernel_encode_Msym_s": lanes * t / kenc_dt / 1e6,
                "kernel_decode_zero_copy_Msym_s": lanes * t / kdec_dt / 1e6,
                "container_bytes": len(blob),
                "backends_byte_identical": True,
            })
        points.append(point)
    return points


def main(emit):
    pts = run()
    base = pts[0]
    for p in pts:
        emit(f"lanes_{p['lanes']}_encode_Msym_s", p["coder_encode_Msym_s"],
             f"scaling x{p['coder_encode_Msym_s']/base['coder_encode_Msym_s']:.1f} "
             f"vs {base['lanes']} lanes")
        emit(f"lanes_{p['lanes']}_decode_Msym_s", p["coder_decode_Msym_s"],
             f"zero-copy kernel decode byte-identical="
             f"{p['backends_byte_identical']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_lanes.json")
    args = ap.parse_args()
    pts = run()
    with open(args.out, "w") as f:
        json.dump(pts, f, indent=2)
    for p in pts:
        print(f"lanes={p['lanes']}: coder enc "
              f"{p['coder_encode_Msym_s']:.2f} / dec "
              f"{p['coder_decode_Msym_s']:.2f} Msym/s, kernel enc "
              f"{p['kernel_encode_Msym_s']:.2f} / zero-copy dec "
              f"{p['kernel_decode_zero_copy_Msym_s']:.2f} Msym/s "
              f"(container {p['container_bytes']} B, "
              f"byte-identical={p['backends_byte_identical']})")
    print(f"wrote {len(pts)} points -> {args.out}")
