"""Multi-lane scaling (paper Sec. III: 'a simple multi-lane fabric ...
scales throughput'): encode+decode throughput vs lane count."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, spc
from repro.data.pipeline import image_rows


def run(t: int = 1024, lane_counts=(8, 32, 128, 512), seed: int = 0):
    counts = np.bincount(image_rows(8, 4096, seed=seed).ravel(),
                         minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    out = {}
    for lanes in lane_counts:
        rows = jnp.asarray(image_rows(lanes, t, seed=seed), jnp.int32)
        enc_fn = jax.jit(lambda s: coder.encode(s, tbl))
        enc = enc_fn(rows)
        jax.block_until_ready(enc.buf)
        t0 = time.perf_counter()
        enc = enc_fn(rows)
        jax.block_until_ready(enc.buf)
        dt = time.perf_counter() - t0
        out[lanes] = lanes * t / dt / 1e6  # Msym/s
    return out


def main(emit):
    r = run()
    base = r[min(r)]
    for lanes, msps in sorted(r.items()):
        emit(f"lanes_{lanes}_throughput_Msym_s", msps,
             f"scaling x{msps/base:.1f} vs {min(r)} lanes")
