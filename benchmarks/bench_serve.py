"""Batched serving engine vs. a serial one-request-at-a-time loop.

    PYTHONPATH=src python -m benchmarks.bench_serve [--out BENCH_serve.json]

Drives the same seeded Poisson compress workload through two servers:

* **serial** — the pre-engine deployment: requests queue FIFO and each one
  runs ``lm_compress_chunked`` + container pack start-to-finish before the
  next begins (arrivals respected: the loop sleeps until a request exists).
* **engine** — :class:`repro.serve.engine.BatchEngine` with wall-clock
  admission: requests are continuously batched into slots of one traced
  step program and ride the prefill fast path when eligible.

Both paths use the paper's full ``ras-pimc`` probability model (the
serving regime the engine exists for — per-symbol model cost dominating,
few rANS lanes per request), and every engine blob is asserted
byte-identical to the serial path's before any number is reported
(``byte_identical`` seals the record).  Latency is completion minus
arrival, so serial queueing delay is charged honestly.  Standalone runs
emit ``BENCH_serve.json``; ``main(emit)`` plugs into benchmarks.run.
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import bitstream
from repro.data.pipeline import token_stream
from repro.models import init_model
from repro.serve.compress import lm_compress_chunked
from repro.serve.engine import BatchEngine


def _serial_blob(params, cfg, toks, chunk_size, n_symbols):
    stats = lm_compress_chunked(params, cfg, jnp.asarray(toks),
                                chunk_size=chunk_size)
    enc = jax.tree.map(np.asarray, stats.chunks)
    return bitstream.pack_chunked(enc.buf, enc.start, enc.length,
                                  enc.overflow, chunk_size=chunk_size,
                                  n_symbols=n_symbols)


def _serial_run(params, cfg, streams, arrivals, chunk_size, n_symbols):
    """One-at-a-time server: FIFO by arrival, blobs + per-request latency."""
    blobs, lat = [], []
    t0 = time.perf_counter()
    for toks, arr in zip(streams, arrivals):
        gap = arr - (time.perf_counter() - t0)
        if gap > 0:                       # server idle until the request exists
            time.sleep(gap)
        blobs.append(_serial_blob(params, cfg, toks, chunk_size, n_symbols))
        lat.append((time.perf_counter() - t0) - arr)
    return blobs, np.asarray(lat), time.perf_counter() - t0


def _engine_run(params, cfg, streams, arrivals, *, slots, lanes, chunk_size,
                n_symbols, prefill="auto"):
    eng = BatchEngine(params, cfg, slots=slots, lanes=lanes,
                      chunk_size=chunk_size, max_len=n_symbols,
                      prefill=prefill)
    rids = [eng.submit_compress(s, arrival=float(a))
            for s, a in zip(streams, arrivals)]
    t0 = time.perf_counter()
    res = eng.run(clock="wall")
    wall = time.perf_counter() - t0
    blobs, lat = [], []
    for rid, arr in zip(rids, arrivals):
        r = res[rid]
        assert r.ok, r.error
        blobs.append(r.blob)
        lat.append(r.completed_at - arr)
    return blobs, np.asarray(lat), wall, eng.prefill_cycles


def run(streams: int = 16, slots: int = 4, lanes: int = 2,
        n_symbols: int = 64, chunk_size: int = 16,
        arrival_rate_hz: float = 200.0, seed: int = 0) -> list[dict]:
    cfg = get_config("ras-pimc")
    params = init_model(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / arrival_rate_hz,
                                         size=streams))
    data = [np.asarray(token_stream(cfg.vocab_size, (lanes, n_symbols),
                                    seed=100 + i), np.int32)
            for i in range(streams)]

    # warm both servers (compile), then time a clean pass of each.
    _serial_run(params, cfg, data, np.zeros(streams), chunk_size, n_symbols)
    _engine_run(params, cfg, data, np.zeros(streams), slots=slots,
                lanes=lanes, chunk_size=chunk_size, n_symbols=n_symbols)

    s_blobs, s_lat, s_wall = _serial_run(params, cfg, data, arrivals,
                                         chunk_size, n_symbols)
    e_blobs, e_lat, e_wall, pf = _engine_run(
        params, cfg, data, arrivals, slots=slots, lanes=lanes,
        chunk_size=chunk_size, n_symbols=n_symbols)
    identical = all(e == s for e, s in zip(e_blobs, s_blobs))
    assert identical, "engine blob diverged from the single-request path"

    return [{
        "name": f"serve_s{streams}_sl{slots}_l{lanes}_t{n_symbols}"
                f"_c{chunk_size}",
        "arch": cfg.name,
        "streams": streams,
        "slots": slots,
        "lanes": lanes,
        "n_symbols": n_symbols,
        "chunk_size": chunk_size,
        "arrival_rate_hz": arrival_rate_hz,
        "seed": seed,
        "serial_streams_per_s": streams / s_wall,
        "engine_streams_per_s": streams / e_wall,
        "speedup": s_wall / e_wall,
        "serial_p50_s": float(np.percentile(s_lat, 50)),
        "serial_p99_s": float(np.percentile(s_lat, 99)),
        "engine_p50_s": float(np.percentile(e_lat, 50)),
        "engine_p99_s": float(np.percentile(e_lat, 99)),
        "prefill_cycles": pf,
        "byte_identical": identical,
    }]


def main(emit):
    for p in run():
        emit(f"{p['name']}_speedup", p["speedup"],
             f"engine {p['engine_streams_per_s']:.1f} vs serial "
             f"{p['serial_streams_per_s']:.1f} streams/s, p99 "
             f"{p['engine_p99_s']:.2f}s vs {p['serial_p99_s']:.2f}s, "
             f"{p['prefill_cycles']} prefill cycles, byte-identical")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args()
    pts = run()
    with open(args.out, "w") as f:
        json.dump(pts, f, indent=2)
    for p in pts:
        print(f"{p['name']}: engine {p['engine_streams_per_s']:.1f} "
              f"streams/s vs serial {p['serial_streams_per_s']:.1f} "
              f"({p['speedup']:.2f}x), p99 {p['engine_p99_s']:.2f}s vs "
              f"{p['serial_p99_s']:.2f}s, byte-identical "
              f"{p['byte_identical']}")
    print(f"wrote {len(pts)} points -> {args.out}")
