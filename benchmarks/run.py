"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig4a_speed,...]

Prints ``name,us_per_call,derived`` CSV (us_per_call column holds the
figure's primary value when the metric is not a latency).
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

jax.config.update("jax_platforms", "cpu")

# suites import lazily so one missing optional dep (e.g. bench_ratio's
# zstandard baseline) cannot take down the others
SUITES = {
    "fig4a_speed": "bench_speed",
    "fig4b_search": "bench_search",
    "fig4c_ratio": "bench_ratio",
    "lanes": "bench_lanes",
    "spc": "bench_spc",
    "chunked": "bench_chunked",
    "serve": "bench_serve",
}


def _load(mod_name: str):
    import importlib
    return importlib.import_module(f"benchmarks.{mod_name}").main


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")

    def emit(name, value, derived=""):
        print(f"{name},{value:.4f},{derived}", flush=True)

    failures = 0
    for name, mod_name in SUITES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            _load(mod_name)(emit)
            print(f"# suite {name} done in {time.time()-t0:.1f}s",
                  flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"# suite {name} FAILED: {e}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
