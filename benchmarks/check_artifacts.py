"""Ledger sanity gate for the BENCH_*.json artifacts.

    PYTHONPATH=src python -m benchmarks.check_artifacts [paths...]

CI runs this after the bench sweeps so a refactor that silently drops a
ledger column (or flips an identity seal to False) fails the build instead
of shipping a hole in the perf trajectory.  With no arguments it checks
every known artifact present in the working directory; naming paths makes
missing files an error.

Checked invariants:

* BENCH_encode.json — every point carries the encode bytes-moved ledger
  (``records_stream_hbm_bytes`` > ``fused_stream_hbm_bytes``, ``saved`` is
  their difference) and the scatter-cost model
  (``scatter_selects_per_byte_{onehot,ring}``, pow2 ``ring_size``,
  consistent ``scatter_cost_reduction``); at least one point must show a
  measured reduction > 1 and all must seal ``backends_byte_identical``.
* BENCH_decode.json — at least one chunked point carries the decode mirror
  ledger (``hostgather_stream_hbm_bytes`` > ``zerocopy_stream_hbm_bytes``,
  ``stream_hbm_bytes_saved`` consistent) and seals
  ``container_zero_copy_identical``.
* BENCH_chunked.json — non-empty sweep with throughput fields on every
  point.
* BENCH_serve.json — the batched engine sustains >= 2x the serial
  one-request-at-a-time loop's streams/sec at equal-or-better p99
  latency, and every record seals ``byte_identical`` (engine blobs ==
  single-request path).
* BENCH_ratio.json — a dict of CR columns (not a point list): the rANS
  ladder must carry positive ratios including the bits-back latent column,
  and both byte-identity seals (chunked containers AND latent stack
  evolution across coder/kernel pop backends) must be True.  The
  ``_zoo_frontier`` list must span >= 3 distinct architecture families
  (dense ring / ssm recurrent / hybrid), every point with positive CR and
  encode/decode throughput and both per-point seals
  (``backends_byte_identical``, ``roundtrip_bit_exact``) True — the
  model-state protocol's whole-zoo guarantee, kept gated.
"""

from __future__ import annotations

import json
import os
import sys


def _fail(path: str, msg: str) -> None:
    raise SystemExit(f"{path}: {msg}")


def _points(path: str) -> list[dict]:
    with open(path) as f:
        pts = json.load(f)
    if not isinstance(pts, list) or not pts:
        _fail(path, "expected a non-empty list of point records")
    return pts


def check_encode(path: str) -> str:
    pts = _points(path)
    for p in pts:
        rec, fus = p["records_stream_hbm_bytes"], p["fused_stream_hbm_bytes"]
        if not (rec > fus and p["stream_hbm_bytes_saved"] == rec - fus):
            _fail(path, f"{p['name']}: encode bytes-moved ledger inconsistent")
        ring, cap = p["scatter_selects_per_byte_ring"], \
            p["scatter_selects_per_byte_onehot"]
        if ring != p["ring_size"] or ring & (ring - 1):
            _fail(path, f"{p['name']}: ring_size {ring} not a power of two")
        if abs(p["scatter_cost_reduction"] - cap / ring) > 1e-9:
            _fail(path, f"{p['name']}: scatter_cost_reduction != cap/ring")
        if p["backends_byte_identical"] is not True:
            _fail(path, f"{p['name']}: byte-identity seal missing")
    if not any(p["scatter_cost_reduction"] > 1 for p in pts):
        _fail(path, "no point shows a per-byte scatter-cost reduction > 1")
    return f"{len(pts)} points, scatter + bytes-moved ledgers consistent"


def check_decode(path: str) -> str:
    pts = _points(path)
    chunked = [p for p in pts
               if p.get("hostgather_stream_hbm_bytes") is not None]
    if not chunked:
        _fail(path, "no chunked point carries the decode stream ledger")
    for p in chunked:
        host, zero = p["hostgather_stream_hbm_bytes"], \
            p["zerocopy_stream_hbm_bytes"]
        if not (host > zero
                and p["stream_hbm_bytes_saved"] == host - zero):
            _fail(path, f"{p['name']}: decode bytes-moved ledger inconsistent")
        if p["container_zero_copy_identical"] is not True:
            _fail(path, f"{p['name']}: zero-copy identity seal missing")
    return (f"{len(chunked)}/{len(pts)} points carry the zero-copy ledger, "
            f"all sealed identical")


def check_chunked(path: str) -> str:
    pts = _points(path)
    for p in pts:
        if not (p["encode_Msym_s"] > 0 and p["decode_Msym_s"] > 0):
            _fail(path, f"{p['name']}: non-positive throughput")
    return f"{len(pts)} sweep points"


def check_serve(path: str) -> str:
    pts = _points(path)
    for p in pts:
        if not (p["serial_streams_per_s"] > 0
                and p["engine_streams_per_s"] > 0):
            _fail(path, f"{p['name']}: non-positive throughput")
        if not (p["serial_p50_s"] <= p["serial_p99_s"]
                and p["engine_p50_s"] <= p["engine_p99_s"]):
            _fail(path, f"{p['name']}: latency percentiles out of order")
        if p["speedup"] < 2.0:
            _fail(path, f"{p['name']}: engine speedup {p['speedup']:.2f}x "
                        "below the 2x continuous-batching bar")
        if p["engine_p99_s"] > p["serial_p99_s"]:
            _fail(path, f"{p['name']}: engine p99 worse than serial")
        if p["byte_identical"] is not True:
            _fail(path, f"{p['name']}: byte-identity seal missing")
    best = max(p["speedup"] for p in pts)
    return f"{len(pts)} points, engine {best:.2f}x serial, all sealed"


def check_ratio(path: str) -> str:
    # ratio artifact is a single dict of named CR columns, not a point list
    with open(path) as f:
        r = json.load(f)
    if not isinstance(r, dict) or not r:
        _fail(path, "expected a non-empty dict of CR columns")
    for col in ("rANS-static-histogram", "rANS-neural(ras-pimc)",
                "rANS-bitsback-latent(vae)"):
        if not (isinstance(r.get(col), float) and r[col] > 0):
            _fail(path, f"missing or non-positive CR column {col!r}")
    for seal in ("_backends_byte_identical",
                 "_latent_backends_byte_identical"):
        if r.get(seal) is not True:
            _fail(path, f"byte-identity seal {seal!r} missing or False")
    zoo = r.get("_zoo_frontier")
    if not isinstance(zoo, list) or len(zoo) < 3:
        _fail(path, "_zoo_frontier must carry >= 3 family points")
    for p in zoo:
        name = p.get("arch", "?")
        if not (isinstance(p.get("cr"), float) and p["cr"] > 0):
            _fail(path, f"zoo point {name}: missing or non-positive cr")
        if not (p.get("encode_sym_s", 0) > 0 and p.get("decode_sym_s", 0) > 0):
            _fail(path, f"zoo point {name}: non-positive throughput")
        if p.get("backends_byte_identical") is not True \
                or p.get("roundtrip_bit_exact") is not True:
            _fail(path, f"zoo point {name}: identity/round-trip seal "
                        "missing or False")
    fams = {p.get("family") for p in zoo}
    if len(fams) < 3:
        _fail(path, f"_zoo_frontier spans only families {sorted(fams)}: "
                    "need >= 3 distinct (the whole-zoo guarantee)")
    n = sum(1 for k in r if not k.startswith("_"))
    return (f"{n} CR columns + {len(zoo)}-family zoo frontier "
            f"({', '.join(sorted(fams))}), all seals True")


CHECKS = {
    "BENCH_encode.json": check_encode,
    "BENCH_decode.json": check_decode,
    "BENCH_chunked.json": check_chunked,
    "BENCH_serve.json": check_serve,
    "BENCH_ratio.json": check_ratio,
}


def main(argv: list[str]) -> None:
    paths = argv or [p for p in CHECKS if os.path.exists(p)]
    if not paths:
        _fail("check_artifacts", "no artifacts found and none named")
    for path in paths:
        check = CHECKS.get(path.rsplit("/", 1)[-1])
        if check is None:
            _fail(path, f"no checker registered (known: {sorted(CHECKS)})")
        print(f"{path}: OK — {check(path)}")


if __name__ == "__main__":
    main(sys.argv[1:])
