"""Fig. 4(c): compression ratio — classical codecs vs rANS-based neural
models (paper: neural rANS models beat JPEG2000/WebP/PNG/Zstd).

Offline container: no ImageNet/CIFAR and no PNG/WebP codecs, so the
distributional claim is reproduced on seeded synthetic images with the
available classical baselines (zlib = PNG's DEFLATE entropy stage, zstd)
against the RAS ladder: static-histogram rANS -> trained compact-NN
(ras-pimc) rANS.  CR = original bytes / compressed bytes (higher better).
"""

from __future__ import annotations

import zlib

import numpy as np
import jax
import jax.numpy as jnp
import zstandard

from repro.core import bitstream
from repro.data.pipeline import synthetic_image
from repro.serve.compress import histogram_compress, lm_compress


def _train_pimc(rows: np.ndarray, steps: int = 120):
    """Briefly train the paper's compact probability model on image rows."""
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.train.train_loop import init_train_state, make_train_step

    cfg = get_smoke_config("ras-pimc").with_(grad_accum=1)
    params = init_model(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(cfg, base_lr=3e-3))
    b, s = 8, 128
    flat = rows.reshape(-1)
    n = (len(flat) - 1) // (b * s) * (b * s)
    for i in range(steps):
        off = (i * b * s) % max(n - b * s, 1)
        tok = flat[off:off + b * s].reshape(b, s)
        lab = flat[off + 1:off + 1 + b * s].reshape(b, s)
        batch = {"tokens": jnp.asarray(tok, jnp.int32),
                 "labels": jnp.asarray(lab, jnp.int32)}
        state, m = step(state, batch)
    return cfg, state.params, float(m["loss"])


def run(h: int = 128, w: int = 256, seed: int = 0):
    img = synthetic_image(h, w, seed=seed)
    raw = img.tobytes()
    out = {}
    out["zlib(PNG-DEFLATE)"] = len(raw) / len(zlib.compress(raw, 9))
    out["zstd-19"] = len(raw) / len(
        zstandard.ZstdCompressor(level=19).compress(raw))

    lanes = 16
    rows = img.reshape(lanes, -1).astype(np.int64)
    enc, _ = histogram_compress(rows, 256)
    out["rANS-static-histogram"] = len(raw) / bitstream.compressed_size(
        np.asarray(enc.length))

    cfg, params, loss = _train_pimc(rows)
    stats = lm_compress(params, cfg, jnp.asarray(rows, jnp.int32))
    out["rANS-neural(ras-pimc)"] = len(raw) / bitstream.compressed_size(
        np.asarray(stats.enc.length))
    out["_pimc_train_loss_bits"] = loss / np.log(2)
    return out


def main(emit):
    r = run()
    for name, cr in r.items():
        if name.startswith("_"):
            continue
        emit(f"fig4c_CR_{name}", cr, "higher is better")
    emit("fig4c_pimc_model_entropy_bits", r["_pimc_train_loss_bits"],
         "bits/symbol after brief training")
