"""Fig. 4(c): compression ratio — classical codecs vs rANS-based neural
models (paper: neural rANS models beat JPEG2000/WebP/PNG/Zstd).

    PYTHONPATH=src python -m benchmarks.bench_ratio [--out BENCH_ratio.json]

Offline container: no ImageNet/CIFAR and no PNG/WebP codecs, so the
distributional claim is reproduced on seeded synthetic images with the
available classical baselines (zlib = PNG's DEFLATE entropy stage, plus
zstd when the optional ``zstandard`` package is installed) against the RAS
ladder: static-histogram rANS -> trained compact-NN (ras-pimc) rANS.  The
neural rung ships through the production path — kernel-backed chunked
encode packed into the v2 streaming container — and the bench asserts the
kernel and pure-coder backends produce *byte-identical* containers before
reporting a ratio.  CR = original bytes / compressed bytes (higher better).

``_zoo_frontier`` extends the neural rung across architecture families
(dense KV-ring / Mamba2 recurrent / RecurrentGemma hybrid — the
model-state protocol makes the serve stack generator-agnostic): one
ratio-vs-throughput point per family through the identical chunked
container + fused-kernel-decode path, each sealed byte-identical across
backends and bit-exact on the round trip.  Gated in CI by
``benchmarks.check_artifacts``.
"""

from __future__ import annotations

import argparse
import json
import zlib

import numpy as np
import jax
import jax.numpy as jnp

try:  # optional classical baseline — not shipped in every container image
    import zstandard
except ImportError:  # pragma: no cover
    zstandard = None

from repro.core import bitstream
from repro.data.pipeline import synthetic_image
from repro.serve.compress import (histogram_compress, lm_compress_chunked,
                                  lm_decompress_chunked)


def _train_arch(arch: str, rows: np.ndarray, steps: int = 120):
    """Briefly train a registry arch's smoke config on image rows.

    Any ``ARCH_IDS`` entry works — the model-state protocol makes the
    serve stack generator-agnostic, so the bench trains and ships each
    family through the identical datapath (image bytes fit every smoke
    vocab: all are >= 256).
    """
    from repro.configs import get_smoke_config
    from repro.models import init_model
    from repro.train.train_loop import init_train_state, make_train_step

    cfg = get_smoke_config(arch).with_(grad_accum=1)
    params = init_model(cfg, jax.random.PRNGKey(0))
    state = init_train_state(params)
    step = jax.jit(make_train_step(cfg, base_lr=3e-3))
    b, s = 8, 128
    flat = rows.reshape(-1)
    n = (len(flat) - 1) // (b * s) * (b * s)
    for i in range(steps):
        off = (i * b * s) % max(n - b * s, 1)
        tok = flat[off:off + b * s].reshape(b, s)
        lab = flat[off + 1:off + 1 + b * s].reshape(b, s)
        batch = {"tokens": jnp.asarray(tok, jnp.int32),
                 "labels": jnp.asarray(lab, jnp.int32)}
        state, m = step(state, batch)
    return cfg, state.params, float(m["loss"])


def _latent_rung(img: np.ndarray, steps: int = 300):
    """Bits-back VAE rung: net stack-byte cost of coding the image's 8x8
    patches through the Bit-Swap schedule (models/vae.py over core/stack.py).

    Returns ``(net_bytes, backends_identical, elbo_nats)``; the identity
    seal asserts the coder and Pallas-kernel pop backends evolved the stack
    byte-identically, and the decode side is asserted bit-exact (pixels AND
    restored initial stack — the bits-back identity) before any CR ships.
    """
    from repro.core import stack
    from repro.models import vae

    cfg = vae.VAEConfig()
    h, w = img.shape

    def patch(im):
        return im.reshape(h // 8, 8, w // 8, 8).swapaxes(1, 2).reshape(-1, 64)

    params, loss = vae.train_vae(
        cfg,
        lambda i: patch(synthetic_image(h, w, seed=100 + i)).astype(np.int64),
        steps=steps, lr=1e-2, seed=0)
    x = jnp.asarray(patch(img), jnp.int32)
    lanes = x.shape[0]
    st0 = stack.stack_init_bits(lanes, 1024, n_bytes=32, seed=7)
    st = vae.bb_encode(st0, params, x, cfg)
    st_k = vae.bb_encode(st0, params, x, cfg, backend="kernel")
    identical = bool(
        np.array_equal(np.asarray(st_k.buf), np.asarray(st.buf))
        and np.array_equal(np.asarray(st_k.s), np.asarray(st.s)))
    st_d, x_d = vae.bb_decode(st, params, cfg)
    assert np.array_equal(np.asarray(x_d), np.asarray(x))
    assert np.array_equal(np.asarray(st_d.s), np.asarray(st0.s))
    assert not np.asarray(st_d.underflow).any()
    net = int((np.asarray(stack.stack_bytes(st))
               - np.asarray(stack.stack_bytes(st0))).sum())
    return net, identical, loss


def _pack_v2(stats) -> bytes:
    """ChunkedCompressStats -> v2 container bytes (the shipped artifact)."""
    ch = stats.chunks
    return bitstream.pack_chunked(
        np.asarray(ch.buf), np.asarray(ch.start), np.asarray(ch.length),
        None if ch.overflow is None else np.asarray(ch.overflow),
        chunk_size=stats.chunk_size, n_symbols=stats.n_symbols)


def run(h: int = 128, w: int = 256, seed: int = 0, chunk_size: int = 512):
    img = synthetic_image(h, w, seed=seed)
    raw = img.tobytes()
    out = {}
    out["zlib(PNG-DEFLATE)"] = len(raw) / len(zlib.compress(raw, 9))
    if zstandard is not None:
        out["zstd-19"] = len(raw) / len(
            zstandard.ZstdCompressor(level=19).compress(raw))

    lanes = 16
    rows = img.reshape(lanes, -1).astype(np.int64)
    enc, _ = histogram_compress(rows, 256)
    out["rANS-static-histogram"] = len(raw) / bitstream.compressed_size(
        np.asarray(enc.length))

    cfg, params, loss = _train_arch("ras-pimc", rows)
    toks = jnp.asarray(rows, jnp.int32)
    stats = lm_compress_chunked(params, cfg, toks, chunk_size,
                                backend="kernel")
    blob = _pack_v2(stats)
    # differential gate: the Pallas encode kernel and the pure-JAX lane
    # coder must ship byte-identical v2 containers before a CR is reported
    ref_blob = _pack_v2(lm_compress_chunked(params, cfg, toks, chunk_size,
                                            backend="coder"))
    assert blob == ref_blob, "kernel/coder v2 containers diverge byte-wise"
    out["rANS-neural(ras-pimc)"] = len(raw) / len(blob)
    out["_pimc_train_loss_bits"] = loss / np.log(2)
    out["_backends_byte_identical"] = True

    net, lat_identical, lat_loss = _latent_rung(img)
    out["rANS-bitsback-latent(vae)"] = len(raw) / net
    out["_vae_elbo_bits_per_pixel"] = lat_loss / np.log(2) / 64
    out["_latent_backends_byte_identical"] = lat_identical

    out["_zoo_frontier"] = _zoo_frontier(
        img, pimc=(cfg, params, float(loss)))
    return out


def _zoo_frontier(img: np.ndarray, pimc) -> list[dict]:
    """Ratio-vs-throughput frontier across architecture families.

    One point per ``configs.SERVE_SMOKE_ARCHS`` entry — dense attention
    (ras-pimc, pure KV ring), Mamba2 (pure recurrent ``(h, conv)``), and
    RecurrentGemma (ring + recurrent hybrid) — every family through the
    IDENTICAL production path: briefly trained smoke model, kernel-backed
    chunked encode into the v2 container, and the FUSED kernel decode
    (`lm_decompress_chunked(backend="kernel")`) carrying the state pytree
    across chunk boundaries.  Each point seals (a) kernel/coder container
    byte-identity and (b) decode round-trip bit-exactness before any
    number ships; throughput is compiled-wall-clock symbols/sec over the
    post-warmup run (interpret-mode Pallas on CPU — relative frontier
    shape, not absolute hardware numbers).
    """
    import time

    from repro.configs import SERVE_SMOKE_ARCHS, get_smoke_config
    from repro.models import state_spec

    lanes, t_len, csize = 16, 256, 128
    rows = img.reshape(lanes, -1)[:, :t_len].astype(np.int64)
    toks = jnp.asarray(rows, jnp.int32)
    raw_bytes = rows.size  # one byte per symbol
    points = []
    for arch in SERVE_SMOKE_ARCHS:
        if arch == "ras-pimc":
            cfg, params, loss = pimc
        else:
            cfg, params, loss = _train_arch(arch, rows, steps=60)
        spec = state_spec(cfg)

        def compress():
            return lm_compress_chunked(params, cfg, toks, csize,
                                       backend="kernel")

        stats = compress()                              # compile + warm
        jax.block_until_ready(stats.chunks.buf)
        t0 = time.perf_counter()
        stats = compress()
        jax.block_until_ready(stats.chunks.buf)
        t_enc = time.perf_counter() - t0
        blob = _pack_v2(stats)
        ref = _pack_v2(lm_compress_chunked(params, cfg, toks, csize,
                                           backend="coder"))
        identical = blob == ref
        slab = bitstream.parse_chunked(blob)

        def decompress():
            return lm_decompress_chunked(params, cfg, slab, t_len, csize,
                                         backend="kernel")

        dec, _ = decompress()                           # compile + warm
        jax.block_until_ready(dec)
        t0 = time.perf_counter()
        dec, _ = decompress()
        jax.block_until_ready(dec)
        t_dec = time.perf_counter() - t0
        exact = bool(np.array_equal(np.asarray(dec), rows))
        assert identical, f"{arch}: kernel/coder containers diverge"
        assert exact, f"{arch}: fused kernel round-trip not bit-exact"
        points.append({
            "arch": arch,
            "family": cfg.family,
            "state": ("ring+recurrent" if spec.ring and spec.recurrent
                      else "recurrent" if spec.recurrent else "ring"),
            "cr": raw_bytes / len(blob),
            "bits_per_symbol": float(stats.bits_per_symbol),
            "model_entropy_bits": loss / float(np.log(2)),
            "encode_sym_s": rows.size / t_enc,
            "decode_sym_s": rows.size / t_dec,
            "backends_byte_identical": identical,
            "roundtrip_bit_exact": exact,
        })
    return points


def main(emit):
    r = run()
    for name, cr in r.items():
        if name.startswith("_"):
            continue
        emit(f"fig4c_CR_{name}", cr, "higher is better")
    emit("fig4c_backends_byte_identical",
         float(r["_backends_byte_identical"]),
         "1.0 = kernel and coder v2 containers byte-identical")
    emit("fig4c_pimc_model_entropy_bits", r["_pimc_train_loss_bits"],
         "bits/symbol after brief training")
    for p in r["_zoo_frontier"]:
        emit(f"zoo_CR_{p['arch']}", p["cr"],
             f"{p['family']}/{p['state']} — higher is better")
        emit(f"zoo_decode_sym_s_{p['arch']}", p["decode_sym_s"],
             "fused kernel decode, interpret mode")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_ratio.json")
    args = ap.parse_args()
    r = run()
    with open(args.out, "w") as f:
        json.dump(r, f, indent=2)
    for name, v in r.items():
        if not name.startswith("_"):
            print(f"{name}: CR={v:.3f}")
    print(f"backends byte-identical: {r['_backends_byte_identical']}")
    for p in r["_zoo_frontier"]:
        print(f"zoo {p['arch']} ({p['family']}/{p['state']}): "
              f"CR={p['cr']:.3f} enc={p['encode_sym_s']:.0f} sym/s "
              f"dec={p['decode_sym_s']:.0f} sym/s "
              f"sealed={p['backends_byte_identical'] and p['roundtrip_bit_exact']}")
    print(f"wrote -> {args.out}")
