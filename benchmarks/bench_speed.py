"""Fig. 4(a): coder speed — multi-lane RAS coder vs the Python rANS baseline.

Protocol mirrors the paper: same symbolization, same CDFs (so bitstreams are
identical), coder kernels only (no probability generation, no host I/O),
cycle-normalized with a nominal clock (the paper used 2.9 GHz for its M4
baseline; we time both sides on *this* host so the ratio is self-normalizing).

Encode-backend sweep (``--out BENCH_encode.json``): coder vs Pallas kernel
x static / per-position / per-lane / chunked table layouts — and, on the
kernel side, **fused in-kernel compaction vs the records reference path**
(DESIGN.md §8).  Every point asserts all backends' streams are
byte-identical before timing, so the JSON doubles as a cross-backend
differential record, and reports the analytic encode-side HBM stream
traffic of both kernel datapaths (``fused_stream_hbm_bytes`` /
``records_stream_hbm_bytes``): the records path ships fixed-shape
``(T, 2, lanes)`` byte+mask planes to HBM and reads them back for
host-side compaction (~4x the record planes plus the packed buffer), the
fused path writes each packed ``(cap, lanes)`` stream exactly once.  NOTE:
the kernel runs in interpret mode on CPU — its wall-clock here measures
the *interpreter*, not TPU hardware; the point of the sweep is the
bit-exactness seal, the bytes-moved ledger, and a tracked shape/latency
baseline to diff against real-TPU runs (``tests/test_tpu_hw.py``).

    PYTHONPATH=src python -m benchmarks.bench_speed [--out BENCH_encode.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, constants as C, python_baseline, spc
from repro.data.pipeline import image_rows

NOMINAL_HZ = 2.9e9


def run(lanes: int = 128, t: int = 2048, py_symbols: int = 40_000,
        seed: int = 0):
    rows = image_rows(lanes, t, seed=seed)
    counts = np.bincount(rows.ravel(), minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    f, cdf = np.asarray(tbl.freq), np.asarray(tbl.cdf)
    syms = jnp.asarray(rows, jnp.int32)

    # --- Python baseline (single lane, the paper's software reference)
    pr = python_baseline.PyRans(f, cdf)
    py_syms = [int(x) for x in rows.ravel()[:py_symbols]]
    t0 = time.perf_counter()
    blob = pr.encode(py_syms)
    py_enc = (time.perf_counter() - t0) / len(py_syms)
    t0 = time.perf_counter()
    out = pr.decode(blob, len(py_syms))
    py_dec = (time.perf_counter() - t0) / len(py_syms)
    assert out == py_syms

    # --- multi-lane JAX coder (jitted; steady-state timing after warmup)
    enc_fn = jax.jit(lambda s: coder.encode(s, tbl))
    enc = enc_fn(syms)
    jax.block_until_ready(enc.buf)
    t0 = time.perf_counter()
    enc = enc_fn(syms)
    jax.block_until_ready(enc.buf)
    jx_enc = (time.perf_counter() - t0) / (lanes * t)

    def timed(fn, arg):
        out = fn(arg)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        t0 = time.perf_counter()
        out = fn(arg)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        return (time.perf_counter() - t0) / (lanes * t), out

    # paper-faithful decode (binary search over the CDF)
    jx_dec, (dec, _) = timed(jax.jit(lambda e: coder.decode(e, t, tbl)), enc)
    assert np.array_equal(np.asarray(dec), rows)
    # beyond-paper: O(1) slot->symbol LUT (static tables; §Perf H3)
    jx_lut, (dec2, _) = timed(
        jax.jit(lambda e: coder.decode(e, t, tbl, use_lut=True)), enc)
    assert np.array_equal(np.asarray(dec2), rows)

    return {
        "py_enc_us": py_enc * 1e6, "py_dec_us": py_dec * 1e6,
        "jax_enc_us": jx_enc * 1e6, "jax_dec_us": jx_dec * 1e6,
        "jax_lut_us": jx_lut * 1e6,
        "speedup_enc": py_enc / jx_enc,
        "speedup_dec": py_dec / jx_dec,
        "speedup_dec_lut": py_dec / jx_lut,
        "py_enc_cycles": py_enc * NOMINAL_HZ,
        "jax_enc_cycles": jx_enc * NOMINAL_HZ,
        "lanes": lanes, "symbols_per_lane": t,
    }


def _timed_encode(fn, syms):
    out = fn(syms)
    jax.block_until_ready(out.buf)
    t0 = time.perf_counter()
    out = fn(syms)
    jax.block_until_ready(out.buf)
    return (time.perf_counter() - t0) / syms.size, out


def _encode_stream_hbm_bytes(lanes: int, t: int, chunk: int | None,
                             cap: int) -> dict:
    """Analytic encode-side HBM stream traffic of the two kernel datapaths.

    Records path: the kernel writes ``(rows, 2, lanes)`` byte + mask planes
    to HBM and ``compact_records`` reads both back before writing the
    packed buffer — every encoded byte crosses HBM ~2x plus the mask
    overhead.  Fused path: the packed ``(n_chunks, lanes, cap)`` buffer is
    written once (plus three small per-lane geometry planes).  Symbol and
    table input traffic is identical on both paths and excluded.
    """
    chunk = t if chunk is None else min(chunk, t)
    n_chunks = -(-t // chunk)
    rows = n_chunks * chunk          # t_block=None: no padding rows
    rec_planes = rows * C.MAX_RENORM_STEPS * lanes * 2   # bytes + mask, u8
    packed = n_chunks * lanes * cap
    return {
        "records_stream_hbm_bytes": 2 * rec_planes + packed,
        "fused_stream_hbm_bytes": packed + 3 * n_chunks * lanes * 4,
    }


def run_encode_backends(seed: int = 0) -> list[dict]:
    """coder vs kernel-fused vs kernel-records x static/adaptive/chunked.

    Shapes are deliberately modest: the kernel side runs the Pallas
    *interpreter* on CPU (see module docstring).  Each point asserts
    byte-identity between all backends before reporting wall-clock and the
    bytes-moved ledger.

    The fused kernel is timed on BOTH scatter datapaths (DESIGN.md §10):
    the banked byte-ring (production default — per-byte scatter cost
    O(ring) with the autotuned ``t_block``) and the one-hot row scatter it
    replaced (per-byte cost O(cap)).  Each point reports the measured
    wall-clocks plus the analytic selects-per-byte of both
    (``scatter_selects_per_byte_{ring,onehot}`` and their ratio
    ``scatter_cost_reduction`` = cap / ring).
    """
    from repro.core import bitstream
    from repro.kernels import ops
    from repro.kernels.autotune import ring_size, select_encode_t_block
    from repro.kernels.rans_encode import rans_encode_records
    rng = np.random.default_rng(seed)

    def static_case(k, lanes, t):
        tbl = spc.tables_from_probs(
            jnp.asarray(rng.dirichlet(np.ones(k) * 0.5), jnp.float32))
        return tbl, jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)

    def perpos_case(k, lanes, t):
        probs = rng.dirichlet(np.ones(k) * 0.5, size=t).astype(np.float32)
        tbl = spc.tables_from_probs(jnp.asarray(probs))
        return tbl, jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)

    def perlane_case(k, lanes, t):
        probs = rng.dirichlet(np.ones(k) * 0.5,
                              size=(t, lanes)).astype(np.float32)
        tbl = spc.tables_from_probs(jnp.asarray(probs))
        return tbl, jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)

    cases = [
        ("static", static_case(256, 128, 512), None),
        ("perpos_TK", perpos_case(64, 16, 256), None),
        ("perlane_TLK", perlane_case(32, 8, 128), None),
        ("chunked_static", static_case(256, 128, 512), 128),
        ("chunked_perpos", perpos_case(64, 16, 256), 64),
    ]
    points = []
    for name, (tbl, syms), chunk in cases:
        lanes, t = map(int, syms.shape)
        cap = coder.default_cap(t if chunk is None else min(chunk, t))
        if chunk is None:
            coder_fn = jax.jit(lambda s, tb=tbl: coder.encode(s, tb))
            kern_fn = lambda s, tb=tbl: ops.rans_encode(s, tb)  # noqa: E731
            onehot_fn = (lambda s, tb=tbl:
                         ops.rans_encode(s, tb, scatter="onehot"))

            def rec_fn(s, tb=tbl, cp=cap):
                b, m, st = rans_encode_records(s, tb)
                return bitstream.compact_records(b[0], m[0], st[0], cp)
        else:
            coder_fn = (lambda s, tb=tbl, c=chunk:
                        coder.encode_chunked(s, tb, c))
            kern_fn = (lambda s, tb=tbl, c=chunk:
                       ops.rans_encode_chunked(s, tb, c))
            onehot_fn = (lambda s, tb=tbl, c=chunk:
                         ops.rans_encode_chunked(s, tb, c, scatter="onehot"))

            def rec_fn(s, tb=tbl, c=chunk, cp=cap):
                b, m, st = rans_encode_records(s, tb, chunk_size=c)
                return jax.vmap(
                    lambda bb, mm, ss:
                    bitstream.compact_records(bb, mm, ss, cp))(b, m, st)
        c_us, c_out = _timed_encode(coder_fn, syms)
        k_us, k_out = _timed_encode(kern_fn, syms)
        o_us, o_out = _timed_encode(onehot_fn, syms)
        r_us, r_out = _timed_encode(rec_fn, syms)
        for a, b in zip(c_out, k_out):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                f"{name}: fused kernel streams diverge from the coder")
        for a, b in zip(o_out, k_out):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                f"{name}: one-hot scatter streams diverge from the ring")
        for a, b in zip(r_out, k_out):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                f"{name}: records-path streams diverge from the fused path")
        moved = _encode_stream_hbm_bytes(lanes, t, chunk, cap)
        # the autotuned blocking the default ring path actually ran with
        layout = {1: "static", 2: "perpos", 3: "lane"}[tbl.freq.ndim]
        eff_chunk = t if chunk is None else min(chunk, t)
        ring_tb = select_encode_t_block(eff_chunk, cap, min(lanes, 128),
                                        int(tbl.freq.shape[-1]), layout)
        ring = ring_size(ring_tb)
        points.append({
            "name": name, "lanes": lanes,
            "n_symbols": t,
            "chunk_size": chunk,
            "cap": cap,
            "coder_us_per_symbol": c_us * 1e6,
            # the fused (production) kernel datapath — field name kept from
            # the PR 3 sweep so dashboards diff across PRs; since the
            # banked-ring PR this is the ring-scatter path
            "kernel_interpret_us_per_symbol": k_us * 1e6,
            "kernel_onehot_us_per_symbol": o_us * 1e6,
            "kernel_records_us_per_symbol": r_us * 1e6,
            "ring_t_block": ring_tb,
            "ring_size": ring,
            "scatter_selects_per_byte_ring": ring,
            "scatter_selects_per_byte_onehot": cap,
            "scatter_cost_reduction": cap / ring,
            "ring_vs_onehot_speedup": o_us / k_us,
            **moved,
            "stream_hbm_bytes_saved": (moved["records_stream_hbm_bytes"]
                                       - moved["fused_stream_hbm_bytes"]),
            "backends_byte_identical": True,
        })
    return points


def main(emit):
    r = run()
    emit("fig4a_encode_python_baseline", r["py_enc_us"],
         f"cycles/sym={r['py_enc_cycles']:.0f}")
    emit("fig4a_encode_ras_multilane", r["jax_enc_us"],
         f"speedup={r['speedup_enc']:.1f}x (paper: 121.2x)")
    emit("fig4a_decode_python_baseline", r["py_dec_us"], "")
    emit("fig4a_decode_ras_multilane", r["jax_dec_us"],
         f"speedup={r['speedup_dec']:.1f}x (paper: 70.9x)")
    emit("fig4a_decode_ras_lut_beyond_paper", r["jax_lut_us"],
         f"speedup={r['speedup_dec_lut']:.1f}x (static-table O(1) LUT)")
    for p in run_encode_backends():
        emit(f"encode_backend_{p['name']}_coder",
             p["coder_us_per_symbol"],
             "us/symbol, pure-JAX lane coder")
        emit(f"encode_backend_{p['name']}_kernel",
             p["kernel_interpret_us_per_symbol"],
             "us/symbol, fused Pallas kernel (INTERPRET; byte-identical)")
        emit(f"encode_backend_{p['name']}_kernel_records",
             p["kernel_records_us_per_symbol"],
             "us/symbol, records kernel + host compact_records (reference)")
        emit(f"encode_backend_{p['name']}_ring_speedup",
             p["ring_vs_onehot_speedup"],
             f"banked-ring vs one-hot scatter (selects/byte "
             f"{p['scatter_selects_per_byte_onehot']} -> "
             f"{p['scatter_selects_per_byte_ring']}, "
             f"t_block={p['ring_t_block']})")
        emit(f"encode_backend_{p['name']}_hbm_saved",
             p["stream_hbm_bytes_saved"],
             f"stream HBM bytes saved by fused compaction "
             f"({p['records_stream_hbm_bytes']} -> "
             f"{p['fused_stream_hbm_bytes']})")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_encode.json")
    args = ap.parse_args()
    pts = run_encode_backends()
    with open(args.out, "w") as f:
        json.dump(pts, f, indent=2)
    for p in pts:
        print(f"{p['name']}: coder {p['coder_us_per_symbol']:.3f} us/sym, "
              f"kernel-ring {p['kernel_interpret_us_per_symbol']:.3f} "
              f"us/sym (tb={p['ring_t_block']}, "
              f"{p['ring_vs_onehot_speedup']:.2f}x vs one-hot "
              f"{p['kernel_onehot_us_per_symbol']:.3f}), kernel-records "
              f"{p['kernel_records_us_per_symbol']:.3f} us/sym, "
              f"selects/byte {p['scatter_selects_per_byte_onehot']} -> "
              f"{p['scatter_selects_per_byte_ring']}, "
              f"stream HBM {p['records_stream_hbm_bytes']} -> "
              f"{p['fused_stream_hbm_bytes']} B "
              f"({p['stream_hbm_bytes_saved']} saved), "
              f"byte-identical={p['backends_byte_identical']}")
    print(f"wrote {len(pts)} points -> {args.out}")
