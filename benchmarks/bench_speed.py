"""Fig. 4(a): coder speed — multi-lane RAS coder vs the Python rANS baseline.

Protocol mirrors the paper: same symbolization, same CDFs (so bitstreams are
identical), coder kernels only (no probability generation, no host I/O),
cycle-normalized with a nominal clock (the paper used 2.9 GHz for its M4
baseline; we time both sides on *this* host so the ratio is self-normalizing).
"""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, python_baseline, spc
from repro.data.pipeline import image_rows

NOMINAL_HZ = 2.9e9


def run(lanes: int = 128, t: int = 2048, py_symbols: int = 40_000,
        seed: int = 0):
    rows = image_rows(lanes, t, seed=seed)
    counts = np.bincount(rows.ravel(), minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    f, cdf = np.asarray(tbl.freq), np.asarray(tbl.cdf)
    syms = jnp.asarray(rows, jnp.int32)

    # --- Python baseline (single lane, the paper's software reference)
    pr = python_baseline.PyRans(f, cdf)
    py_syms = [int(x) for x in rows.ravel()[:py_symbols]]
    t0 = time.perf_counter()
    blob = pr.encode(py_syms)
    py_enc = (time.perf_counter() - t0) / len(py_syms)
    t0 = time.perf_counter()
    out = pr.decode(blob, len(py_syms))
    py_dec = (time.perf_counter() - t0) / len(py_syms)
    assert out == py_syms

    # --- multi-lane JAX coder (jitted; steady-state timing after warmup)
    enc_fn = jax.jit(lambda s: coder.encode(s, tbl))
    enc = enc_fn(syms)
    jax.block_until_ready(enc.buf)
    t0 = time.perf_counter()
    enc = enc_fn(syms)
    jax.block_until_ready(enc.buf)
    jx_enc = (time.perf_counter() - t0) / (lanes * t)

    def timed(fn, arg):
        out = fn(arg)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        t0 = time.perf_counter()
        out = fn(arg)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        return (time.perf_counter() - t0) / (lanes * t), out

    # paper-faithful decode (binary search over the CDF)
    jx_dec, (dec, _) = timed(jax.jit(lambda e: coder.decode(e, t, tbl)), enc)
    assert np.array_equal(np.asarray(dec), rows)
    # beyond-paper: O(1) slot->symbol LUT (static tables; §Perf H3)
    jx_lut, (dec2, _) = timed(
        jax.jit(lambda e: coder.decode(e, t, tbl, use_lut=True)), enc)
    assert np.array_equal(np.asarray(dec2), rows)

    return {
        "py_enc_us": py_enc * 1e6, "py_dec_us": py_dec * 1e6,
        "jax_enc_us": jx_enc * 1e6, "jax_dec_us": jx_dec * 1e6,
        "jax_lut_us": jx_lut * 1e6,
        "speedup_enc": py_enc / jx_enc,
        "speedup_dec": py_dec / jx_dec,
        "speedup_dec_lut": py_dec / jx_lut,
        "py_enc_cycles": py_enc * NOMINAL_HZ,
        "jax_enc_cycles": jx_enc * NOMINAL_HZ,
        "lanes": lanes, "symbols_per_lane": t,
    }


def main(emit):
    r = run()
    emit("fig4a_encode_python_baseline", r["py_enc_us"],
         f"cycles/sym={r['py_enc_cycles']:.0f}")
    emit("fig4a_encode_ras_multilane", r["jax_enc_us"],
         f"speedup={r['speedup_enc']:.1f}x (paper: 121.2x)")
    emit("fig4a_decode_python_baseline", r["py_dec_us"], "")
    emit("fig4a_decode_ras_multilane", r["jax_dec_us"],
         f"speedup={r['speedup_dec']:.1f}x (paper: 70.9x)")
    emit("fig4a_decode_ras_lut_beyond_paper", r["jax_lut_us"],
         f"speedup={r['speedup_dec_lut']:.1f}x (static-table O(1) LUT)")
