"""Fig. 4(a): coder speed — multi-lane RAS coder vs the Python rANS baseline.

Protocol mirrors the paper: same symbolization, same CDFs (so bitstreams are
identical), coder kernels only (no probability generation, no host I/O),
cycle-normalized with a nominal clock (the paper used 2.9 GHz for its M4
baseline; we time both sides on *this* host so the ratio is self-normalizing).

Encode-backend sweep (``--out BENCH_encode.json``): coder vs Pallas kernel
x static / per-position / per-lane / chunked table layouts.  Every point
asserts the two backends' streams are byte-identical before timing, so the
JSON doubles as a cross-backend differential record.  NOTE: the kernel runs
in interpret mode on CPU — its wall-clock here measures the *interpreter*,
not TPU hardware; the point of the sweep is the bit-exactness seal plus a
tracked shape/latency baseline to diff against real-TPU runs
(``tests/test_tpu_hw.py``).

    PYTHONPATH=src python -m benchmarks.bench_speed [--out BENCH_encode.json]
"""

from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import coder, python_baseline, spc
from repro.data.pipeline import image_rows

NOMINAL_HZ = 2.9e9


def run(lanes: int = 128, t: int = 2048, py_symbols: int = 40_000,
        seed: int = 0):
    rows = image_rows(lanes, t, seed=seed)
    counts = np.bincount(rows.ravel(), minlength=256)
    tbl = jax.tree.map(jnp.asarray, spc.tables_from_counts_np(counts))
    f, cdf = np.asarray(tbl.freq), np.asarray(tbl.cdf)
    syms = jnp.asarray(rows, jnp.int32)

    # --- Python baseline (single lane, the paper's software reference)
    pr = python_baseline.PyRans(f, cdf)
    py_syms = [int(x) for x in rows.ravel()[:py_symbols]]
    t0 = time.perf_counter()
    blob = pr.encode(py_syms)
    py_enc = (time.perf_counter() - t0) / len(py_syms)
    t0 = time.perf_counter()
    out = pr.decode(blob, len(py_syms))
    py_dec = (time.perf_counter() - t0) / len(py_syms)
    assert out == py_syms

    # --- multi-lane JAX coder (jitted; steady-state timing after warmup)
    enc_fn = jax.jit(lambda s: coder.encode(s, tbl))
    enc = enc_fn(syms)
    jax.block_until_ready(enc.buf)
    t0 = time.perf_counter()
    enc = enc_fn(syms)
    jax.block_until_ready(enc.buf)
    jx_enc = (time.perf_counter() - t0) / (lanes * t)

    def timed(fn, arg):
        out = fn(arg)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        t0 = time.perf_counter()
        out = fn(arg)
        jax.block_until_ready(jax.tree.leaves(out)[0])
        return (time.perf_counter() - t0) / (lanes * t), out

    # paper-faithful decode (binary search over the CDF)
    jx_dec, (dec, _) = timed(jax.jit(lambda e: coder.decode(e, t, tbl)), enc)
    assert np.array_equal(np.asarray(dec), rows)
    # beyond-paper: O(1) slot->symbol LUT (static tables; §Perf H3)
    jx_lut, (dec2, _) = timed(
        jax.jit(lambda e: coder.decode(e, t, tbl, use_lut=True)), enc)
    assert np.array_equal(np.asarray(dec2), rows)

    return {
        "py_enc_us": py_enc * 1e6, "py_dec_us": py_dec * 1e6,
        "jax_enc_us": jx_enc * 1e6, "jax_dec_us": jx_dec * 1e6,
        "jax_lut_us": jx_lut * 1e6,
        "speedup_enc": py_enc / jx_enc,
        "speedup_dec": py_dec / jx_dec,
        "speedup_dec_lut": py_dec / jx_lut,
        "py_enc_cycles": py_enc * NOMINAL_HZ,
        "jax_enc_cycles": jx_enc * NOMINAL_HZ,
        "lanes": lanes, "symbols_per_lane": t,
    }


def _timed_encode(fn, syms):
    out = fn(syms)
    jax.block_until_ready(out.buf)
    t0 = time.perf_counter()
    out = fn(syms)
    jax.block_until_ready(out.buf)
    return (time.perf_counter() - t0) / syms.size, out


def run_encode_backends(seed: int = 0) -> list[dict]:
    """coder vs kernel x static/per-position/per-lane/chunked encode.

    Shapes are deliberately modest: the kernel side runs the Pallas
    *interpreter* on CPU (see module docstring).  Each point asserts
    byte-identity between backends before reporting wall-clock.
    """
    from repro.kernels import ops
    rng = np.random.default_rng(seed)

    def static_case(k, lanes, t):
        tbl = spc.tables_from_probs(
            jnp.asarray(rng.dirichlet(np.ones(k) * 0.5), jnp.float32))
        return tbl, jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)

    def perpos_case(k, lanes, t):
        probs = rng.dirichlet(np.ones(k) * 0.5, size=t).astype(np.float32)
        tbl = spc.tables_from_probs(jnp.asarray(probs))
        return tbl, jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)

    def perlane_case(k, lanes, t):
        probs = rng.dirichlet(np.ones(k) * 0.5,
                              size=(t, lanes)).astype(np.float32)
        tbl = spc.tables_from_probs(jnp.asarray(probs))
        return tbl, jnp.asarray(rng.integers(0, k, (lanes, t)), jnp.int32)

    cases = [
        ("static", static_case(256, 128, 512), None),
        ("perpos_TK", perpos_case(64, 16, 256), None),
        ("perlane_TLK", perlane_case(32, 8, 128), None),
        ("chunked_static", static_case(256, 128, 512), 128),
        ("chunked_perpos", perpos_case(64, 16, 256), 64),
    ]
    points = []
    for name, (tbl, syms), chunk in cases:
        if chunk is None:
            coder_fn = jax.jit(lambda s, tb=tbl: coder.encode(s, tb))
            kern_fn = lambda s, tb=tbl: ops.rans_encode(s, tb)  # noqa: E731
        else:
            coder_fn = (lambda s, tb=tbl, c=chunk:
                        coder.encode_chunked(s, tb, c))
            kern_fn = (lambda s, tb=tbl, c=chunk:
                       ops.rans_encode_chunked(s, tb, c))
        c_us, c_out = _timed_encode(coder_fn, syms)
        k_us, k_out = _timed_encode(kern_fn, syms)
        for a, b in zip(c_out, k_out):
            assert np.array_equal(np.asarray(a), np.asarray(b)), (
                f"{name}: backend streams diverge")
        points.append({
            "name": name, "lanes": int(syms.shape[0]),
            "n_symbols": int(syms.shape[1]),
            "chunk_size": chunk,
            "coder_us_per_symbol": c_us * 1e6,
            "kernel_interpret_us_per_symbol": k_us * 1e6,
            "backends_byte_identical": True,
        })
    return points


def main(emit):
    r = run()
    emit("fig4a_encode_python_baseline", r["py_enc_us"],
         f"cycles/sym={r['py_enc_cycles']:.0f}")
    emit("fig4a_encode_ras_multilane", r["jax_enc_us"],
         f"speedup={r['speedup_enc']:.1f}x (paper: 121.2x)")
    emit("fig4a_decode_python_baseline", r["py_dec_us"], "")
    emit("fig4a_decode_ras_multilane", r["jax_dec_us"],
         f"speedup={r['speedup_dec']:.1f}x (paper: 70.9x)")
    emit("fig4a_decode_ras_lut_beyond_paper", r["jax_lut_us"],
         f"speedup={r['speedup_dec_lut']:.1f}x (static-table O(1) LUT)")
    for p in run_encode_backends():
        emit(f"encode_backend_{p['name']}_coder",
             p["coder_us_per_symbol"],
             "us/symbol, pure-JAX lane coder")
        emit(f"encode_backend_{p['name']}_kernel",
             p["kernel_interpret_us_per_symbol"],
             "us/symbol, Pallas kernel (INTERPRET mode; byte-identical)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_encode.json")
    args = ap.parse_args()
    pts = run_encode_backends()
    with open(args.out, "w") as f:
        json.dump(pts, f, indent=2)
    for p in pts:
        print(f"{p['name']}: coder {p['coder_us_per_symbol']:.3f} us/sym, "
              f"kernel(interpret) "
              f"{p['kernel_interpret_us_per_symbol']:.3f} us/sym, "
              f"byte-identical={p['backends_byte_identical']}")
    print(f"wrote {len(pts)} points -> {args.out}")
